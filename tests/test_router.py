"""Fleet-layer tests (launch/router.py): N replicas behind the
telemetry-driven router, simulated in-process and driven entirely by
the FakeClock harness — zero real sleeps.

The acceptance properties pinned here:

  (a) p99-aware routing beats round-robin on tail latency when the
      replicas are heterogeneous (one fast, one slow server);
  (b) under overload, interactive-class requests are never shed before
      batch-class ones (batch admission stops at ``batch_threshold``,
      interactive continues to ``max_outstanding``);
  (c) the control loop's online CostParams re-fit changes a live
      routing decision (single_device -> row_band for a tall bucket)
      with no restart — ``Planner.set_params`` swaps the analytic
      constants under any measured overlay.

Plus: watchdog-based replica health (exclusion, probing, and recovery
through the adapted EMA), per-replica metric labels aggregating into
one scrape, and the admission/validation surface.
"""
from concurrent.futures import Future
from types import SimpleNamespace

import numpy as np
import pytest

from repro.launch.batching import FakeClock, QueueFull
from repro.launch.router import (
    DEADLINE_CLASSES,
    POLICIES,
    Router,
    ServiceReplica,
)
from repro.runtime.executor import plan_kind
from repro.runtime.fault_tolerance import Watchdog
from repro.runtime.planner import PlanFeatures, Planner
from repro.runtime.telemetry import CostBook


class SimService:
    """One simulated replica: a FIFO single-server queue on a shared
    FakeClock.  ``submit`` computes the request's completion time from
    the server's backlog; futures resolve when the clock advances past
    it — so a fleet of these is exactly deterministic."""

    def __init__(self, clk: FakeClock, service_s: float,
                 hw=(64, 64)):
        self.clock = clk
        self.service_s = service_s      # mutable: tests inject slowdowns
        self.hw = tuple(hw)
        self.book = CostBook(warmup=0)
        self.started = False
        self._busy_until = 0.0
        self._queue = []                # (done_at, seq, fut, payload)
        self._seq = 0
        clk.subscribe(self._drain)

    def start_batched(self):
        self.started = True

    def stop_batched(self):
        self.started = False

    def submit(self, payload):
        assert self.started, "submit before start_batched"
        fut = Future()
        now = self.clock()
        done = max(now, self._busy_until) + self.service_s
        self._busy_until = done
        self.book.record_step(self.hw, 1, "single_device",
                              self.service_s)
        self._queue.append((done, self._seq, fut, payload))
        self._seq += 1
        return fut

    def _drain(self):
        now = self.clock()
        due = sorted(q for q in self._queue if q[0] <= now)
        self._queue = [q for q in self._queue if q[0] > now]
        for _done_at, _seq, fut, payload in due:
            fut.set_result(payload)


def no_health_watchdog():
    """A watchdog that never flags — isolates pure-routing tests from
    the replica-health machinery."""
    return Watchdog(threshold=float("inf"), warmup_steps=0)


def make_fleet(clk, service_times, *, policy, **router_kw):
    sims = [SimService(clk, s) for s in service_times]
    reps = [ServiceReplica(f"r{i}", sim, clock=clk,
                           watchdog=no_health_watchdog())
            for i, sim in enumerate(sims)]
    router_kw.setdefault("unhealthy_after", 10 ** 9)
    return sims, reps, Router(reps, policy=policy, clock=clk,
                              **router_kw)


def drive(clk, router, n_requests, arrival_dt):
    """Open-loop arrival process: one request per ``arrival_dt`` of
    fake time; returns every request's measured latency."""
    lat = []
    futs = []
    for i in range(n_requests):
        t0 = clk()
        fut = router.submit(i)
        fut.add_done_callback(
            lambda f, t0=t0: lat.append(clk() - t0))
        futs.append(fut)
        clk.advance(arrival_dt)
    clk.advance(1000.0)                 # drain the fleet
    assert all(f.done() for f in futs)
    return sorted(lat)


class TestP99Routing:
    """Acceptance (a): tail-aware placement on heterogeneous replicas."""

    SERVICE_TIMES = (0.05, 0.5)         # r0 fast, r1 10x slower
    N, ARRIVAL = 24, 0.1

    def _run(self, policy):
        clk = FakeClock()
        _, _, router = make_fleet(clk, self.SERVICE_TIMES, policy=policy)
        with router:
            lat = drive(clk, router, self.N, self.ARRIVAL)
            placed = dict(router.stats["placed"])
        return lat, placed

    def test_p99_routing_beats_round_robin_tail(self):
        rr_lat, rr_placed = self._run("round_robin")
        p99_lat, p99_placed = self._run("p99")
        # identical arrival schedule, same simulated fleet: round-robin
        # piles half the traffic on the slow replica and its queue
        # grows without bound; p99 scoring discounts it
        assert rr_placed == {"r0": 12, "r1": 12}
        assert p99_placed["r0"] >= 20
        assert max(rr_lat) > 2.0 * max(p99_lat)
        assert max(p99_lat) <= 1.0       # slow replica explored, once-ish
        # every request still completed under both policies
        assert len(rr_lat) == len(p99_lat) == self.N

    def test_least_loaded_follows_queue_depth(self):
        clk = FakeClock()
        sims, reps, router = make_fleet(clk, (0.05, 0.05),
                                        policy="least_loaded")
        with router:
            # preload r0 outside the router: 4 requests queued
            for i in range(4):
                reps[0].submit(("pre", i))
            assert reps[0].load() == 4.0
            before = dict(router.stats["placed"])
            router.submit("x")
            after = router.stats["placed"]
            assert after["r1"] == before["r1"] + 1
            clk.advance(10.0)

    def test_unmeasured_replica_gets_explored_under_p99(self):
        clk = FakeClock()
        _, reps, router = make_fleet(clk, (0.05, 0.05), policy="p99")
        with router:
            for i in range(4):
                router.submit(i)
                clk.advance(0.2)
            placed = router.stats["placed"]
            # neither replica starves: the unmeasured one scores as
            # free until it has samples
            assert placed["r0"] >= 1 and placed["r1"] >= 1
            clk.advance(10.0)


class TestDeadlineClassAdmission:
    """Acceptance (b): batch sheds first, interactive keeps headroom."""

    def _router(self, clk, **kw):
        kw.setdefault("max_outstanding", 8)
        kw.setdefault("batch_threshold", 4)
        _, _, router = make_fleet(clk, (100.0,), policy="round_robin",
                                  **kw)
        return router

    def test_batch_sheds_before_interactive(self):
        clk = FakeClock()
        router = self._router(clk)
        with router:
            admitted = []
            for i in range(4):           # fill to the batch threshold
                admitted.append(router.submit(i, deadline_class="batch"))
            with pytest.raises(QueueFull):
                router.submit("b!", deadline_class="batch")
            assert router.stats["shed"] == {"interactive": 0, "batch": 1}
            # interactive still has headroom up to the full cap
            for i in range(4):
                admitted.append(
                    router.submit(i, deadline_class="interactive"))
            with pytest.raises(QueueFull):
                router.submit("i!", deadline_class="interactive")
            assert router.stats["shed"] == {"interactive": 1, "batch": 1}
            # every admitted request drains and completes
            clk.advance(10_000.0)
            assert all(f.done() for f in admitted)

    def test_interactive_never_sheds_before_batch_on_mixed_stream(self):
        clk = FakeClock()
        router = self._router(clk)
        with router:
            sheds = []                   # deadline classes in shed order
            for i in range(30):          # overload, nothing completes
                cls = "interactive" if i % 2 else "batch"
                try:
                    router.submit(i, deadline_class=cls)
                except QueueFull:
                    sheds.append(cls)
            assert sheds, "overload never shed"
            assert sheds[0] == "batch"
            first_interactive = sheds.index("interactive") \
                if "interactive" in sheds else len(sheds)
            assert "batch" in sheds[:first_interactive]
            clk.advance(10_000.0)

    def test_unknown_deadline_class_rejected(self):
        clk = FakeClock()
        router = self._router(clk)
        with router:
            with pytest.raises(ValueError, match="deadline class"):
                router.submit(0, deadline_class="best_effort")
            clk.advance(10_000.0)


def fake_mesh(data_n=1, model_n=4):
    """mesh_axis_sizes only reads axis_names + devices.shape, so a
    duck-typed mesh routes plans without any real devices."""
    return SimpleNamespace(
        axis_names=("data", "model"),
        devices=np.empty((data_n, model_n), dtype=object))


def tall_features(hw):
    h, w = hw
    return PlanFeatures(flops=2e5 * h * w / 64.0,
                        halo_bytes=3e4 * w / 64.0,
                        deepest_stride=32, halo_layers=20)


class TestOnlineRefit:
    """Acceptance (c): the control loop re-fits CostParams from the
    live book and flips a routing decision with no restart."""

    HW = (128, 64)                       # H % (model_n * stride) == 0

    def _replica(self, clk):
        svc = SimService(clk, 0.05)
        svc.planner = Planner(fake_mesh(1, 4), tall_features)
        # live "measurements": single_device steps are far slower than
        # the napkin constants predict (a slow host), linear in FLOPs
        # so the least-squares fit recovers peak_flops exactly
        for _ in range(3):
            svc.book.record_step(self.HW, 1, "single_device", 0.02)
            svc.book.record_step((64, 64), 1, "single_device", 0.01)
        return svc, ServiceReplica("r0", svc, clock=clk,
                                   features_fn=tall_features,
                                   watchdog=no_health_watchdog())

    def test_control_loop_refit_flips_routing_online(self):
        clk = FakeClock()
        svc, rep = self._replica(clk)
        router = Router([rep], policy="p99", refit_interval_s=10.0,
                        clock=clk)
        with router:
            planner = svc.planner
            # napkin constants: overhead dominates, the tall bucket
            # stays on a single device
            assert plan_kind(planner.choose(self.HW, 1)) == \
                "single_device"
            clk.advance(10.5)            # the control loop tick fires
            assert router.stats["refits"] >= 1
            # fitted peak_flops ~1.28e9 makes compute dominant, so
            # splitting the rows across the model axis wins — the SAME
            # planner object routes differently, no restart
            assert plan_kind(planner.choose(self.HW, 1)) == "row_band"
            assert planner.params.peak_flops == pytest.approx(1.28e9,
                                                              rel=1e-3)

    def test_refit_now_returns_fitted_params_per_replica(self):
        clk = FakeClock()
        svc, rep = self._replica(clk)
        router = Router([rep], policy="p99", clock=clk)
        with router:
            fitted = router.refit_now()
            assert set(fitted) == {"r0"}
            assert fitted["r0"].peak_flops == pytest.approx(1.28e9,
                                                            rel=1e-3)

    def test_set_params_preserves_measured_overlay(self):
        from repro.runtime.planner import CostParams, MeasuredCost

        book = CostBook(warmup=0)
        planner = Planner(fake_mesh(1, 4), tall_features)
        planner.use_measurements(book)
        new = CostParams(peak_flops=1.28e9)
        planner.set_params(new)
        assert isinstance(planner.cost, MeasuredCost)
        assert planner.cost.book is book
        assert planner.params == new

    def test_replica_without_planner_refits_to_none(self):
        clk = FakeClock()
        svc = SimService(clk, 0.05)
        rep = ServiceReplica("r0", svc, clock=clk,
                             watchdog=no_health_watchdog())
        assert rep.refit() is None


class TestReplicaHealth:
    """Watchdog-driven exclusion, probing, and recovery: a replica
    that slows down 10x is routed around; its probes feed the adapted
    EMA (the fault_tolerance fix) so it rejoins once the slowdown is
    its own baseline."""

    def test_slow_replica_excluded_then_recovers(self):
        clk = FakeClock()
        fast = SimService(clk, 0.05)
        sick = SimService(clk, 0.05)
        wd = Watchdog(threshold=3.0, ema=0.5, warmup_steps=0,
                      adapt_after=2)
        reps = [
            ServiceReplica("r0", fast, clock=clk,
                           watchdog=no_health_watchdog()),
            ServiceReplica("r1", sick, clock=clk, watchdog=wd),
        ]
        router = Router(reps, policy="round_robin", unhealthy_after=2,
                        probe_every=4, clock=clk)

        def place_one(i):
            before = dict(router.stats["placed"])
            router.submit(i)
            # fine-grained ticks: a request's measured latency is its
            # resolving tick, so 0.1 s granularity separates the fast
            # (0.05 s) from the slowed (1.0 s) server
            for _ in range(11):
                clk.advance(0.1)
            after = router.stats["placed"]
            return next(n for n in after if after[n] != before[n])

        with router:
            for i in range(6):           # warm both watchdog EMAs
                place_one(i)
            sick.service_s = 1.0         # sustained 10x slowdown
            placements = [place_one(i) for i in range(16)]
        # the slowdown is detected and r1 is routed around...
        assert wd.incidents, "slowdown never flagged"
        r0_run = max(len(s) for s in
                     "".join("x" if p == "r0" else "." for p in
                             placements).split("."))
        assert r0_run >= 3, placements
        # ...probes keep feeding its watchdog, the EMA adapts, and r1
        # rejoins the rotation
        assert router.stats["probes"] >= 1
        assert wd.consecutive == 0
        first_exclusion = placements.index("r0")
        assert "r1" in placements[first_exclusion + r0_run:], placements

    def test_all_unhealthy_still_routes(self):
        clk = FakeClock()
        sim = SimService(clk, 0.05)
        wd = Watchdog(threshold=3.0, warmup_steps=0, adapt_after=10 ** 9)
        rep = ServiceReplica("r0", sim, clock=clk, watchdog=wd)
        router = Router([rep], policy="round_robin", unhealthy_after=1,
                        clock=clk)
        with router:
            wd.ema = 1e-9                # everything is a straggler now
            router.submit(0)
            clk.advance(1.0)
            router.submit(1)             # degraded fleet: still placed
            clk.advance(1.0)
            assert router.stats["placed"]["r0"] == 2


class TestFleetTelemetry:
    def test_one_scrape_aggregates_all_replicas_without_clobbering(self):
        clk = FakeClock()
        _, reps, router = make_fleet(clk, (0.05, 0.5), policy="p99")
        with router:
            drive(clk, router, 8, 0.1)
            snap = router.metrics_snapshot()
        for name in ("r0", "r1"):
            # each replica's book series and gauges are present under
            # its own label — the label dimension prevents clobbering
            assert any(f'replica="{name}"' in k
                       and k.startswith("std_step_p99_s{")
                       for k in snap), name
            assert snap[f'std_replica_outstanding{{replica="{name}"}}'] \
                == 0.0
        placed = sum(
            snap[f'std_router_placed_total{{replica="{n}"}}']
            for n in ("r0", "r1"))
        assert placed == 8.0
        assert snap['std_router_shed_total{class="interactive"}'] == 0.0
        assert snap["std_router_outstanding"] == 0.0

    def test_replica_labels_book_on_wrap(self):
        clk = FakeClock()
        sim = SimService(clk, 0.05)
        ServiceReplica("west-3", sim, clock=clk)
        assert sim.book.labels == {"replica": "west-3"}


class TestRouterValidation:
    def test_policy_and_replica_validation(self):
        clk = FakeClock()
        sim = SimService(clk, 0.05)
        rep = ServiceReplica("r0", sim, clock=clk)
        with pytest.raises(ValueError, match="at least one"):
            Router([])
        with pytest.raises(ValueError, match="unknown policy"):
            Router([rep], policy="fastest_first")
        dup = ServiceReplica("r0", SimService(clk, 0.05), clock=clk)
        with pytest.raises(ValueError, match="unique"):
            Router([rep, dup])
        assert set(POLICIES) == {"round_robin", "p99", "least_loaded"}
        assert set(DEADLINE_CLASSES) == {"interactive", "batch"}

    def test_submit_before_start_rejected(self):
        clk = FakeClock()
        rep = ServiceReplica("r0", SimService(clk, 0.05), clock=clk)
        router = Router([rep])
        with pytest.raises(RuntimeError, match="start"):
            router.submit(0)

    def test_service_level_shed_rolls_back_outstanding(self):
        clk = FakeClock()

        class Shedding:
            book = None

            def start_batched(self):
                pass

            def stop_batched(self):
                pass

            def submit(self, payload):
                raise QueueFull("service full")

        rep = ServiceReplica("r0", Shedding(), clock=clk)
        router = Router([rep], policy="round_robin")
        with router:
            with pytest.raises(QueueFull):
                router.submit(0)
            assert router.outstanding() == 0
            assert router.stats["shed"]["interactive"] == 1
