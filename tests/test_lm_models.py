"""LM model tests: per-arch reduced smoke (fwd + train step, shapes +
finiteness), decode==full consistency, MoE invariants, microcode-driven
block structure, weight sharing in hybrids."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.lm import LMModel, cross_entropy
from repro.models.lm import moe as moe_mod


def _prefix_for(cfg, batch=2):
    if cfg.frontend == "none":
        return None
    return jax.random.normal(
        jax.random.PRNGKey(9), (batch, cfg.frontend_len, cfg.d_model)
    ) * 0.1


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = get_smoke_config(arch)
        model = LMModel(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab)
        kw = {}
        pf = _prefix_for(cfg)
        if pf is not None:
            kw["prefix_embed"] = pf
        logits = model.forward(params, toks, **kw)
        assert logits.shape == (2, 16, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

        # one full train step (fwd+bwd+sgd) must stay finite
        def loss(p):
            return cross_entropy(model.forward(p, toks, **kw), toks)

        l0, g = jax.value_and_grad(loss)(params)
        new_p = jax.tree_util.tree_map(lambda p, gg: p - 1e-2 * gg, params, g)
        l1 = loss(new_p)
        assert np.isfinite(float(l0)) and np.isfinite(float(l1))
        assert all(bool(jnp.all(jnp.isfinite(x)))
                   for x in jax.tree_util.tree_leaves(g))


@pytest.mark.parametrize(
    "arch", ["tinyllama-1.1b", "mamba2-370m", "zamba2-2.7b", "whisper-tiny",
             "qwen2.5-14b"]
)
def test_decode_matches_full_forward(arch):
    """Prefill 8 + token-by-token decode == one-shot forward."""
    cfg = get_smoke_config(arch)
    model = LMModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    kw = {}
    pf = _prefix_for(cfg)
    if pf is not None:
        kw["prefix_embed"] = pf
    full = model.forward(params, toks, **kw)
    _, cache = model.forward(params, toks[:, :8], cache_out=True,
                             max_len=16, **kw)
    cl = 8
    for t in range(8, 16):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache, cl)
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err < 5e-3, (t, err)
        cl += 1


class TestMoE:
    def _setup(self, E=8, k=2, d=32, f=64, T=64, cf=1.25):
        table = {"n_experts": E, "top_k": k, "capacity_factor": cf}
        meta = moe_mod.moe_meta(d, f, E, jnp.float32)
        from repro.models.lm.params import materialize

        p = materialize(meta, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, T // 2, d))
        return p, x, table

    def test_output_shape_finite(self):
        p, x, table = self._setup()
        y = moe_mod.moe(p, x, table=table)
        assert y.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_high_capacity_equals_dense_mixture(self):
        """With cf high enough nothing drops: output == explicit top-k sum."""
        p, x, table = self._setup(cf=16.0)
        y = moe_mod.moe(p, x, table=table)
        B, L, D = x.shape
        xt = x.reshape(-1, D)
        gates = jax.nn.softmax(xt @ p["router"], axis=-1)
        topv, topi = jax.lax.top_k(gates, table["top_k"])
        topv = topv / topv.sum(-1, keepdims=True)
        dense = jnp.zeros_like(xt)
        for e in range(table["n_experts"]):
            h = jax.nn.silu(xt @ p["wg"][e]) * (xt @ p["wu"][e])
            ye = h @ p["wd"][e]
            w = jnp.sum(jnp.where(topi == e, topv, 0.0), axis=-1)
            dense = dense + ye * w[:, None]
        np.testing.assert_allclose(
            y.reshape(-1, D), dense, atol=2e-4, rtol=2e-3
        )

    def test_capacity_drops_tokens(self):
        p, x, table = self._setup(cf=0.25)
        y = moe_mod.moe(p, x, table=table)
        # some tokens must be zero-contribution (dropped from all slots)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_rank_computation(self):
        ids = jnp.asarray([0, 1, 0, 2, 0, 1], jnp.int32)
        ranks = moe_mod._ranks_by_sort(ids, 3)
        np.testing.assert_array_equal(ranks, [0, 0, 1, 0, 2, 1])

    def test_aux_loss_balanced_vs_skewed(self):
        p, x, table = self._setup()
        bal = moe_mod.aux_load_loss(p, x, table=table)
        p_skew = dict(p)
        p_skew["router"] = p["router"].at[:, 0].add(100.0)  # all -> expert 0
        skew = moe_mod.aux_load_loss(p_skew, x, table=table)
        assert float(skew) > float(bal)


class TestHybridWeightSharing:
    def test_shared_attention_single_copy(self):
        """zamba2: 9 call sites, ONE parameter set (microcode addr reuse)."""
        cfg = get_smoke_config("zamba2-2.7b")
        model = LMModel(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        assert "shared_attn" in params
        n_sites = cfg.n_layers // cfg.attn_every
        assert n_sites == 2
        # mamba layers stacked; shared attn has NO layer dim
        sa_wq = params["shared_attn"]["shared_attn"]["wq"]
        assert sa_wq.ndim == 3                       # (d, h, hd) — unstacked
        lyr = params["layers"]["ssm"]["in_proj"]
        assert lyr.shape[0] == cfg.n_layers          # stacked

    def test_grad_flows_to_shared_block(self):
        cfg = get_smoke_config("zamba2-2.7b")
        model = LMModel(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                  cfg.vocab)
        g = jax.grad(
            lambda p: cross_entropy(model.forward(p, toks), toks)
        )(params)
        gn = float(jnp.linalg.norm(g["shared_attn"]["shared_attn"]["wq"]))
        assert gn > 0                               # both call sites contribute


class TestMicrocodeDriven:
    def test_block_is_microcode_stream(self):
        cfg = get_smoke_config("tinyllama-1.1b")
        model = LMModel(cfg)
        from repro.core.microcode import ExtOp

        ops = [w.ext_opcode for w in model.block.words]
        assert ExtOp.ATTN in ops
        assert ExtOp.GLU_MLP in ops
        # transformer residual == paper Fig.3 cache/add
        from repro.core.microcode import ResOp

        res = [w.res_op for w in model.block.words]
        assert res.count(int(ResOp.CACHE)) == 2
        assert res.count(int(ResOp.ADD)) == 2

    def test_stream_packs_to_256bit_words(self):
        from repro.core.microcode import pack_program, unpack_program

        cfg = get_smoke_config("grok-1-314b")
        model = LMModel(cfg)
        raw = pack_program(model.block.words)
        assert raw.shape[1] == 32
        assert unpack_program(raw) == model.block.words

    def test_moe_hyperparams_from_side_table(self):
        cfg = get_smoke_config("kimi-k2-1t-a32b")
        model = LMModel(cfg)
        from repro.core.microcode import ExtOp

        moe_words = [w for w in model.block.words
                     if w.ext_opcode == ExtOp.MOE]
        assert len(moe_words) == 1
        tbl = model.block.tables[moe_words[0].ext_table_idx - 1]
        assert tbl["n_experts"] == cfg.n_experts
        assert tbl["top_k"] == cfg.top_k
