"""BFP (paper Algorithm 1) tests: bit-exactness vs a numpy oracle, the
1-block-ulp error bound, matmul semantics, wide-vs-narrow accumulator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # bare interpreter: seeded fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import bfp


def numpy_algorithm1(x: np.ndarray, mantissa_bits: int) -> np.ndarray:
    """Literal Algorithm 1 over one block, integer mantissas, trunc shift."""
    m, e = np.frexp(x.astype(np.float64))
    e = np.where(x == 0, -(2**30), e)
    xi = max(e.max(), -(2**29))
    mi = np.trunc(m * (1 << mantissa_bits)).astype(np.int64)
    d = np.minimum(xi - e, 31)
    mb = mi >> d
    return (mb * np.exp2(float(xi - mantissa_bits))).astype(np.float32)


class TestAlgorithm1:
    @settings(max_examples=50, deadline=None)
    @given(
        st.integers(0, 2**31 - 1),
        st.sampled_from([4, 7, 10, 15]),
    )
    def test_bit_exact_vs_numpy(self, seed, mb):
        x = np.random.default_rng(seed).normal(
            size=(32,)).astype(np.float32) * 10 ** np.random.default_rng(
            seed + 1).uniform(-3, 3)
        ours = np.asarray(bfp.roundtrip(
            jnp.asarray(x), block_size=32, mantissa_bits=mb, rounding="trunc"
        ))
        oracle = numpy_algorithm1(x, mb)
        np.testing.assert_array_equal(ours, oracle)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16, 32, 64]))
    def test_error_bounded_by_block_ulp(self, seed, bs):
        x = jnp.asarray(
            np.random.default_rng(seed).normal(size=(4, 128)), jnp.float32
        )
        t = bfp.quantize(x, block_size=bs, mantissa_bits=10)
        y = bfp.dequantize(t)
        xb = np.asarray(x).reshape(4, 128 // bs, bs)
        yb = np.asarray(y).reshape(4, 128 // bs, bs)
        ulp = np.exp2(np.asarray(t.exponent) - 10.0)[..., None]
        assert np.max(np.abs(xb - yb) / ulp) <= 1.0 + 1e-6

    def test_exact_for_shared_exponent_values(self):
        x = jnp.asarray([[1.0, -0.5, 0.75, 1.5] * 8])
        assert jnp.array_equal(bfp.roundtrip(x, block_size=32), x)

    def test_zeros_preserved(self):
        x = jnp.zeros((2, 64))
        assert jnp.array_equal(bfp.roundtrip(x), x)
        mixed = jnp.asarray([[0.0, 1.0] * 16])
        y = bfp.roundtrip(mixed)
        assert jnp.array_equal(y, mixed)

    def test_error_decreases_with_mantissa_bits(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 256))
        errs = [
            float(bfp.quantization_error(x, mantissa_bits=mb))
            for mb in (4, 7, 10, 15)
        ]
        assert errs == sorted(errs, reverse=True)
        assert errs[-1] < 1e-3

    def test_pad_nondivisible_block(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 50))
        y = bfp.roundtrip(x, block_size=32)
        assert y.shape == x.shape
        rel = jnp.abs(x - y) / jnp.maximum(jnp.abs(x), 1e-6)
        assert float(jnp.median(rel)) < 1e-2

    def test_remainder_block_error_bounded(self):
        """A trailing 8-wide remainder block (40 = 32 + 8) gets its OWN
        shared exponent: its error must obey the same block-ulp bound as
        full blocks, and a large magnitude in the full block must not
        leak into the remainder block's scaling."""
        mb = 10
        x = jax.random.normal(jax.random.PRNGKey(5), (4, 40))
        # blow up one element of the FULL block only
        x = x.at[:, 0].set(1000.0)
        y = bfp.roundtrip(x, block_size=32, mantissa_bits=mb)
        assert y.shape == x.shape
        # remainder block [32:40] scales to its own max, not the 1000
        rem = x[:, 32:]
        ulp = jnp.max(jnp.abs(rem), axis=1, keepdims=True) * 2.0 ** (
            1 - mb)
        assert bool(jnp.all(jnp.abs(rem - y[:, 32:]) <= ulp))
        # idempotence holds across the remainder block too (the
        # property the interpreter's in-call weight quantization needs)
        np.testing.assert_array_equal(
            np.asarray(bfp.roundtrip(y, block_size=32, mantissa_bits=mb)),
            np.asarray(y))

    def test_nbytes_model(self):
        t = bfp.quantize(jnp.ones((128, 256)), block_size=32,
                         mantissa_bits=7)
        # int8 mantissas + 1B/exponent
        assert t.nbytes_model() == 128 * 256 + 128 * 8


class TestBFPMatmul:
    def test_wide_accum_close_to_f32(self):
        a = jax.random.normal(jax.random.PRNGKey(0), (32, 128))
        b = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
        c = bfp.bfp_matmul_reference(a, b, mantissa_bits=12)
        rel = float(jnp.max(jnp.abs(c - a @ b)) / jnp.max(jnp.abs(a @ b)))
        assert rel < 2e-3

    def test_narrow_accumulator_worse_than_wide(self):
        """The §IV.C motivation: truncating partial sums loses accuracy."""
        a = jax.random.normal(jax.random.PRNGKey(2), (16, 512)) * 3
        b = jax.random.normal(jax.random.PRNGKey(3), (512, 16))
        ref = a @ b
        wide = bfp.bfp_matmul_reference(a, b, mantissa_bits=6,
                                        wide_accum=True)
        narrow = bfp.bfp_matmul_reference(a, b, mantissa_bits=6,
                                          wide_accum=False)
        err_w = float(jnp.mean(jnp.abs(wide - ref)))
        err_n = float(jnp.mean(jnp.abs(narrow - ref)))
        assert err_n > err_w

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 1000))
    def test_matmul_grows_with_precision(self, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        a = jax.random.normal(k1, (8, 64))
        b = jax.random.normal(k2, (64, 8))
        ref = a @ b
        errs = [
            float(jnp.max(jnp.abs(
                bfp.bfp_matmul_reference(a, b, mantissa_bits=mb) - ref)))
            for mb in (5, 10, 15)
        ]
        assert errs[0] >= errs[1] >= errs[2]
