"""Precision-mode serving tests: the (bucket, batch, plan, precision)
engine identity, the bfp-vs-f32 accuracy-parity gate, and the engine
state/bootstrap bugfix regressions that rode along (concurrent
transposed tracing, in-call BFP weight quantization, backend-derived
Pallas interpret default)."""
import inspect
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Assembler, BFPConfig, FCNEngine, LayerSpec


def tiny_program(hw=(16, 16), *, bn=False):
    specs = [
        LayerSpec("c1", "conv", ["input"], out_ch=8, kernel=3, relu=True,
                  bn=bn),
        LayerSpec("c2", "conv", ["c1"], out_ch=8, kernel=3, relu=True),
        LayerSpec("cc", "conv", ["c2"], out_ch=4, kernel=1),
        LayerSpec("sg", "sigmoid", ["cc"]),
    ]
    return Assembler((hw[0], hw[1], 3)).assemble(specs, outputs=["sg"])


def _std_model(hw, precision="f32"):
    from repro.models.fcn.pixellink import PixelLinkModel, STDConfig

    return PixelLinkModel(STDConfig(
        backbone="vgg16", width=0.125, image_size=hw,
        merge_ch=(16, 16, 8),
        bfp=BFPConfig() if precision == "bfp" else None,
        storage_fp16=(precision == "bfp"),
    ))


class TestEngineLRUPrecision:
    """Tentpole: precision is part of the engine identity — a precision
    change is a new compiled engine and a new param entry, never a
    cache hit on the other numerics."""

    def test_distinct_engines_and_params_per_precision(self):
        from repro.runtime.executor import EngineFactory, SingleDevice

        fac = EngineFactory(_std_model)
        hw = (64, 64)
        f_f32 = fac.plan_fn(hw, 1, SingleDevice(), "f32")
        f_bfp = fac.plan_fn(hw, 1, SingleDevice(), "bfp")
        assert f_f32 is not f_bfp
        assert len(fac) == 2
        # cache hits return the identical callable per precision
        assert fac.plan_fn(hw, 1, SingleDevice(), "f32") is f_f32
        assert fac.plan_fn(hw, 1, SingleDevice(), "bfp") is f_bfp
        # compiled stats record the precision axis
        precs = {e["precision"] for e in fac.stats["compiled"]}
        assert precs == {"f32", "bfp"}
        # bfp params are the f32 set through normalize_weights: same
        # factory, different trees (BN folded / weights quantized)
        pf = fac.params(hw, "f32")
        pb = fac.params(hw, "bfp")
        assert pf is not pb

    def test_both_precisions_serve_same_weight_set(self):
        """f32 and bfp engines produce close (not identical) maps from
        the shared PRNGKey(0) weight set — close proves one weight set,
        a nonzero delta proves the bfp engine actually quantized."""
        from repro.runtime.executor import EngineFactory

        fac = EngineFactory(_std_model)
        hw = (64, 64)
        x = jax.random.uniform(jax.random.PRNGKey(3), (1, 64, 64, 3))
        of = fac.model(hw, "f32").apply(fac.params(hw, "f32"), x)
        ob = fac.model(hw, "bfp").apply(fac.params(hw, "bfp"), x)
        d = float(jnp.max(jnp.abs(of["score"] - ob["score"])))
        assert 0.0 < d < 0.05

    def test_unknown_precision_rejected(self):
        from repro.runtime.executor import (EngineFactory, SingleDevice,
                                            check_precision)

        with pytest.raises(ValueError, match="unknown precision"):
            check_precision("fp8")
        fac = EngineFactory(_std_model)
        with pytest.raises(ValueError, match="unknown precision"):
            fac.plan_fn((64, 64), 1, SingleDevice(), "fp8")

    def test_legacy_single_arg_factory_pins_f32(self):
        from repro.runtime.executor import EngineFactory, SingleDevice

        fac = EngineFactory(lambda hw: _std_model(hw))
        assert fac.plan_fn((64, 64), 1, SingleDevice()) is not None
        with pytest.raises(ValueError, match="precision-aware"):
            fac.plan_fn((64, 64), 1, SingleDevice(), "bfp")


class TestConcurrentTranspose:
    """Bugfix regression: FCNEngine used to stash ``transposed`` as
    mutable instance state read later by ``_conv`` — two concurrent
    traces could bake the WRONG kernel orientation into a compiled
    engine.  ``transposed`` is now a threaded argument."""

    def test_no_transposed_attribute(self):
        eng = FCNEngine(tiny_program())
        params = eng.init_params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 3))
        eng(params, x)
        eng(params, x, transposed=True)
        assert not hasattr(eng, "_transposed")

    def test_concurrent_traces_keep_orientation(self):
        prog = tiny_program((16, 16))
        progT = tiny_program((16, 16))
        eng = FCNEngine(prog)
        engT = FCNEngine(progT)
        params = eng.init_params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 3))
        xT = jnp.transpose(x, (0, 2, 1, 3))
        want = np.asarray(eng(params, x)["sg"])
        wantT = np.asarray(engT(params, xT, transposed=True)["sg"])

        n_rounds, n_threads = 8, 4
        errors = []
        barrier = threading.Barrier(n_threads)

        def worker(i):
            try:
                for r in range(n_rounds):
                    barrier.wait()
                    if (i + r) % 2 == 0:
                        got = np.asarray(eng(params, x)["sg"])
                        ref = want
                    else:
                        got = np.asarray(engT(params, xT,
                                              transposed=True)["sg"])
                        ref = wantT
                    np.testing.assert_allclose(got, ref, atol=1e-5)
            except Exception as e:            # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors[0]


class TestBFPWeightQuantization:
    """Bugfix regression: with ``bfp`` set, ``_conv`` used to quantize
    activations but silently run UN-quantized f32 weights unless the
    caller remembered ``normalize_weights()`` first.  Weights now
    quantize in-call (idempotent trunc rounding makes pre-normalized
    weights pass through unchanged)."""

    def setup_method(self, _):
        self.prog = tiny_program(bn=False)     # no BN: normalize_weights
                                               # is then ONLY the BFP
                                               # weight roundtrip
        self.x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16, 3))

    def test_raw_params_equal_normalized_params(self):
        eng = FCNEngine(self.prog, bfp=BFPConfig(mantissa_bits=6))
        params = eng.init_params(jax.random.PRNGKey(1))
        a = eng(params, self.x)["sg"]                       # raw entry
        b = eng(eng.normalize_weights(params), self.x)["sg"]  # normalized
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_raw_params_differ_from_f32(self):
        """The in-call weight roundtrip must actually bite: a coarse
        mantissa visibly moves the output vs the f32 engine."""
        eng_f = FCNEngine(self.prog)
        eng_b = FCNEngine(self.prog, bfp=BFPConfig(mantissa_bits=6))
        params = eng_f.init_params(jax.random.PRNGKey(1))
        a = eng_f(params, self.x)["sg"]
        b = eng_b(params, self.x)["sg"]
        assert float(jnp.max(jnp.abs(a - b))) > 0.0


class TestInterpretDefault:
    """Bugfix regression: the Pallas kernels defaulted interpret=True
    everywhere, so even TPU runs interpreted.  The default now derives
    from the backend (compiled on TPU, interpreted elsewhere)."""

    def test_default_is_backend_derived(self):
        from repro.kernels import default_interpret
        from repro.kernels.bfp_matmul.ops import bfp_matmul
        from repro.kernels.winograd_conv.ops import winograd_conv2d

        for fn in (winograd_conv2d, bfp_matmul):
            p = inspect.signature(fn).parameters["interpret"]
            assert p.default is None, fn.__qualname__
        assert default_interpret() == (jax.default_backend() != "tpu")

    def test_winograd_runs_without_explicit_interpret(self):
        from repro.kernels.winograd_conv.ops import winograd_conv2d

        k = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(k[0], (1, 8, 8, 4))
        w = jax.random.normal(k[1], (3, 3, 4, 8))
        y = winograd_conv2d(x, w)
        assert y.shape == (1, 8, 8, 8)

    def test_bfp_matmul_runs_without_explicit_interpret(self):
        from repro.kernels.bfp_matmul.ops import bfp_matmul

        k = jax.random.split(jax.random.PRNGKey(1))
        a = jax.random.normal(k[0], (16, 32))
        b = jax.random.normal(k[1], (32, 8))
        y = bfp_matmul(a, b)
        np.testing.assert_allclose(np.asarray(y), np.asarray(a @ b),
                                   atol=0.2, rtol=0.2)


class TestServicePrecision:
    """Service-level wiring: STDService(precision=...) routes plan_fn /
    params / telemetry through the requested numerics, and the
    bfp-vs-f32 parity gate holds on the serving buckets."""

    def test_service_records_per_precision_walls(self):
        from repro.launch.serve import STDService
        from repro.runtime.telemetry import CostBook

        img = (np.random.default_rng(0).random((40, 56, 3)) * 255
               ).astype(np.float32)
        svc = STDService(width=0.125, buckets=(64,), max_batch=2,
                         book=CostBook(warmup=0), precision="bfp")
        boxes = svc(img)
        assert isinstance(boxes, list)
        assert svc.factory.stats["compiled"][0]["precision"] == "bfp"
        hw = (64, 64)
        assert svc.book.step_count(hw, 1, "single_device",
                                   precision="bfp") == 1
        assert svc.book.step_count(hw, 1, "single_device") == 0
        # snapshot labels carry the precision only for non-f32
        keys = [k for k in svc.book.snapshot()
                if "step_count" in k and 'stage="step"' in k]
        assert keys and all('precision="bfp"' in k for k in keys)

    def test_invalid_precision_rejected(self):
        from repro.launch.serve import STDService

        with pytest.raises(ValueError, match="unknown precision"):
            STDService(width=0.125, precision="int8")

    def test_parity_gate_on_bucket_grid(self):
        """The acceptance gate: bfp maps within the accuracy budget of
        f32 (and provably quantized), boxes exactly equal under the
        0.5-threshold margin guard."""
        from benchmarks.serve_bench import precision_parity_gate
        from repro.runtime.executor import EngineFactory

        fac = EngineFactory(_std_model)
        for hw in ((64, 64), (64, 128)):
            x = jax.random.uniform(jax.random.PRNGKey(7),
                                   (1,) + hw + (3,))
            of = fac.model(hw, "f32").apply(fac.params(hw, "f32"), x)
            ob = fac.model(hw, "bfp").apply(fac.params(hw, "bfp"), x)
            d, boxes_equal = precision_parity_gate(
                of["score"], of["links"], ob["score"], ob["links"])
            assert 0.0 < d < 0.05, (hw, d)
            assert boxes_equal, hw

    def test_measured_cost_reads_per_precision_series(self):
        from repro.runtime.planner import (AnalyticCost, MeasuredCost,
                                           PlanFeatures)
        from repro.runtime.telemetry import CostBook

        book = CostBook(warmup=0)
        hw, feats = (64, 64), PlanFeatures(flops=1e9, halo_bytes=0.0)
        for _ in range(MeasuredCost.MIN_OBSERVATIONS):
            book.record_step(hw, 1, "single_device", 0.5,
                             precision="bfp")
        mc_f32 = MeasuredCost(book, AnalyticCost())
        mc_bfp = MeasuredCost(book, AnalyticCost(), precision="bfp")
        # the bfp overlay sees the measurement, the f32 one falls back
        assert mc_bfp.step_cost(feats, hw, "single_device", 1,
                                data_n=1, model_n=1) == 0.5
        assert mc_f32.step_cost(feats, hw, "single_device", 1,
                                data_n=1, model_n=1) != 0.5
