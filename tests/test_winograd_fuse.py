"""Winograd F(4x4,3x3) and fusion (BN fold, phase-decomposed upsample)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # bare interpreter: seeded fallback shim
    from _hypothesis_compat import given, settings, strategies as st
from jax import lax

from repro.core import fuse, winograd


def direct(x, w, padding="SAME"):
    return lax.conv_general_dilated(
        x, w, (1, 1), padding, dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


class TestWinograd:
    @settings(max_examples=20, deadline=None)
    @given(
        st.integers(0, 100),
        st.integers(4, 21),
        st.integers(4, 21),
        st.sampled_from([1, 3, 5]),
        st.sampled_from([1, 2, 7]),
        st.sampled_from(["SAME", "VALID"]),
    )
    def test_matches_direct_conv(self, seed, h, w, cin, cout, padding):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(k1, (2, h, w, cin))
        ker = jax.random.normal(k2, (3, 3, cin, cout))
        got = winograd.winograd_conv2d(x, ker, padding=padding)
        want = direct(x, ker, padding)
        np.testing.assert_allclose(got, want, atol=2e-3, rtol=1e-3)

    def test_transform_identity(self):
        """AT @ (BT X B pointwise GWG^T) A == conv for a single tile."""
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 6, 1))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 1, 1))
        got = winograd.winograd_conv2d(x, w, padding="VALID")
        want = direct(x, w, "VALID")
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_multiply_reduction_factor(self):
        c = winograd.multiply_count(64, 64, 128, 128)
        assert abs(c["mac_reduction"] - 4.0) < 0.01   # the paper's 4x


class TestBNFold:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 100))
    def test_fold_equivalence(self, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 7)
        x = jax.random.normal(ks[0], (2, 8, 8, 5))
        w = jax.random.normal(ks[1], (3, 3, 5, 7))
        b = jax.random.normal(ks[2], (7,))
        gamma = jax.random.normal(ks[3], (7,)) * 0.2 + 1.0
        beta = jax.random.normal(ks[4], (7,))
        mean = jax.random.normal(ks[5], (7,))
        var = jax.nn.softplus(jax.random.normal(ks[6], (7,))) + 0.1
        y_unfused = (direct(x, w) + b - mean) * gamma * lax.rsqrt(
            var + 1e-5) + beta
        wf, bf = fuse.fold_batchnorm(w, b, gamma, beta, mean, var)
        y_fused = direct(x, wf) + bf
        np.testing.assert_allclose(y_fused, y_unfused, atol=1e-4, rtol=1e-4)


class TestUpsampleFusion:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 100), st.integers(2, 12), st.integers(2, 12))
    def test_phase_decomposition_equivalence(self, seed, h, w):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(k1, (2, h, w, 3))
        ker = jax.random.normal(k2, (3, 3, 3, 4))
        naive = fuse.upsample2x_conv3x3_naive(x, ker)
        fused = fuse.upsample2x_conv3x3_fused(x, ker)
        np.testing.assert_allclose(fused, naive, atol=1e-5, rtol=1e-5)

    def test_75_percent_reduction(self):
        c = fuse.upsample_mac_counts(64, 64, 32, 32)
        assert abs(c["reduction"] - 0.75) < 1e-9      # exactly the paper

    def test_nearest_upsample(self):
        x = jnp.arange(4.0).reshape(1, 2, 2, 1)
        y = fuse.upsample_nearest_2x(x)
        assert y.shape == (1, 4, 4, 1)
        assert float(y[0, 0, 0, 0]) == float(y[0, 1, 1, 0]) == 0.0
        assert float(y[0, 2, 3, 0]) == float(x[0, 1, 1, 0])
