"""Async pipelined dispatch (paper §C4 module-level multithreading
applied to the engine): the MicroBatcher's dispatch/completion split,
the bounded in-flight queue, stats thread-safety, and async-vs-sync box
parity.

Fast tier — stub-engine semantics of the two-stage pipeline, a lost-
update hammer on the service stats, and end-to-end SingleDevice parity
(the async pipelined path must produce boxes identical to the plain
``detect`` path: same engines, same math, different threading).

Slow tier — subprocess 8-device (2x4 data x model) host mesh: GridPlan
async-vs-sync parity with the same 0.5-threshold guard as
tests/test_gridplan.py, and an in-flight stress run that holds the
pipeline at its bound.
"""
import os
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

from repro.launch.batching import MicroBatcher

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
TESTS = os.path.dirname(__file__)


def run_sub(body: str, timeout: int = 900) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, {SRC!r})
        sys.path.insert(0, {TESTS!r})
    """) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


class TestDispatchCompletionSplit:
    """Two-stage pipeline semantics on stub engines (no device work)."""

    def test_finalize_runs_once_per_batch_results_ordered(self):
        calls = {"infer": 0, "finalize": 0}

        def infer(key, payloads):
            calls["infer"] += 1
            return ("pending", payloads)         # un-materialized stand-in

        def finalize(key, raw):
            calls["finalize"] += 1
            tag, payloads = raw
            assert tag == "pending"
            return [p * 10 for p in payloads]

        with MicroBatcher(infer, finalize_fn=finalize, max_batch=2,
                          max_wait_ms=5, inflight=2) as mb:
            futs = [mb.submit("a", i) for i in range(6)]
            assert [f.result(timeout=10) for f in futs] == \
                [0, 10, 20, 30, 40, 50]
        assert calls == {"infer": 3, "finalize": 3}
        assert mb.stats["inflight_peak"] >= 1

    def test_finalize_error_propagates_to_the_batch(self):
        def finalize(key, raw):
            raise RuntimeError("D2H on fire")

        with MicroBatcher(lambda k, ps: ps, finalize_fn=finalize,
                          max_batch=2, max_wait_ms=5, inflight=1) as mb:
            fut = mb.submit("a", 1)
            with pytest.raises(RuntimeError, match="D2H on fire"):
                fut.result(timeout=10)

    def test_inflight_zero_is_the_synchronous_path(self):
        """inflight=0 collapses completion into the dispatch thread: no
        mb-complete thread exists, and dispatch never runs ahead."""
        order = []

        def infer(key, payloads):
            order.append(("dispatch", threading.current_thread().name))
            return payloads

        def finalize(key, raw):
            order.append(("complete", threading.current_thread().name))
            return raw

        with MicroBatcher(infer, finalize_fn=finalize, max_batch=1,
                          max_wait_ms=5, inflight=0) as mb:
            assert mb._complete_t is None
            futs = [mb.submit("a", i) for i in range(3)]
            assert [f.result(timeout=10) for f in futs] == [0, 1, 2]
        # strict alternation: dispatch i+1 never starts before
        # completion i finishes, and both run on the dispatch thread
        assert [kind for kind, _ in order] == \
            ["dispatch", "complete"] * 3
        assert {name for _, name in order} == {"mb-dispatch"}
        assert mb.stats["inflight_peak"] == 1

    def test_bounded_inflight_queue_limits_dispatch_runahead(self):
        """With completion gated, dispatch may run at most
        1 (finalizing) + inflight (queued) + 1 (blocked on the handoff)
        batches ahead — the in-flight bound that caps device memory."""
        inflight = 2
        gate = threading.Event()
        dispatched = threading.Semaphore(0)

        def infer(key, payloads):
            dispatched.release()
            return payloads

        def finalize(key, raw):
            gate.wait(10)
            return raw

        mb = MicroBatcher(infer, finalize_fn=finalize, max_batch=1,
                          max_wait_ms=1, inflight=inflight).start()
        try:
            futs = [mb.submit("a", i) for i in range(8)]
            for _ in range(inflight + 2):        # the allowed run-ahead
                assert dispatched.acquire(timeout=5)
            # the bound: no further batch may dispatch while completion
            # is blocked (event-driven check, the 0.2 s is an upper
            # bound on the negative, not a sleep the test relies on)
            assert not dispatched.acquire(timeout=0.2), \
                "dispatch overran the in-flight bound"
        finally:
            gate.set()
            mb.stop()
        assert [f.result(timeout=10) for f in futs] == list(range(8))
        assert mb.stats["inflight_peak"] <= inflight + 2

    def test_stage_stats_recorded(self):
        with MicroBatcher(lambda k, ps: ps,
                          finalize_fn=lambda k, r: r,
                          max_batch=2, max_wait_ms=5, inflight=1) as mb:
            futs = [mb.submit("a", i) for i in range(4)]
            [f.result(timeout=10) for f in futs]
        occ = mb.stats["stage_occupancy"]
        assert set(occ) == {"dispatch", "complete", "post"}
        assert all(0.0 <= v for v in occ.values())
        assert mb.stats["dispatch_busy_s"] >= 0.0
        assert mb.stats["complete_busy_s"] >= 0.0
        assert 1 <= mb.stats["inflight_peak"] <= 3


class TestStatsThreadSafety:
    """Counters are read-modify-write: without a lock the GIL alone
    loses updates under thread preemption.  Hammer from many threads
    with a tiny switch interval and assert nothing is lost."""

    N_THREADS = 16
    PER_THREAD = 500

    def test_service_stats_no_lost_updates(self):
        from repro.launch.serve import STDService

        svc = STDService(width=0.125, buckets=(64,), max_batch=2)
        old = sys.getswitchinterval()
        sys.setswitchinterval(1e-5)
        try:
            ts = [threading.Thread(
                target=lambda: [svc._record_request(1e-6)
                                for _ in range(self.PER_THREAD)])
                for _ in range(self.N_THREADS)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        finally:
            sys.setswitchinterval(old)
        total = self.N_THREADS * self.PER_THREAD
        assert svc.stats["n"] == total, "lost n updates"
        assert len(svc.stats["latency_s"]) == total, "lost latency samples"

    def test_batcher_stats_no_lost_updates(self):
        """submitted/rejected counters mutated from concurrent
        submitters must account every attempt exactly once."""
        from repro.launch.batching import QueueFull

        gate = threading.Event()

        def infer(key, payloads):
            gate.wait(5)
            return payloads

        mb = MicroBatcher(infer, max_batch=4, max_wait_ms=1.0,
                          max_pending=8, admission="reject").start()
        attempts = self.N_THREADS * 50
        shed = [0] * self.N_THREADS
        futs = [[] for _ in range(self.N_THREADS)]

        def producer(i):
            for _ in range(50):
                try:
                    futs[i].append(mb.submit("b", i))
                except QueueFull:
                    shed[i] += 1

        old = sys.getswitchinterval()
        sys.setswitchinterval(1e-5)
        try:
            ts = [threading.Thread(target=producer, args=(i,))
                  for i in range(self.N_THREADS)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        finally:
            sys.setswitchinterval(old)
            gate.set()
            mb.stop()
        n_ok = sum(len(f) for f in futs)
        assert n_ok + sum(shed) == attempts
        assert mb.stats["submitted"] == n_ok, "lost submitted updates"
        assert mb.stats["rejected"] == sum(shed), "lost rejected updates"


class TestAsyncSyncParitySingleDevice:
    def test_pipelined_async_boxes_match_detect(self):
        """The acceptance parity on one device: identical boxes from the
        async pipelined path (inflight=2) and the plain detect path on
        the same image set — same engines, same math, so equality is
        exact (no threshold guard needed on a single plan)."""
        from repro.data.images import RequestStream
        from repro.launch.serve import STDService

        images = RequestStream(
            6, seed=3, hw_range=((48, 64), (48, 64))
        ).images()
        svc = STDService(width=0.125, buckets=(64,), max_batch=4,
                         max_wait_ms=20, inflight=2)
        key = lambda rs: [[b["box"] for b in r] for r in rs]
        sync = key([svc(img) for img in images])
        got = key(svc.serve_batched(images))
        assert got == sync
        b = svc.stats["batching"]
        assert b["inflight_peak"] >= 1
        assert set(b["stage_occupancy"]) == {"dispatch", "complete", "post"}

    def test_sync_and_async_schedulers_agree(self):
        """inflight=0 (serialized) and inflight=2 (pipelined) schedulers
        produce identical boxes through the same service."""
        from repro.data.images import RequestStream
        from repro.launch.serve import STDService

        images = RequestStream(
            4, seed=11, hw_range=((48, 64), (48, 64))
        ).images()
        key = lambda rs: [[b["box"] for b in r] for r in rs]
        svc = STDService(width=0.125, buckets=(64,), max_batch=4,
                         max_wait_ms=20, inflight=0)
        sync_sched = key(svc.serve_batched(images))
        svc.inflight = 2                 # next start_batched picks it up
        async_sched = key(svc.serve_batched(images))
        assert async_sched == sync_sched


@pytest.mark.slow
class TestAsyncGridParity:
    def test_gridplan_async_matches_sync_on_8_devices(self):
        """GridPlan on a 2x4 mesh: async pipelined boxes == sync detect
        boxes (same engine, exact), and both match the SingleDevice
        reference under the 0.5-threshold guard (cross-plan compare:
        Winograd tile regrouping can shift scores ~1e-6, so skip the
        cross-plan assertion when any score/link sits that close to the
        threshold — same guard as tests/test_gridplan.py)."""
        out = run_sub("""
            import jax
            import jax.numpy as jnp
            import numpy as np
            from repro.data.images import RequestStream
            from repro.launch.mesh import make_mesh
            from repro.launch.serve import STDService
            from repro.runtime.executor import GridPlan

            mesh = make_mesh((2, 4), ("data", "model"))
            kw = dict(width=0.125, buckets=(128,), max_batch=4)
            key = lambda rs: [[b["box"] for b in r] for r in rs]
            images = RequestStream(
                6, seed=3, hw_range=((48, 96), (48, 96))).images()

            base = STDService(**kw)
            want = key([base(img) for img in images])

            svc = STDService(**kw, plan=GridPlan(mesh), inflight=2)
            sync_grid = key([svc(img) for img in images])
            async_grid = key(svc.serve_batched(images))
            # same plan, same engine: async threading must not change
            # a single box
            assert async_grid == sync_grid, "async diverged from sync"

            # cross-plan (grid vs single-device) under the threshold
            # guard used by the gridplan property suite
            model = base.factory.model((128, 128))
            params = base.factory.params((128, 128))
            fwd = jax.jit(lambda p, x: model.apply(p, x))
            gap = float("inf")
            for img in images:
                x, _, _ = base.preprocess(img)
                o = fwd(params, jnp.asarray(x[None]))
                gap = min(gap, float(jnp.minimum(
                    jnp.min(jnp.abs(o["score"] - 0.5)),
                    jnp.min(jnp.abs(o["links"] - 0.5)))))
            if gap < 1e-6:
                print(f"ASYNC_GRID_GUARD_SKIP gap={gap}")
            else:
                assert async_grid == want, "grid diverged from reference"
                print("ASYNC_GRID_PARITY_OK")
            b = svc.stats["batching"]
            assert b["inflight_peak"] >= 1
            print("peak", b["inflight_peak"],
                  "occ", b["stage_occupancy"])
        """)
        assert "ASYNC_GRID_PARITY_OK" in out or \
            "ASYNC_GRID_GUARD_SKIP" in out

    def test_inflight_stress_on_8_devices(self):
        """Hold the async pipeline at its bound on the mesh: concurrent
        producers through a GridPlan service with inflight=3 and a
        bounded admission queue — every future resolves, the in-flight
        peak respects the bound, and the accounting is exact."""
        out = run_sub("""
            import threading
            import numpy as np
            from concurrent.futures import ThreadPoolExecutor
            from repro.data.images import RequestStream
            from repro.launch.mesh import make_mesh
            from repro.launch.serve import STDService
            from repro.runtime.executor import GridPlan

            mesh = make_mesh((2, 4), ("data", "model"))
            svc = STDService(width=0.125, buckets=(128,), max_batch=4,
                             max_wait_ms=4.0, plan=GridPlan(mesh),
                             inflight=3, max_pending=16,
                             admission="block")
            images = RequestStream(
                32, seed=5, hw_range=((48, 96), (48, 96))).images()
            # warm the engines the scheduler can form (compile once)
            svc.serve_batched(images[:8])

            svc.start_batched()
            try:
                with ThreadPoolExecutor(8) as ex:
                    futs = list(ex.map(svc.submit, images))
                results = [f.result(timeout=600) for f in futs]
            finally:
                svc.stop_batched()
            assert len(results) == 32
            b = svc.stats["batching"]
            assert b["submitted"] == 32
            assert b["rejected"] == 0
            assert 1 <= b["inflight_peak"] <= 3 + 2, b["inflight_peak"]
            assert b["pending_peak"] <= 16
            # sanity: the async path agrees with plain detect
            want = [[x["box"] for x in svc(images[0])]]
            got = [[x["box"] for x in results[0]]]
            assert got == want, "stressed async diverged from detect"
            print("ASYNC_STRESS_OK peak", b["inflight_peak"])
        """)
        assert "ASYNC_STRESS_OK" in out
