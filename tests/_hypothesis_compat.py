"""Seeded stand-in for `hypothesis` so the property tests collect and run
on a bare interpreter (no pip installs in this environment).

Semantics: ``@given(*strategies)`` replays ``max_examples`` examples drawn
from a deterministic RNG seeded by the test's qualified name — no
shrinking, no database, but the same example stream on every run, so a
failure reproduces exactly.  Only the strategy surface this repo's tests
use is provided: ``integers``, ``sampled_from``, ``booleans``, ``builds``.

``HYPOTHESIS_COMPAT_MAX_EXAMPLES`` (env) caps the per-test example count
for quick local iterations.
"""
from __future__ import annotations

import functools
import os
import zlib
from typing import Any, Callable

import numpy as np

_ENV_CAP = int(os.environ.get("HYPOTHESIS_COMPAT_MAX_EXAMPLES", "0"))
_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self._draw = draw

    def example(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(options) -> _Strategy:
    opts = list(options)
    return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(2)))


def builds(target: Callable, **kwargs: _Strategy) -> _Strategy:
    # sorted draw order keeps the example stream independent of kwargs
    # insertion order
    def draw(rng):
        return target(**{k: kwargs[k].example(rng) for k in sorted(kwargs)})

    return _Strategy(draw)


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_compat_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            if _ENV_CAP > 0:
                n = min(n, _ENV_CAP)
            seed = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = np.random.default_rng(
                    np.random.SeedSequence([seed, i])
                )
                drawn = [s.example(rng) for s in strats]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i}: {fn.__qualname__}"
                        f"({', '.join(map(repr, drawn))})"
                    ) from e

        # pytest must not see the original signature, else it treats the
        # drawn parameters as fixtures
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper._compat_given = True
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    """Applied above @given: records max_examples on the given-wrapper."""

    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn

    return deco


class _StrategiesNamespace:
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)
    builds = staticmethod(builds)


# `from _hypothesis_compat import strategies as st`
strategies = _StrategiesNamespace()
