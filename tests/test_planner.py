"""Cost-model plan router tests (runtime/planner.py) — fast tier.

The routing properties pinned here are what serving correctness and the
ISSUE acceptance rely on, independent of the napkin constants:

  * monotonicity — a taller image never moves from a row-banded plan
    back to SingleDevice (compute grows with H, halo bytes do not);
  * the band-height invariant ``H % (bands * deepest_stride) == 0``
    gates RowBand/GridPlan eligibility (the executor enforces the same
    rule at compile time);
  * over-tall (and transposed over-wide, which becomes over-tall before
    routing) shapes land on a row-banded plan whenever the mesh has
    model-axis capacity (``force_banded``);
  * batch-split occupancy — padding a batch of 1 across a data axis
    never looks cheaper than a single device.

Feature extraction (core.rowband.program_band_costs) is checked against
the real assembled PixelLink program.
"""
import numpy as np
import pytest

from repro.runtime.planner import (
    PLAN_KINDS,
    CostParams,
    PlanFeatures,
    Planner,
    choose_kind,
    eligible_kinds,
    features_for_program,
    padded_batch,
    step_cost,
)

# crossover-friendly constants: tiny-model FLOPs still register against
# the overheads, so routing decisions move within the swept ranges
TEST_PARAMS = CostParams(
    peak_flops=5e9, ici_bw=1e9,
    dispatch_overhead_s=50e-6, collective_overhead_s=20e-6,
)


def tall_features(h: int, w: int = 64) -> PlanFeatures:
    """Synthetic features of an FCN plane: compute scales with the
    plane, halo bytes scale with W only (boundary rows)."""
    return PlanFeatures(flops=2e5 * h * w / 64.0, halo_bytes=3e4 * w / 64.0,
                        deepest_stride=32)


class TestStepCost:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown plan kind"):
            step_cost(tall_features(64), "mystery", 1)

    def test_occupancy_batch_one_never_prefers_data_parallel(self):
        """A batch of 1 on a 4-wide data axis pads to 4: full
        single-device compute per device plus sharding overhead."""
        f = tall_features(512)
        single = step_cost(f, "single_device", 1, data_n=4, model_n=1,
                           params=TEST_PARAMS)
        dp = step_cost(f, "data_parallel", 1, data_n=4, model_n=1,
                       params=TEST_PARAMS)
        assert dp > single

    def test_data_parallel_wins_at_full_batch(self):
        f = tall_features(512)
        single = step_cost(f, "single_device", 8, data_n=4, model_n=1,
                           params=TEST_PARAMS)
        dp = step_cost(f, "data_parallel", 8, data_n=4, model_n=1,
                       params=TEST_PARAMS)
        assert dp < single

    def test_grid_splits_both_axes(self):
        """At full batch on a tall plane the grid cost sits below both
        single-axis plans (compute divided by data_n x model_n)."""
        f = tall_features(1024)
        kw = dict(data_n=2, model_n=4, params=TEST_PARAMS)
        grid = step_cost(f, "grid", 8, **kw)
        assert grid < step_cost(f, "row_band", 8, **kw)
        assert grid < step_cost(f, "data_parallel", 8, **kw)
        assert grid < step_cost(f, "single_device", 8, **kw)

    def test_halo_layer_launches_penalize_banded_plans_only(self):
        """Every halo-exchanging layer is a ppermute pair per step; the
        launch cost lands on row-banded kinds and leaves single-device /
        data-parallel costs untouched."""
        base = tall_features(512)
        many = PlanFeatures(flops=base.flops, halo_bytes=base.halo_bytes,
                            deepest_stride=32, halo_layers=30)
        kw = dict(data_n=2, model_n=4, params=TEST_PARAMS)
        for kind in ("single_device", "data_parallel"):
            assert step_cost(many, kind, 1, **kw) == \
                step_cost(base, kind, 1, **kw)
        for kind in ("row_band", "grid"):
            assert step_cost(many, kind, 1, **kw) == pytest.approx(
                step_cost(base, kind, 1, **kw)
                + 30 * TEST_PARAMS.halo_launch_s)

    def test_padded_batch(self):
        assert padded_batch(1, 4) == 4
        assert padded_batch(4, 4) == 4
        assert padded_batch(5, 4) == 8
        assert padded_batch(3, 1) == 3


class TestEligibility:
    def test_band_height_invariant_gates_banded_kinds(self):
        kw = dict(data_n=2, model_n=4, deepest_stride=32)
        # 4 bands x stride 32 -> H must be a multiple of 128
        assert "row_band" not in eligible_kinds((64, 64), **kw)
        assert "grid" not in eligible_kinds((192, 64), **kw)
        assert set(eligible_kinds((256, 64), **kw)) == {
            "single_device", "data_parallel", "row_band", "grid"}

    def test_unit_mesh_is_single_device_only(self):
        assert eligible_kinds((256, 64), data_n=1, model_n=1,
                              deepest_stride=32) == ["single_device"]

    def test_no_data_axis_no_batch_kinds(self):
        kinds = eligible_kinds((256, 64), data_n=1, model_n=4,
                               deepest_stride=32)
        assert kinds == ["single_device", "row_band"]


class TestRouting:
    def test_taller_never_moves_back_to_single_device(self):
        """Monotonicity: sweeping H upward, once routing leaves
        SingleDevice for a row-banded plan it never returns."""
        kw = dict(data_n=2, model_n=4, params=TEST_PARAMS)
        banded_seen = False
        kinds = []
        for h in range(128, 4097, 128):
            k = choose_kind(tall_features(h), (h, 64), 1, **kw)
            kinds.append(k)
            if k in ("row_band", "grid"):
                banded_seen = True
            elif banded_seen:
                raise AssertionError(
                    f"H={h} moved back to {k} after banding: {kinds}")
        assert banded_seen, f"crossover never happened: {kinds}"

    def test_small_plane_stays_single_device(self):
        k = choose_kind(tall_features(64), (64, 64), 1, data_n=2,
                        model_n=4, params=TEST_PARAMS)
        assert k == "single_device"

    def test_force_banded_lands_on_row_banded_plan(self):
        """The over-tall / transposed-over-wide rule: even where a small
        plan is cheaper, oversize shapes must ride a banded plan."""
        f = tall_features(256)
        k = choose_kind(f, (256, 64), 1, data_n=2, model_n=4,
                        params=TEST_PARAMS, force_banded=True)
        assert k in ("row_band", "grid")
        # with batch depth the grid becomes the banded winner
        k8 = choose_kind(tall_features(2048), (2048, 64), 8, data_n=2,
                         model_n=4, params=TEST_PARAMS, force_banded=True)
        assert k8 == "grid"

    def test_force_banded_falls_back_without_capacity(self):
        k = choose_kind(tall_features(256), (256, 64), 1, data_n=1,
                        model_n=1, params=TEST_PARAMS, force_banded=True)
        assert k == "single_device"

    def test_batch_moves_routing_toward_data_parallel(self):
        f = tall_features(320)
        kw = dict(data_n=4, model_n=1, params=TEST_PARAMS)
        assert choose_kind(f, (320, 64), 1, **kw) == "single_device"
        assert choose_kind(f, (320, 64), 8, **kw) == "data_parallel"


class TestGoldenRouting:
    """Frozen Planner.choose decisions over a canonical grid of
    (bucket, batch, mesh-shape) inputs.  The monotonicity properties
    above survive many cost-model edits; this table does not — any
    change to CostParams defaults, the step-cost formula, or
    eligibility that silently flips a routing decision fails HERE with
    the exact input named.  If a flip is intentional, run
    ``python scripts/regen_golden_routing.py`` — it recomputes every
    row (choose_kind with tall_features + TEST_PARAMS) and rewrites the
    marked block below, so the golden updates in the same commit that
    changes the model."""

    # (hw, batch, (data_n, model_n)) -> expected plan kind, generated
    # from choose_kind(tall_features(*hw), hw, batch, ...) at
    # TEST_PARAMS.  Rows group by mesh: unit mesh, data-only 4x1,
    # model-only 1x4, and the 2x4 grid mesh.
    # GOLDEN-BEGIN (generated: scripts/regen_golden_routing.py)
    GOLDEN = {
        # unit mesh: nothing to shard over
        ((64, 64), 1, (1, 1)): "single_device",
        ((512, 64), 8, (1, 1)): "single_device",
        ((2048, 64), 8, (1, 1)): "single_device",
        # data-only mesh: batch depth decides, height never bands
        ((64, 64), 1, (4, 1)): "single_device",
        ((64, 64), 4, (4, 1)): "data_parallel",
        ((64, 64), 8, (4, 1)): "data_parallel",
        ((256, 64), 1, (4, 1)): "single_device",
        ((256, 64), 4, (4, 1)): "data_parallel",
        ((512, 64), 1, (4, 1)): "single_device",
        ((512, 64), 8, (4, 1)): "data_parallel",
        ((1024, 128), 1, (4, 1)): "single_device",
        ((1024, 128), 4, (4, 1)): "data_parallel",
        ((2048, 64), 1, (4, 1)): "single_device",
        ((2048, 64), 8, (4, 1)): "data_parallel",
        # model-only mesh: the height crossover (64 -> 128 at W=64/128
        # with TEST_PARAMS), band-height invariant already satisfied
        ((64, 64), 1, (1, 4)): "single_device",
        ((64, 64), 8, (1, 4)): "single_device",
        ((128, 128), 1, (1, 4)): "row_band",
        ((128, 128), 8, (1, 4)): "row_band",
        ((256, 64), 1, (1, 4)): "row_band",
        ((512, 64), 4, (1, 4)): "row_band",
        ((1024, 128), 8, (1, 4)): "row_band",
        ((2048, 64), 1, (1, 4)): "row_band",
        # 2x4 grid mesh: small planes stay single/data-parallel by
        # batch depth; tall planes band at batch 1 and take the
        # composed grid once the batch is deep enough to split too
        ((64, 64), 1, (2, 4)): "single_device",
        ((64, 64), 4, (2, 4)): "data_parallel",
        ((64, 64), 8, (2, 4)): "data_parallel",
        ((128, 128), 1, (2, 4)): "row_band",
        ((128, 128), 4, (2, 4)): "grid",
        ((256, 64), 1, (2, 4)): "row_band",
        ((256, 64), 8, (2, 4)): "grid",
        ((512, 64), 1, (2, 4)): "row_band",
        ((512, 64), 4, (2, 4)): "grid",
        ((1024, 128), 1, (2, 4)): "row_band",
        ((1024, 128), 8, (2, 4)): "grid",
        ((2048, 64), 1, (2, 4)): "row_band",
        ((2048, 64), 8, (2, 4)): "grid",
    }
    # GOLDEN-END

    def test_golden_table(self):
        flips = []
        for (hw, batch, (dn, mn)), want in self.GOLDEN.items():
            got = choose_kind(tall_features(*hw), hw, batch,
                              data_n=dn, model_n=mn, params=TEST_PARAMS)
            if got != want:
                flips.append(
                    f"hw={hw} batch={batch} mesh=({dn},{mn}): "
                    f"{want} -> {got}")
        assert not flips, (
            "cost-model edit flipped routing decisions (update the "
            "golden table if intentional):\n" + "\n".join(flips))

    def test_golden_covers_every_kind(self):
        """The grid must keep exercising all four plan kinds — a table
        that collapses to one kind no longer pins the crossovers."""
        assert set(self.GOLDEN.values()) == set(PLAN_KINDS)


class TestProgramFeatures:
    @pytest.fixture(scope="class")
    def model_at(self):
        from repro.models.fcn.pixellink import PixelLinkModel, STDConfig

        def make(hw):
            return PixelLinkModel(STDConfig(
                backbone="vgg16", width=0.125, image_size=hw,
                merge_ch=(16, 16, 8), mode="optimized",
                storage_fp16=False))

        return make

    def test_band_costs_from_real_program(self, model_at):
        from repro.core.rowband import program_band_costs

        c = program_band_costs(model_at((64, 64)).program)
        assert c["flops"] > 0 and c["halo_bytes"] > 0
        assert c["halo_layers"] > 0

    def test_flops_scale_with_height_halo_does_not(self, model_at):
        from repro.core.rowband import program_band_costs

        c1 = program_band_costs(model_at((64, 64)).program)
        c2 = program_band_costs(model_at((128, 64)).program)
        assert c2["flops"] == pytest.approx(2 * c1["flops"], rel=0.05)
        # halo rows are boundary rows: W-dependent, H-independent
        assert c2["halo_bytes"] == c1["halo_bytes"]

    def test_features_for_program(self, model_at):
        f = features_for_program(model_at((64, 64)).program, 32)
        assert isinstance(f, PlanFeatures)
        assert f.deepest_stride == 32 and f.flops > 0


class TestPlanner:
    @pytest.fixture()
    def unit_mesh(self):
        from repro.launch.mesh import make_host_mesh

        return make_host_mesh((1, 1), ("data", "model"))

    def test_features_memoized(self, unit_mesh):
        calls = []

        def feats(hw):
            calls.append(hw)
            return tall_features(hw[0], hw[1])

        p = Planner(unit_mesh, feats)
        p.choose((64, 64), 1)
        p.choose((64, 64), 4)
        assert calls == [(64, 64)]

    def test_unbound_features_raise(self, unit_mesh):
        with pytest.raises(RuntimeError, match="features_fn"):
            Planner(unit_mesh).choose((64, 64), 1)

    def test_bind_features_is_idempotent(self, unit_mesh):
        from repro.runtime.executor import DEFAULT_MODEL

        first = lambda hw: tall_features(hw[0], hw[1])
        p = Planner(unit_mesh, first)
        p.bind_features(lambda hw: (_ for _ in ()).throw(AssertionError))
        assert p._features_fns[DEFAULT_MODEL] is first
        # per-model: another model's source binds alongside, first wins
        other = lambda hw: tall_features(hw[0], hw[1])
        p.bind_features(other, model="east")
        p.bind_features(lambda hw: (_ for _ in ()).throw(AssertionError),
                        model="east")
        assert p._features_fns["east"] is other

    def test_plan_for_kind_mapping(self, unit_mesh):
        from repro.runtime.executor import (DataParallel, GridPlan,
                                            RowBand, SingleDevice)

        p = Planner(unit_mesh)
        assert isinstance(p.plan_for_kind("single_device"), SingleDevice)
        assert isinstance(p.plan_for_kind("data_parallel"), DataParallel)
        assert isinstance(p.plan_for_kind("row_band"), RowBand)
        assert isinstance(p.plan_for_kind("grid"), GridPlan)
        with pytest.raises(ValueError, match="unknown plan kind"):
            p.plan_for_kind("pod")

    def test_height_unit(self, unit_mesh):
        assert Planner(unit_mesh).height_unit(32) == 32

    def test_costs_table_only_eligible_kinds(self, unit_mesh):
        p = Planner(unit_mesh, lambda hw: tall_features(hw[0], hw[1]))
        assert set(p.costs((256, 64), 4)) == {"single_device"}

    def test_service_with_unit_planner_serves_over_tall(self, unit_mesh):
        """End to end on one device: a planner-routed service clamps and
        serves an over-tall image exactly like the base service (no
        banded capacity on a unit mesh -> single-device fallback)."""
        from repro.launch.serve import STDService

        svc = STDService(width=0.125, buckets=(64,), max_batch=2,
                         planner=Planner(unit_mesh))
        img = np.random.default_rng(0).random(
            (100, 48, 3)).astype(np.float32)
        boxes = svc(img)
        assert svc.stats["plan_choices"][(128, 64)] == "single_device"
        ref = STDService(width=0.125, buckets=(64,), max_batch=2)
        assert [b["box"] for b in boxes] == [b["box"] for b in ref(img)]
