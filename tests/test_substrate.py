"""Substrate tests: optimizer convergence across moment dtypes, EF
compression conservation, checkpoint atomicity/retention/bitwise restore,
deterministic data, fault-tolerant runner (crash -> bit-exact resume),
watchdog straggler detection, preemption guard."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.data import Prefetcher, TokenDataset
from repro.optim import adamw, sgd_momentum, cosine_with_warmup
from repro.optim.grad_utils import (
    GradAccumulator, clip_by_global_norm, error_feedback_compress,
    global_norm, init_residual,
)
from repro.runtime.fault_tolerance import (
    PreemptionGuard, TrainRunner, Watchdog,
)


class TestOptim:
    @pytest.mark.parametrize("md", ["float32", "bfloat16", "bfp8"])
    def test_adamw_converges(self, md):
        target = jnp.asarray(
            np.random.default_rng(0).normal(size=(4, 32)), jnp.float32
        )
        init, update = adamw(1e-1, moment_dtype=md, weight_decay=0.0)
        params = {"w": jnp.zeros((4, 32))}
        st = init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.mean((p["w"] - target) ** 2))(params)
            params, st = update(g, st, params)
        assert float(jnp.max(jnp.abs(params["w"] - target))) < 0.06

    def test_sgd_momentum_converges(self):
        target = jnp.ones((8,)) * 3
        init, update = sgd_momentum(5e-2)
        params = {"w": jnp.zeros((8,))}
        st = init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.mean((p["w"] - target) ** 2))(params)
            params, st = update(g, st, params)
        assert float(jnp.max(jnp.abs(params["w"] - target))) < 1e-2

    def test_bfp8_moment_memory_model(self):
        """bfp8 mu is ~1 byte/param + exponents; nu bf16 (see optimizers.py
        for the measured nu-divergence negative result)."""
        from repro.core.bfp import BFPTensor

        init, _ = adamw(1e-3, moment_dtype="bfp8")
        params = {"w": jnp.zeros((64, 512))}
        st = init(params)
        assert isinstance(st.mu["w"], BFPTensor)
        assert st.mu["w"].mantissa.dtype == jnp.int32  # stored repr
        assert st.mu["w"].nbytes_model() == 64 * 512 + 64 * 16
        assert st.nu["w"].dtype == jnp.bfloat16

    def test_schedule_shapes(self):
        f = cosine_with_warmup(1e-3, 10, 100)
        lrs = [float(f(jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
        assert lrs[0] == 0.0
        assert abs(lrs[2] - 1e-3) < 1e-9
        assert lrs[3] < lrs[2]
        assert abs(lrs[4] - 1e-4) < 1e-6

    def test_clip_by_global_norm(self):
        g = {"a": jnp.ones((10,)) * 10}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
        assert float(norm) > 30

    def test_grad_accumulation_equivalence(self):
        def loss(p, b):
            return jnp.mean((p["w"] * b["x"] - b["y"]) ** 2)

        p = {"w": jnp.asarray(2.0)}
        batch = {
            "x": jax.random.normal(jax.random.PRNGKey(0), (8, 4)),
            "y": jax.random.normal(jax.random.PRNGKey(1), (8, 4)),
        }
        l1, g1 = jax.value_and_grad(loss)(p, batch)
        l2, g2 = GradAccumulator(4)(loss, p, batch)
        assert abs(float(l1) - float(l2)) < 1e-6
        assert abs(float(g1["w"]) - float(g2["w"])) < 1e-6


class TestErrorFeedback:
    def test_conservation(self):
        """sum(compressed) + residual == sum(raw) exactly-ish: EF never
        loses gradient mass."""
        r = init_residual({"w": jnp.zeros((8, 64))})
        tot_q = jnp.zeros((8, 64))
        tot_g = jnp.zeros((8, 64))
        for i in range(30):
            gi = {"w": jax.random.normal(jax.random.PRNGKey(i), (8, 64))}
            q, r = error_feedback_compress(gi, r, mantissa_bits=4)
            tot_q += q["w"]
            tot_g += gi["w"]
        assert float(jnp.max(jnp.abs(tot_q + r["w"] - tot_g))) < 1e-3

    def test_compression_error_shrinks_with_bits(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 128))}
        errs = []
        for mb in (3, 7, 12):
            q, _ = error_feedback_compress(
                g, init_residual(g), mantissa_bits=mb
            )
            errs.append(float(jnp.mean(jnp.abs(q["w"] - g["w"]))))
        assert errs == sorted(errs, reverse=True)


class TestCheckpoint:
    def test_orphaned_tmp_dirs_pruned_on_init(self):
        """A crashed writer's uniquely-suffixed staging dir must be
        reclaimed by the next manager, not live forever."""
        with tempfile.TemporaryDirectory() as d:
            orphan = os.path.join(d, "step_5.tmp-999-0")
            os.makedirs(orphan)
            save_checkpoint(d, 7, {"w": jnp.ones((2,))}, blocking=True)
            mgr = CheckpointManager(d)
            assert not os.path.exists(orphan)
            assert mgr.steps() == [7]           # real checkpoints survive

    def _tree(self):
        init, update = adamw(1e-2, moment_dtype="bfp8")
        params = {"a": jnp.arange(12.0).reshape(3, 4).astype(jnp.bfloat16),
                  "b": {"c": jnp.ones((5,))}}
        st = init(params)
        g = jax.tree_util.tree_map(
            lambda x: jnp.ones(x.shape, jnp.float32), params
        )
        params, st = update(g, st, params)
        return {"params": params, "opt": st}

    def test_roundtrip_bitwise(self):
        tree = self._tree()
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 7, tree, blocking=True)
            got = restore_checkpoint(d, 7, tree)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(tree)):
                assert a.dtype == b.dtype
                assert bool(jnp.all(a == b))

    def test_retention_and_latest(self):
        tree = self._tree()
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, keep=2)
            for s in (1, 2, 3, 4):
                cm.save(s, tree, blocking=True)
            assert cm.steps() == [3, 4]
            assert cm.latest_step() == 4

    def test_async_save(self):
        tree = self._tree()
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, keep=3)
            cm.save(1, tree, blocking=False)
            cm.wait()
            assert cm.latest_step() == 1

    def test_crash_during_save_leaves_no_corrupt_latest(self):
        """A .tmp dir (simulated mid-crash) must not be visible as a step."""
        tree = self._tree()
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            cm.save(1, tree, blocking=True)
            os.makedirs(os.path.join(d, "step_2.tmp"))
            assert cm.latest_step() == 1

    def test_shape_mismatch_rejected(self):
        tree = self._tree()
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, tree, blocking=True)
            bad = jax.tree_util.tree_map(
                lambda x: jnp.zeros((9, 9), x.dtype), tree
            )
            with pytest.raises(ValueError):
                restore_checkpoint(d, 1, bad)


class TestData:
    def test_deterministic_per_step(self):
        ds = TokenDataset(100, 32, 8, seed=3)
        a, b = ds.batch(17), ds.batch(17)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = ds.batch(18)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_host_sharding_disjoint(self):
        d0 = TokenDataset(100, 16, 8, seed=1, n_hosts=2, host_id=0)
        d1 = TokenDataset(100, 16, 8, seed=1, n_hosts=2, host_id=1)
        assert d0.local_batch == 4
        assert not np.array_equal(d0.batch(0)["tokens"],
                                  d1.batch(0)["tokens"])

    def test_labels_are_shifted_tokens(self):
        ds = TokenDataset(100, 16, 4, seed=0)
        b = ds.batch(0)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
        assert (b["labels"][:, -1] == -1).all()

    def test_prefetcher_yields_all(self):
        ds = TokenDataset(50, 8, 2, seed=0)
        it = (ds.batch(i) for i in range(5))
        got = list(Prefetcher(it))
        assert len(got) == 5
        np.testing.assert_array_equal(
            np.asarray(got[3]["tokens"]), ds.batch(3)["tokens"]
        )


class TestFaultTolerance:
    @staticmethod
    def _step_fn(state, batch):
        p, s = state
        g = jax.grad(lambda w: jnp.mean((w - batch) ** 2))(p)
        return (p - 0.1 * g, s + 1), {"loss": jnp.mean((p - batch) ** 2)}

    @staticmethod
    def _batch_fn(step):
        return jnp.asarray(
            np.random.default_rng(step).normal(size=(4,)), jnp.float32
        )

    def test_crash_resume_bit_exact(self):
        state0 = (jnp.zeros((4,)), jnp.zeros((), jnp.int32))
        with tempfile.TemporaryDirectory() as d:
            r = TrainRunner(self._step_fn, self._batch_fn,
                            CheckpointManager(d), ckpt_every=5)
            with pytest.raises(RuntimeError):
                r.run(state0, 0, 20, fail_at=13)
            r2 = TrainRunner(self._step_fn, self._batch_fn,
                             CheckpointManager(d), ckpt_every=5)
            start, state = r2.resume_or_init(state0)
            assert start == 10
            _, resumed, status = r2.run(state, start, 20 - start)
            assert status == "done"
            direct = state0
            for i in range(20):
                direct, _ = self._step_fn(direct, self._batch_fn(i))
            assert bool(jnp.all(resumed[0] == direct[0]))

    def test_watchdog_flags_straggler(self):
        wd = Watchdog(threshold=3.0, warmup_steps=1)
        for i in range(10):
            assert not wd.observe(i, 0.1)
        assert wd.observe(99, 1.0)                  # 10x the EMA
        assert wd.incidents[-1]["step"] == 99

    def test_watchdog_transient_spike_leaves_ema_untouched(self):
        """A lone spike is flagged and must NOT inflate the baseline."""
        wd = Watchdog(threshold=3.0, warmup_steps=1)
        for i in range(10):
            wd.observe(i, 0.1)
        ema_before = wd.ema
        assert wd.observe(99, 1.0)
        assert wd.ema == pytest.approx(ema_before)
        assert not wd.observe(100, 0.1)             # back to normal
        assert wd.consecutive == 0

    def test_watchdog_adapts_to_sustained_slowdown(self):
        """Regression: observe() never updated the EMA on a straggler
        step, so a sustained legitimate slowdown (e.g. after re-mesh)
        flagged every subsequent step forever.  After ``adapt_after``
        consecutive incidents the EMA must converge on the new step
        time and flagging must stop."""
        wd = Watchdog(threshold=3.0, ema=0.5, warmup_steps=1,
                      adapt_after=3)
        for i in range(10):
            assert not wd.observe(i, 0.1)
        # a 10x sustained slowdown: the onset is flagged...
        flagged = [wd.observe(100 + i, 1.0) for i in range(20)]
        assert flagged[0] and flagged[1] and flagged[2]
        # ...but the baseline adapts and flagging recovers (the old
        # behaviour flagged all 20)
        assert not all(flagged)
        assert not flagged[-1]
        assert wd.consecutive == 0
        assert wd.ema == pytest.approx(1.0, rel=0.35)
        # the new normal is no longer an incident
        assert not wd.observe(200, 1.0)
        # and the incident log still recorded the onset
        assert wd.incidents and wd.incidents[0]["step"] == 100

    def test_watchdog_adapt_after_validation(self):
        with pytest.raises(ValueError):
            Watchdog(adapt_after=0)

    def test_straggler_triggers_incident_hook(self):
        incidents = []
        slow_once = {"done": False}

        def step(state, batch):
            if state[1] == 5 and not slow_once["done"]:
                slow_once["done"] = True
                time.sleep(0.5)
            return self._step_fn(state, batch)

        with tempfile.TemporaryDirectory() as d:
            r = TrainRunner(
                step, self._batch_fn, CheckpointManager(d), ckpt_every=100,
                watchdog=Watchdog(threshold=5.0, warmup_steps=2),
                on_incident=incidents.append,
            )
            r.run((jnp.zeros((4,)), jnp.zeros((), jnp.int32)), 0, 10)
        assert len(incidents) >= 1

    def test_preemption_checkpoint_and_stop(self):
        guard = PreemptionGuard(install=False)
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            r = TrainRunner(self._step_fn, self._batch_fn, cm,
                            ckpt_every=100, guard=guard)
            state0 = (jnp.zeros((4,)), jnp.zeros((), jnp.int32))
            step, state, status = r.run(state0, 0, 3)
            guard.request()
            step, state, status = r.run(state, step, 100)
            assert status == "preempted"
            assert cm.latest_step() == step

    def test_elastic_restore_resharding(self):
        """Restore onto explicit (1-device) shardings — the elastic path."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh((1, 1))
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        sh = {"w": NamedSharding(mesh, P("data", "model"))}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, tree, blocking=True)
            got = restore_checkpoint(d, 1, tree, shardings=sh)
            assert bool(jnp.all(got["w"] == tree["w"]))
            assert got["w"].sharding == sh["w"]
