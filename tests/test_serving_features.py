"""Serving/perf feature tests: BFP weight storage (paper C2 as HBM
bandwidth), MoE expert fission, the STD serving pipeline with random-size
inputs + transpose trick."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.lm import LMModel
from repro.models.lm import moe as moe_mod
from repro.models.lm import params as params_lib
from repro.models.lm.params import materialize


class TestBFPWeights:
    def test_quantized_forward_close(self, monkeypatch):
        monkeypatch.setattr(params_lib, "_BFP_MIN_SIZE", 1)
        cfg = get_smoke_config("tinyllama-1.1b")
        model = LMModel(cfg)
        metas = model.param_meta()
        params = model.init_params(jax.random.PRNGKey(0))
        qp = params_lib.quantize_weights(params, metas)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab)
        full = model.forward(params, toks)
        quant = model.forward(qp, toks)
        p1 = jax.nn.softmax(full, -1)
        p2 = jax.nn.softmax(quant, -1)
        assert float(jnp.mean(jnp.abs(p1 - p2))) < 2e-3

    def test_decode_with_bfp_weights(self, monkeypatch):
        monkeypatch.setattr(params_lib, "_BFP_MIN_SIZE", 1)
        cfg = get_smoke_config("internlm2-1.8b")
        model = LMModel(cfg)
        metas = model.param_meta()
        params = model.init_params(jax.random.PRNGKey(0))
        qp = params_lib.quantize_weights(params, metas)
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                  cfg.vocab)
        _, cache = model.forward(qp, toks, cache_out=True, max_len=12)
        lg, cache = model.decode_step(qp, toks[:, :1], cache, 8)
        assert bool(jnp.all(jnp.isfinite(lg)))

    def test_storage_is_int8(self, monkeypatch):
        monkeypatch.setattr(params_lib, "_BFP_MIN_SIZE", 1)
        cfg = get_smoke_config("tinyllama-1.1b")
        model = LMModel(cfg)
        metas = model.param_meta()
        params = model.init_params(jax.random.PRNGKey(0))
        qp = params_lib.quantize_weights(params, metas)
        wq = qp["layers"]["attn"]["wq"]
        from repro.core.bfp import BFPTensor

        assert isinstance(wq, BFPTensor)
        assert wq.mantissa.dtype == jnp.int8
        # embed is excluded (gather path)
        assert not isinstance(qp["embed"]["table"], BFPTensor)

    def test_abstract_matches_quantized(self, monkeypatch):
        monkeypatch.setattr(params_lib, "_BFP_MIN_SIZE", 1)
        cfg = get_smoke_config("tinyllama-1.1b")
        model = LMModel(cfg)
        metas = model.param_meta()
        params = model.init_params(jax.random.PRNGKey(0))
        qp = params_lib.quantize_weights(params, metas)
        ab = params_lib.bfp_abstract(metas)
        s1 = jax.tree_util.tree_structure(qp)
        s2 = jax.tree_util.tree_structure(ab)
        assert s1 == s2
        for a, b in zip(jax.tree_util.tree_leaves(qp),
                        jax.tree_util.tree_leaves(ab)):
            assert a.shape == b.shape and a.dtype == b.dtype


class TestMoEFission:
    def test_equivalence_to_unfissioned(self):
        d, f, E, k, T = 32, 64, 4, 2, 64
        t1 = {"n_experts": E, "top_k": k, "capacity_factor": 16.0}
        p1 = materialize(moe_mod.moe_meta(d, f, E, jnp.float32),
                         jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, T // 2, d))
        y1 = moe_mod.moe(p1, x, table=t1)
        r = 2
        p2 = {
            "router": p1["router"],
            "wg": p1["wg"].reshape(E, d, r, f // r).transpose(0, 2, 1, 3)
            .reshape(E * r, d, f // r),
            "wu": p1["wu"].reshape(E, d, r, f // r).transpose(0, 2, 1, 3)
            .reshape(E * r, d, f // r),
            "wd": p1["wd"].reshape(E, r, f // r, d).reshape(E * r, f // r, d),
        }
        y2 = moe_mod.moe(p2, x, table=dict(t1, fission=r))
        np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)

    def test_fission_meta_shapes(self):
        m = moe_mod.moe_meta(32, 64, 8, jnp.float32, fission=2)
        assert m["wg"].shape == (16, 32, 32)
        assert m["wd"].shape == (16, 32, 32)
        assert m["router"].shape == (32, 8)        # router stays per-expert


class TestSTDServing:
    def test_random_size_and_transpose_trick(self, monkeypatch):
        import repro.launch.serve as srv

        monkeypatch.setattr(srv, "MAX_WIDTH", 100)   # force the trick
        svc = srv.STDService(width=0.125, buckets=(64, 128, 256))
        img = np.random.rand(64, 160, 3).astype(np.float32)   # w > limit
        boxes = svc(img)
        assert svc.stats["transposed"] == 1
        assert isinstance(boxes, list)

    def test_pipelined_results_match_sequential(self):
        from repro.data.images import SyntheticSTDData
        from repro.launch.serve import STDService

        svc = STDService(width=0.125, buckets=(64,))
        images = [SyntheticSTDData((56, 64), seed=i).sample(0, 1)["images"][0]
                  for i in range(4)]
        seq = [svc(img) for img in images]
        pipe = svc.serve_pipelined(images)
        assert [[b["box"] for b in r] for r in seq] == \
               [[b["box"] for b in r] for r in pipe]


class TestInt8KVCache:
    """Paper C2 on the decode-dominant stream (§Perf cell C finding)."""

    def test_decode_quality_and_dtype(self):
        import dataclasses

        cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"),
                                  kv_cache_dtype="int8")
        m = LMModel(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab)
        full = m.forward(params, toks)
        _, cache = m.forward(params, toks[:, :8], cache_out=True,
                             max_len=16)
        assert cache["layers"]["k"].dtype == jnp.int8
        assert cache["layers"]["k_scale"].dtype == jnp.float16
        cl = 8
        outs = []
        for t in range(8, 16):
            lg, cache = m.decode_step(params, toks[:, t:t + 1], cache, cl)
            outs.append(lg[:, 0])
            cl += 1
        lg = jnp.stack(outs, 1)
        p1 = jax.nn.softmax(lg, -1)
        p2 = jax.nn.softmax(full[:, 8:], -1)
        assert float(jnp.mean(jnp.abs(p1 - p2))) < 1e-3

    def test_cache_bytes_halved(self):
        import dataclasses

        import numpy as np

        from repro.models.lm.params import is_meta

        base = get_smoke_config("tinyllama-1.1b")
        q = dataclasses.replace(base, kv_cache_dtype="int8")

        def cache_bytes(cfg):
            m = LMModel(cfg)
            tree = m.cache_meta(8, 1024)
            return sum(
                int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
                for x in jax.tree_util.tree_leaves(tree, is_leaf=is_meta)
            )

        b0, b1 = cache_bytes(base), cache_bytes(q)
        assert b1 < 0.6 * b0          # int8 + small scale tensors
