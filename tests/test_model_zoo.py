"""Model-zoo tests: PixelLink, EAST, and DB heads all compile through
ONE assembler -> microcode -> FCNEngine seam (paper Fig. 4's
configuration flow), the per-model microcode disassembly stays
byte-stable against golden snapshots, the engine LRU keys on the model
axis without collisions, STDService routes per model, and every head's
serving decode matches its pure-NumPy reference oracle on shared maps.

Golden snapshots live in tests/golden/microcode_<model>.txt and are
regenerated (never hand-edited) by scripts/regen_golden_models.py."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.microcode import ExtOp
from repro.models.fcn import (
    DEFAULT_MODEL,
    MODEL_ZOO,
    DetectionModel,
    build_head,
    check_model,
)
from repro.models.fcn.pixellink import STDConfig
from repro.runtime.executor import EngineFactory, SingleDevice
from repro.runtime.telemetry import CostBook

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_HW = (64, 64)


def golden_model(name: str, hw=GOLDEN_HW) -> DetectionModel:
    """The canonical zoo build the golden snapshots freeze: a tiny
    vgg16 trunk in reference mode, so the microcode depends only on the
    assembler + the head's LayerSpecs — never on precision or runtime
    knobs."""
    return DetectionModel(
        STDConfig(name=f"{name}_vgg16", backbone="vgg16", width=0.125,
                  image_size=tuple(hw), merge_ch=(16, 16, 8),
                  mode="reference", storage_fp16=False),
        build_head(name),
    )


def golden_path(name: str) -> str:
    return os.path.join(GOLDEN_DIR, f"microcode_{name}.txt")


def golden_memplan_path(name: str) -> str:
    return os.path.join(GOLDEN_DIR, f"microcode_{name}_memplan.txt")


def golden_memplan_text(name: str) -> str:
    """Memplan-optimized disassembly + plan annotations for one zoo
    model (core.memplan.plan_disassembly over the canonical golden
    build) — the snapshot that freezes the planner's schedule, slot
    assignment, free-after sets, and fusion facts per head."""
    from repro.core.memplan import plan_disassembly

    return plan_disassembly(golden_model(name).program) + "\n"


def _zoo_factory(capacity: int = 8) -> EngineFactory:
    return EngineFactory(
        lambda hw, precision="f32", model=DEFAULT_MODEL:
            golden_model(model, hw),
        capacity=capacity,
    )


class TestZooCompile:
    @pytest.mark.parametrize("name", sorted(MODEL_ZOO))
    def test_head_compiles_and_applies(self, name):
        """Every zoo head assembles to non-empty microcode and its
        apply() returns exactly the maps the head declares, at the
        declared ranks (quarter-res plane)."""
        m = golden_model(name)
        assert len(m.program.words) > 0
        assert np.asarray(m.microcode_bytes()).size == 32 * len(
            m.program.words)
        params = m.init_params(jax.random.PRNGKey(0))
        x = jax.random.uniform(jax.random.PRNGKey(1), (1, 64, 64, 3))
        out = m.apply(params, x)
        for map_name, rank in m.head.maps:
            assert map_name in out
            assert out[map_name].ndim == rank
            assert out[map_name].shape[1:3] == (16, 16)

    def test_db_residual_head_uses_add_ext_op(self):
        """The DB head's shortcut merge must lower to the explicit
        elementwise-add ext op — the microcode seam the assembler
        add-op channel fix exists for."""
        prog = golden_model("db").program
        adds = [w for w in prog.words if w.ext_opcode == ExtOp.ADD]
        assert adds, "DB program lowered without an ADD ext op"
        # binary add: in_ch is ONE operand's channels, not the sum
        (add,) = adds[-1:]
        assert add.in_ch == add.out_ch

    def test_check_model_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown model"):
            check_model("craft")


class TestGoldenMicrocode:
    @pytest.mark.parametrize("name", sorted(MODEL_ZOO))
    def test_disassembly_matches_golden(self, name):
        """Byte-stable microcode per model.  On intentional assembler /
        head changes, regenerate with scripts/regen_golden_models.py
        in the same commit."""
        text = golden_model(name).program.disassemble() + "\n"
        with open(golden_path(name)) as f:
            assert f.read() == text, (
                f"microcode drift for {name!r}; if intentional run "
                "scripts/regen_golden_models.py"
            )

    @pytest.mark.parametrize("name", sorted(MODEL_ZOO))
    def test_assembly_is_deterministic(self, name):
        a = golden_model(name).program.disassemble()
        b = golden_model(name).program.disassemble()
        assert a == b

    @pytest.mark.parametrize("name", sorted(MODEL_ZOO))
    def test_memplan_disassembly_matches_golden(self, name):
        """Byte-stable memory plan per model: schedule, arena slots,
        free-after sets, and fusion facts.  A planner or assembler
        change that moves any of them fails here with a diff; if
        intentional, regenerate with scripts/regen_golden_models.py."""
        with open(golden_memplan_path(name)) as f:
            assert f.read() == golden_memplan_text(name), (
                f"memory-plan drift for {name!r}; if intentional run "
                "scripts/regen_golden_models.py"
            )

    @pytest.mark.parametrize("name", sorted(MODEL_ZOO))
    def test_memplan_is_deterministic(self, name):
        assert golden_memplan_text(name) == golden_memplan_text(name)


class TestMemplanBoxParity:
    @pytest.mark.parametrize("name", sorted(MODEL_ZOO))
    @pytest.mark.parametrize("hw", [(64, 64), (96, 96)])
    def test_planned_engine_boxes_identical(self, name, hw):
        """Property over the bucket grid: the memplan-scheduled engine
        (fusion facts from the plan, buffers dropped at last use) and
        the unplanned engine must be BOX-IDENTICAL — same weights, same
        maps, same reference decode.  Maps are compared bitwise: the
        plan may only reorder bookkeeping, never arithmetic."""
        builds = {}
        for on in (False, True):
            m = DetectionModel(
                STDConfig(name=f"{name}_vgg16", backbone="vgg16",
                          width=0.125, image_size=hw,
                          merge_ch=(16, 16, 8), mode="optimized",
                          storage_fp16=False, memplan=on),
                build_head(name),
            )
            params = m.init_params(jax.random.PRNGKey(0))
            x = jax.random.uniform(jax.random.PRNGKey(5), (1, *hw, 3))
            builds[on] = (m, m.apply(params, x))
        m_on, maps_on = builds[True]
        m_off, maps_off = builds[False]
        assert m_on.engine.memplan is not None
        assert m_off.engine.memplan is None
        for k in maps_off:
            assert np.array_equal(np.asarray(maps_off[k]),
                                  np.asarray(maps_on[k])), k
        valid = (hw[0], hw[1] - 8)
        boxes = {
            on: sorted(b["box"] for b in m.head.reference_decode(
                {k: np.asarray(v[0]) for k, v in maps.items()
                 if k != "logits"},
                valid,
            ))
            for on, (m, maps) in builds.items()
        }
        assert boxes[True] == boxes[False]


class TestEngineLRUModelAxis:
    def test_models_and_params_keyed_per_model(self):
        fac = _zoo_factory()
        by_name = {n: fac.model(GOLDEN_HW, "f32", n)
                   for n in sorted(MODEL_ZOO)}
        assert len({id(m) for m in by_name.values()}) == len(MODEL_ZOO)
        for n, m in by_name.items():
            assert m.head.name == n
            # cache hit: same key returns the same object
            assert fac.model(GOLDEN_HW, "f32", n) is m
        pid = {n: id(fac.params(GOLDEN_HW, "f32", n))
               for n in sorted(MODEL_ZOO)}
        assert len(set(pid.values())) == len(MODEL_ZOO)

    def test_engines_keyed_per_model_no_collision(self):
        """Same (bucket, batch, plan, precision), different model must
        compile DIFFERENT engines — and each engine's payload arity
        proves which head actually ran."""
        fac = _zoo_factory()
        plan = SingleDevice()
        fns = {n: fac.plan_fn(GOLDEN_HW, 1, plan, "f32", n)
               for n in ("pixellink", "east", "db")}
        assert len({id(f) for f in fns.values()}) == 3
        x = jnp.asarray(np.random.default_rng(0).uniform(
            size=(1, *GOLDEN_HW, 3)).astype(np.float32))
        vq = jnp.asarray([[16, 16]], jnp.int32)
        out = {n: fns[n](fac.params(GOLDEN_HW, "f32", n), x, vq)
               for n in fns}
        assert len(out["pixellink"]) == 2      # (labels, converged)
        assert len(out["db"]) == 2             # (labels, converged)
        assert len(out["east"]) == 3           # (score, geo, converged)
        assert np.asarray(out["east"][1]).shape == (1, 16, 16, 4)
        models = {e.get("model") for e in fac.stats["compiled"]}
        assert models == {"pixellink", "east", "db"}

    def test_unknown_model_rejected_at_plan_fn(self):
        fac = _zoo_factory()
        with pytest.raises(ValueError, match="unknown model"):
            fac.plan_fn(GOLDEN_HW, 1, SingleDevice(), "f32", "craft")


class TestServiceModelRouting:
    def _service(self, **kw):
        from repro.launch.serve import STDService
        return STDService(width=0.125, buckets=(64,), max_batch=2,
                          max_wait_ms=4.0, engine_cache_capacity=0,
                          book=CostBook(warmup=0), **kw)

    def test_east_serves_and_labels_telemetry(self):
        svc = self._service(model="east")
        img = np.random.default_rng(2).uniform(
            size=(48, 52, 3)).astype(np.float32)
        boxes = svc.serve_batched([img])[0]
        assert isinstance(boxes, list)
        for b in boxes:
            assert {"label", "box", "area", "score"} <= set(b)
        assert all(e["model"] == "east"
                   for e in svc.factory.stats["compiled"])
        snap = svc.book.snapshot()
        assert any('model="east"' in k for k in snap)
        assert not any('model="pixellink"' in k for k in snap)

    def test_east_device_postprocess_rejected(self):
        with pytest.raises(ValueError, match="no label-map payload"):
            self._service(model="east", postprocess="device")

    def test_db_device_host_box_parity(self):
        img = np.random.default_rng(3).uniform(
            size=(48, 48, 3)).astype(np.float32)
        host = self._service(model="db", postprocess="host")
        dev = self._service(model="db", postprocess="device",
                            boxes_capacity=64)
        bh = host.serve_batched([img])[0]
        bd = dev.serve_batched([img])[0]
        assert [b["box"] for b in bh] == [b["box"] for b in bd]


class TestDecodeParity:
    @pytest.mark.parametrize("name", sorted(MODEL_ZOO))
    def test_decode_matches_reference(self, name):
        """Serving decode (device tail + head.decode) and the pure
        NumPy reference decode must produce identical box sets from ONE
        shared set of eager maps — this gates the decode algorithms, so
        jit-vs-eager float noise at the 0.5 threshold can't flake it."""
        m = golden_model(name)
        head = m.head
        params = m.init_params(jax.random.PRNGKey(3))
        x = jax.random.uniform(jax.random.PRNGKey(4), (1, 64, 64, 3))
        maps = m.apply(params, x)
        valid = (64, 56)      # ragged width exercises the crop path
        fac = _zoo_factory()
        vq = jnp.asarray([[valid[0] // 4, valid[1] // 4]], jnp.int32)
        tail = head.tail(fac, maps, vq)
        arrs = [np.asarray(a)[0] for a in tail[:head.n_payload]]
        payload = arrs[0] if head.n_payload == 1 else tuple(arrs)
        got, kind = head.decode(payload, valid)
        ref = head.reference_decode(
            {k: np.asarray(v[0]) for k, v in maps.items()
             if k != "logits"},
            valid,
        )
        assert kind == "host"
        assert sorted(b["box"] for b in got) \
            == sorted(b["box"] for b in ref)
        if name == "db":      # unclip must have clamped inside the crop
            for b in got:
                x0, y0, x1, y1 = b["box"]
                assert 0 <= x0 <= x1 < valid[1] // 4
                assert 0 <= y0 <= y1 < valid[0] // 4


class TestTelemetryModelAxis:
    def test_series_split_and_labeled_per_model(self):
        book = CostBook(warmup=0)
        book.record_step((64, 64), 1, "single_device", 0.010)
        book.record_step((64, 64), 1, "single_device", 0.020,
                         model="east")
        assert book.step_count((64, 64), 1, "single_device") == 1
        assert book.step_count((64, 64), 1, "single_device",
                               model="east") == 1
        assert book.step_ewma((64, 64), 1, "single_device",
                              model="east") == pytest.approx(0.020)
        snap = book.snapshot()
        east = [k for k in snap if 'model="east"' in k]
        assert east
        # the default model keeps the historical (unlabeled) shape
        base = [k for k in snap
                if "step_count" in k and "model=" not in k]
        assert base
