"""Multi-device tests — each spawns a subprocess with
--xla_force_host_platform_device_count (the main test process must keep
seeing ONE device; see conftest).  Covers: shard_map pipeline parallelism
fwd+grad equivalence, compressed psum, sharded train-step equivalence vs
single device, and a reduced-mesh dry-run smoke."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# each test spawns a fresh interpreter that re-imports jax and compiles a
# multi-device program — minutes, not seconds; keep out of the fast tier
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str, n_devices: int = 8, timeout: int = 900) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import sys
        sys.path.insert(0, {SRC!r})
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


class TestPipelineParallel:
    def test_fwd_and_grad_match_scan(self):
        out = run_sub("""
            from repro.runtime.pipeline import pipeline_apply, split_stages
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((4, 2), ("stage", "mdl"))
            L, D, M, mb, seq = 8, 16, 4, 2, 8
            params = {"w": jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.2,
                      "b": jnp.zeros((L, D))}
            layer_fn = lambda lp, h: jnp.tanh(h @ lp["w"] + lp["b"])
            def ref(params, x):
                return jax.lax.scan(lambda c, lp: (layer_fn(lp, c), None), x, params)[0]
            x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, seq, D))
            staged = split_stages(params, 4)
            y_pp = pipeline_apply(mesh, "stage", layer_fn, staged, x)
            y_ref = jax.vmap(lambda xm: ref(params, xm))(x)
            assert float(jnp.max(jnp.abs(y_pp - y_ref))) < 1e-5
            g_pp = jax.grad(lambda s: jnp.sum(pipeline_apply(mesh, "stage", layer_fn, s, x) ** 2))(staged)
            g_ref = jax.grad(lambda p: jnp.sum(jax.vmap(lambda xm: ref(p, xm))(x) ** 2))(params)
            flat = jax.tree_util.tree_map(lambda a: a.reshape(-1, *a.shape[2:]), g_pp)
            err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
                jax.tree_util.tree_leaves(flat), jax.tree_util.tree_leaves(g_ref)))
            assert err < 1e-4, err
            print("PP_OK")
        """)
        assert "PP_OK" in out


class TestCompressedCollectives:
    def test_compressed_psum_close_to_exact(self):
        out = run_sub("""
            from functools import partial
            from repro.runtime.collectives import compressed_psum
            from repro.launch.mesh import make_mesh
            from repro.runtime.sharding import shard_map_compat
            mesh = make_mesh((8,), ("data",))
            from jax.sharding import PartitionSpec as P
            x = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 64))
            f = shard_map_compat(
                lambda xs: compressed_psum(xs[0], "data", mantissa_bits=7),
                mesh=mesh, in_specs=P("data"), out_specs=P(),
                check=False,
            )
            got = f(x)
            want = jnp.sum(x, axis=0)
            rel = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
            assert rel < 0.05, rel
            print("CPSUM_OK", rel)
        """)
        assert "CPSUM_OK" in out

    def test_bytes_model(self):
        from repro.runtime.collectives import psum_bytes_model

        ring, gather = psum_bytes_model(4 * 2**20, 16, compressed=True)
        assert gather < ring / 4        # >4x traffic reduction


class TestShardedTraining:
    def test_tp_dp_train_step_matches_single_device(self):
        """Same arch, same data: 8-device (2 data x 4 model) sharded train
        step must match the unsharded step numerically."""
        out = run_sub("""
            from repro.configs import get_smoke_config
            from repro.configs.base import ShapeConfig
            from repro.launch.step_fns import build_train_step
            from repro.models.lm import params as params_lib
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((2, 4), ("data", "model"))
            cfg = get_smoke_config("tinyllama-1.1b")
            shape = ShapeConfig("t", 16, 4, "train")
            built = build_train_step(cfg, mesh, shape, moment_dtype="float32")
            model = built.model
            params = model.init_params(jax.random.PRNGKey(0))
            from repro.optim import adamw, cosine_with_warmup
            opt_init, _ = adamw(cosine_with_warmup(3e-4, 2000, 100000))
            opt = opt_init(params)
            batch = {
                "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab),
                "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab),
            }
            # single-device reference FIRST: the jitted step donates
            # params/opt buffers
            from repro.models.lm import cross_entropy
            def loss_fn(p):
                return cross_entropy(model.forward(p, batch["tokens"], mode="train"), batch["labels"])
            l, g = jax.value_and_grad(loss_fn)(params)
            with mesh:
                p2, o2, m = built.fn(params, opt, batch)
            assert abs(float(m["loss"]) - float(l)) < 1e-4, (float(m["loss"]), float(l))
            print("SHARD_TRAIN_OK", float(m["loss"]))
        """)
        assert "SHARD_TRAIN_OK" in out


class TestDryRunSmoke:
    def test_reduced_mesh_dry_run_cell(self):
        """The dry-run machinery end-to-end on a small fake mesh: lower +
        compile + cost/memory/collective extraction for one smoke arch."""
        out = run_sub("""
            from repro.configs import get_smoke_config
            from repro.configs.base import ShapeConfig
            from repro.launch.step_fns import build_step
            from repro.launch import hlo_analysis
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((2, 4), ("data", "model"))
            cfg = get_smoke_config("internlm2-1.8b")
            shape = ShapeConfig("t", 32, 4, "train")
            built = build_step(cfg, mesh, shape, moment_dtype="float32")
            with mesh:
                lowered = built.fn.lower(*built.abstract_args)
                compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):      # jax<=0.4.x: list of per-module dicts
                cost = cost[0]
            coll = hlo_analysis.collective_bytes(compiled.as_text())
            assert cost.get("flops", 0) > 0
            assert coll["count"] > 0
            print("DRYRUN_OK flops=", cost["flops"], "coll=", coll["total"])
        """)
        assert "DRYRUN_OK" in out

    def test_decode_cell_lowers(self):
        out = run_sub("""
            from repro.configs import get_smoke_config
            from repro.configs.base import ShapeConfig
            from repro.launch.step_fns import build_step
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((2, 4), ("data", "model"))
            cfg = get_smoke_config("zamba2-2.7b")
            shape = ShapeConfig("d", 64, 4, "decode")
            built = build_step(cfg, mesh, shape)
            with mesh:
                compiled = built.fn.lower(*built.abstract_args).compile()
            assert compiled.memory_analysis() is not None
            print("DECODE_LOWER_OK")
        """)
        assert "DECODE_LOWER_OK" in out
