"""Paper §IV.B tests: row-band segmentation equivalence + the band
schedule's buffer rule, and the transposed-image engine mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # bare interpreter: seeded fallback shim
    from _hypothesis_compat import given, settings, strategies as st
from jax import lax

from repro.core import Assembler, FCNEngine, LayerSpec
from repro.core.rowband import (band_schedule, conv2d_banded,
                                program_halo_rows)


def sym_conv(x, w, stride=1):
    k = w.shape[0]
    pad = (k - 1) // 2
    return lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


class TestRowBand:
    @settings(max_examples=25, deadline=None)
    @given(
        st.integers(0, 500),
        st.integers(6, 40),
        st.sampled_from([1, 3, 7]),
        st.sampled_from([1, 2]),
        st.integers(1, 6),
    )
    def test_banded_equals_full(self, seed, h, k, stride, n_bands):
        ks = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(ks[0], (2, h, 11, 3))
        w = jax.random.normal(ks[1], (k, k, 3, 5))
        got = conv2d_banded(x, w, stride=stride, n_bands=n_bands)
        want = sym_conv(x, w, stride)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)

    def test_band_schedule_respects_buffer(self):
        h, w, cin = 512, 512, 64
        bands = band_schedule(h, w, cin, buffer_bytes=1 << 20)
        assert bands[0][0] == 0 and bands[-1][1] == h
        for r0, r1 in bands:
            assert (r1 - r0 + 2) * w * cin * 2 <= (1 << 20) + 2 * w * cin * 2
        # contiguous, ordered
        for (a0, a1), (b0, b1) in zip(bands, bands[1:]):
            assert a1 == b0

    def test_more_bands_less_buffer(self):
        """Smaller buffer -> more rounds (the paper's load/compute knob)."""
        n1 = len(band_schedule(512, 512, 64, buffer_bytes=8 << 20))
        n2 = len(band_schedule(512, 512, 64, buffer_bytes=1 << 20))
        assert n2 > n1

    def test_banded_conv_with_engine_weights(self):
        """Row-banding composes with the engine's conv layer output."""
        specs = [LayerSpec("c", "conv", ["input"], out_ch=4, kernel=3)]
        prog = Assembler((16, 12, 3)).assemble(specs, outputs=["c"])
        eng = FCNEngine(prog)
        params = eng.init_params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 12, 3))
        full = eng(params, x)["c"]
        banded = conv2d_banded(x, params["c"]["w"], n_bands=4) + params["c"]["b"]
        np.testing.assert_allclose(banded, full, atol=1e-5)


class TestProgramHaloRows:
    def _prog(self, specs, hw=(32, 32)):
        outs = [specs[-1].name]
        return Assembler(hw + (3,)).assemble(specs, outputs=outs)

    def test_single_conv_bound(self):
        """One 3x3 conv: true radius 1, conservative bound (k-1)*jump=2."""
        prog = self._prog([LayerSpec("c", "conv", ["input"], out_ch=4,
                                     kernel=3)])
        assert 1 <= program_halo_rows(prog) <= 2

    def test_1x1_conv_needs_no_halo(self):
        prog = self._prog([LayerSpec("c", "conv", ["input"], out_ch=4,
                                     kernel=1)])
        assert program_halo_rows(prog) == 0

    def test_radius_grows_with_depth_and_stride(self):
        shallow = self._prog([
            LayerSpec("c1", "conv", ["input"], out_ch=4, kernel=3),
        ])
        deep = self._prog([
            LayerSpec("c1", "conv", ["input"], out_ch=4, kernel=3),
            LayerSpec("p1", "pool", ["c1"], kernel=2, stride=2),
            LayerSpec("c2", "conv", ["p1"], out_ch=4, kernel=3),
            LayerSpec("c3", "conv", ["c2"], out_ch=4, kernel=3),
        ])
        r1 = program_halo_rows(shallow)
        r2 = program_halo_rows(deep)
        # after the stride-2 pool each 3x3 conv reads at jump 2
        assert r2 > r1
        assert r2 >= r1 + 1 + 2 * 2 * 2

    def test_concat_takes_max_over_branches(self):
        # two branches concat-read by the head: radius >= deeper branch
        specs = [
            LayerSpec("a", "conv", ["input"], out_ch=4, kernel=3),
            LayerSpec("b1", "conv", ["input"], out_ch=4, kernel=3),
            LayerSpec("b2", "conv", ["b1"], out_ch=4, kernel=3),
            LayerSpec("h", "conv", ["a", "b2"], out_ch=4, kernel=1),
        ]
        deep_only = self._prog([
            LayerSpec("b1", "conv", ["input"], out_ch=4, kernel=3),
            LayerSpec("b2", "conv", ["b1"], out_ch=4, kernel=3),
        ])
        assert (program_halo_rows(self._prog(specs))
                >= program_halo_rows(deep_only))


class TestTransposedMode:
    def _model(self):
        specs = [
            LayerSpec("c1", "conv", ["input"], out_ch=6, kernel=3,
                      relu=True),
            LayerSpec("p1", "pool", ["c1"], kernel=2, stride=2),
            LayerSpec("c2", "conv", ["p1"], out_ch=4, kernel=3),
        ]
        prog = Assembler((12, 20, 3)).assemble(specs, outputs=["c2"])
        return FCNEngine(prog)

    def test_transposed_execution_matches(self):
        """engine(x.T, transposed=True).T == engine(x) — §IV.B verbatim."""
        eng = self._model()
        params = eng.init_params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 20, 3))
        plain = eng(params, x)["c2"]
        xt = jnp.swapaxes(x, 1, 2)
        tr = eng(params, xt, transposed=True)["c2"]
        np.testing.assert_allclose(jnp.swapaxes(tr, 1, 2), plain, atol=1e-5)

    def test_shape_validation(self):
        eng = self._model()
        params = eng.init_params(jax.random.PRNGKey(0))
        bad = jnp.zeros((1, 20, 12, 3))
        with pytest.raises(ValueError):
            eng(params, bad)                       # wrong orientation
        eng(params, bad, transposed=True)          # correct when declared
