"""FCN engine tests: assembler address/concat semantics, residual cache
ops, backbone assembly, engine modes (reference/optimized/BFP), STD model
end-to-end, CC postprocess vs union-find."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # bare interpreter: seeded fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import Assembler, BFPConfig, FCNEngine, LayerSpec
from repro.core.microcode import unpack_program, pack_program


def tiny_program():
    specs = [
        LayerSpec("c1", "conv", ["input"], out_ch=8, kernel=3, relu=True,
                  bn=True),
        LayerSpec("p1", "pool", ["c1"], kernel=2, stride=2),
        LayerSpec("c2", "conv", ["p1"], out_ch=8, kernel=3, relu=True,
                  res="cache"),
        LayerSpec("c3", "conv", ["c2"], out_ch=8, kernel=3, res="add",
                  relu=True),
        LayerSpec("u1", "upsample", ["c3"], upsample_mode="nearest"),
        LayerSpec("cc", "conv", ["u1", "c1"], out_ch=4, kernel=1),
        LayerSpec("sg", "sigmoid", ["cc"]),
    ]
    return Assembler((16, 16, 3)).assemble(specs, outputs=["sg"])


class TestAssembler:
    def test_concat_producers_adjacent(self):
        """Concat = adjacent addresses (paper §III.B), no copy op."""
        prog = tiny_program()
        by_name = {prog.layer_specs[i].name: w
                   for i, w in enumerate(prog.words)}
        u1, c1 = by_name["u1"], by_name["c1"]
        u1_bytes = 16 * 16 * 8 * 2
        assert c1.out_addr == u1.out_addr + u1_bytes
        cc = by_name["cc"]
        assert cc.in_addr == u1.out_addr
        assert cc.in_ch == 16                      # combined extent

    def test_shape_fields_propagate(self):
        prog = tiny_program()
        w = prog.words[2]                          # c2: after 2x2/2 pool
        assert (w.height, w.width) == (8, 8)
        assert (w.in_ch, w.out_ch) == (8, 8)

    def test_program_packs_to_config_ram_format(self):
        prog = tiny_program()
        raw = pack_program(prog.words)
        assert raw.shape == (len(prog.words), 32)
        assert unpack_program(raw) == prog.words

    def test_double_concat_feeding_rejected(self):
        specs = [
            LayerSpec("a", "conv", ["input"], out_ch=4, kernel=1),
            LayerSpec("b", "conv", ["input"], out_ch=4, kernel=1),
            LayerSpec("c", "conv", ["a", "b"], out_ch=4, kernel=1),
            LayerSpec("d", "conv", ["b", "a"], out_ch=4, kernel=1),
        ]
        with pytest.raises(ValueError, match="concat"):
            Assembler((8, 8, 3)).assemble(specs, outputs=["d"])


class TestEngine:
    def setup_method(self, _):
        self.prog = tiny_program()
        self.x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16, 3))

    def test_reference_forward(self):
        eng = FCNEngine(self.prog)
        params = eng.init_params(jax.random.PRNGKey(1))
        out = eng(params, self.x)
        assert out["sg"].shape == (2, 16, 16, 4)
        assert bool(jnp.all((out["sg"] >= 0) & (out["sg"] <= 1)))

    def test_optimized_matches_reference(self):
        eng_r = FCNEngine(self.prog, mode="reference")
        eng_o = FCNEngine(self.prog, mode="optimized")
        params = eng_r.init_params(jax.random.PRNGKey(1))
        a = eng_r(params, self.x)["sg"]
        b = eng_o(params, self.x)["sg"]
        np.testing.assert_allclose(a, b, atol=1e-4)

    def test_pallas_winograd_path_matches(self):
        eng_r = FCNEngine(self.prog, mode="reference")
        eng_p = FCNEngine(self.prog, mode="optimized", use_pallas=True)
        params = eng_r.init_params(jax.random.PRNGKey(1))
        a = eng_r(params, self.x)["sg"]
        b = eng_p(params, self.x)["sg"]
        np.testing.assert_allclose(a, b, atol=1e-3)

    def test_bfp_mode_close_and_storage_fp16(self):
        eng_r = FCNEngine(self.prog)
        eng_b = FCNEngine(self.prog, bfp=BFPConfig(mantissa_bits=10),
                          storage_dtype=jnp.float16)
        params = eng_r.init_params(jax.random.PRNGKey(1))
        a = eng_r(params, self.x)["sg"]
        b = eng_b(eng_b.normalize_weights(params), self.x)["sg"]
        assert b.dtype == jnp.float16
        assert float(jnp.mean(jnp.abs(a - b.astype(jnp.float32)))) < 0.05

    def test_residual_cache_semantics(self):
        """res=cache then res=add must equal manual residual."""
        specs = [
            LayerSpec("id", "identity", ["input"], res="cache"),
            LayerSpec("c", "conv", ["input"], out_ch=3, kernel=1,
                      res="add"),
        ]
        prog = Assembler((4, 4, 3)).assemble(specs, outputs=["c"])
        eng = FCNEngine(prog)
        params = eng.init_params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 4, 3))
        got = eng(params, x)["c"]
        w, b = params["c"]["w"], params["c"]["b"]
        want = x + (jnp.einsum("nhwc,co->nhwo", x, w[0, 0]) + b)
        np.testing.assert_allclose(got, want, atol=1e-5)


class TestBackbones:
    @pytest.mark.parametrize("backbone", ["resnet50", "vgg16", "mobilenet"])
    def test_backbone_feature_pyramid(self, backbone):
        from repro.models.fcn import backbones as bb

        specs, taps = bb.BACKBONES[backbone](0.25)
        prog = Assembler((64, 64, 3)).assemble(specs, outputs=taps)
        eng = FCNEngine(prog)
        params = eng.init_params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3))
        out = eng(params, x)
        sizes = [out[t].shape[1] for t in taps]
        assert sizes == [16, 8, 4, 2]              # 1/4, 1/8, 1/16, 1/32

    @pytest.mark.parametrize("backbone", ["vgg16", "resnet50"])
    def test_std_model_end_to_end(self, backbone):
        from repro.models.fcn import PixelLinkModel, STDLoss
        from repro.models.fcn.pixellink import STDConfig

        cfg = STDConfig(backbone=backbone, width=0.125,
                        image_size=(64, 64), merge_ch=(16, 16, 8),
                        mode="reference", storage_fp16=False)
        m = PixelLinkModel(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3))
        out = m.apply(params, x)
        assert out["score"].shape == (1, 16, 16)
        assert out["links"].shape == (1, 16, 16, 8)
        sg = (jax.random.uniform(jax.random.PRNGKey(2), (1, 16, 16)) > 0.7
              ).astype(jnp.float32)
        lg = jnp.zeros((1, 16, 16, 8))
        losses = STDLoss()(out, sg, lg)
        grads = jax.grad(
            lambda p: STDLoss()(m.apply(p, x), sg, lg)["loss"]
        )(params)
        assert all(bool(jnp.all(jnp.isfinite(g)))
                   for g in jax.tree_util.tree_leaves(grads))
        assert float(losses["loss"]) > 0


class TestPostprocess:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 100), st.integers(6, 20))
    def test_cc_matches_union_find(self, seed, size):
        from repro.models.fcn import postprocess as pp

        rng = np.random.default_rng(seed)
        score = rng.random((size, size)).astype(np.float32)
        links = rng.random((size, size, 8)).astype(np.float32)
        lj = np.asarray(pp.cc_label(jnp.asarray(score), jnp.asarray(links)))
        ln = pp.cc_label_numpy(score, links)

        def canon(lab):
            mapping, out = {}, np.zeros_like(lab)
            for i, v in enumerate(lab.flat):
                if v:
                    out.flat[i] = mapping.setdefault(v, len(mapping) + 1)
            return out

        np.testing.assert_array_equal(canon(lj), canon(ln))

    def test_boxes_and_f_measure(self):
        from repro.models.fcn import postprocess as pp

        labels = np.zeros((16, 16), np.int32)
        labels[2:5, 3:9] = 7
        labels[10:12, 1:4] = 9
        boxes = pp.boxes_from_labels(labels)
        assert len(boxes) == 2
        gt = [b["box"] for b in boxes]
        fm = pp.f_measure(boxes, gt)
        assert fm["f_measure"] == 1.0
        fm2 = pp.f_measure(boxes, [(0, 0, 1, 1)])
        assert fm2["f_measure"] < 0.5


class TestAssemblerAddOp:
    """Regressions for the add-op channel-summing bug: a binary ``add``
    reads two SAME-shape operands (second via ext_addr2), so its word's
    in_ch is one operand's channel count — the concat path used to sum
    them, corrupting the word and every downstream reader."""

    def _residual_program(self, outputs=("c3",)):
        specs = [
            LayerSpec("c1", "conv", ["input"], out_ch=8, kernel=3,
                      relu=True),
            LayerSpec("c2", "conv", ["c1"], out_ch=8, kernel=1),
            LayerSpec("a", "add", ["c2", "c1"], relu=True),
            LayerSpec("c3", "conv", ["a"], out_ch=4, kernel=1),
        ]
        return Assembler((16, 16, 3)).assemble(specs,
                                               outputs=list(outputs))

    def test_add_word_channels_not_summed(self):
        prog = self._residual_program()
        by = {prog.layer_specs[i].name: w
              for i, w in enumerate(prog.words)}
        add, c1, c3 = by["a"], by["c1"], by["c3"]
        assert add.in_ch == 8                 # bug summed this to 16
        assert add.out_ch == 8
        assert prog.addr_shapes[add.out_addr] == (16, 16, 8)
        # second operand rides in the ext page by address, not channels
        assert add.ext_addr2 == c1.out_addr
        assert c3.in_ch == 8                  # downstream consumer too

    def test_add_channel_mismatch_rejected(self):
        specs = [
            LayerSpec("c1", "conv", ["input"], out_ch=8, kernel=1),
            LayerSpec("c2", "conv", ["input"], out_ch=4, kernel=1),
            LayerSpec("a", "add", ["c1", "c2"]),
        ]
        with pytest.raises(ValueError, match="channel mismatch"):
            Assembler((8, 8, 3)).assemble(specs, outputs=["a"])

    def test_add_numerics_through_engine(self):
        """Interpreter check: the add ext op must compute relu(x + y)
        of its two operands, which only holds once the word carries the
        un-summed channel count."""
        prog = self._residual_program(outputs=("c1", "c2", "a"))
        eng = FCNEngine(prog)
        params = eng.init_params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 3))
        out = eng(params, x)
        assert out["a"].shape == (1, 16, 16, 8)
        np.testing.assert_allclose(
            np.asarray(out["a"]),
            np.maximum(np.asarray(out["c1"]) + np.asarray(out["c2"]), 0),
            atol=1e-5,
        )


class TestKernelEncodingValidation:
    """Regressions for the silent kernel-snapping bug: unencodable
    kernels must raise at assembly, not quietly become a different
    hardware op."""

    def _pool(self, k):
        specs = [LayerSpec("p", "pool", ["input"], kernel=k, stride=2)]
        return Assembler((8, 8, 3)).assemble(specs, outputs=["p"])

    def test_pool_kernel_codes(self):
        # Table II pool convention: code 0 -> 2x2, code 1 -> 3x3
        assert self._pool(2).words[0].kernel == 0
        assert self._pool(3).words[0].kernel == 1

    def test_pool_kernel_unencodable_raises(self):
        with pytest.raises(ValueError, match="pool kernel 5"):
            self._pool(5)

    def test_conv_kernel_unencodable_raises(self):
        specs = [LayerSpec("c", "conv", ["input"], out_ch=4, kernel=5)]
        with pytest.raises(ValueError, match="conv kernel 5"):
            Assembler((8, 8, 3)).assemble(specs, outputs=["c"])


class TestSTDLossNormalization:
    def test_link_loss_matches_masked_mean_oracle(self):
        """Regression for the link-loss denominator bug: the masked
        BCE sum covers n_links channels of every positive pixel, so the
        mean divides by sum(mask) * n_links — dividing by sum(mask)
        alone inflated the link term 8-fold."""
        from repro.models.fcn import STDLoss

        rng = np.random.default_rng(7)
        logits = rng.normal(size=(2, 8, 8, 9)).astype(np.float32)
        score_gt = (rng.random((2, 8, 8)) > 0.6).astype(np.float32)
        link_gt = (rng.random((2, 8, 8, 8)) > 0.5).astype(np.float32)
        assert score_gt.sum() > 0
        losses = STDLoss()({"logits": jnp.asarray(logits)},
                           jnp.asarray(score_gt), jnp.asarray(link_gt))

        lg = logits[..., 1:]
        bce = (np.maximum(lg, 0) - lg * link_gt
               + np.log1p(np.exp(-np.abs(lg))))
        mask = (score_gt > 0.5).astype(np.float32)[..., None]
        want = (bce * mask).sum() / (mask.sum() * lg.shape[-1])
        assert float(losses["link_loss"]) == pytest.approx(want,
                                                           rel=1e-5)
