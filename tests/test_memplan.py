"""Static microcode optimizer + data-pool memory planner (core/memplan.py)
and its serving integrations: liveness / dead-word / dead-store analysis
on synthetic programs, arena slot accounting, admissible-batch math, the
byte-weighted engine LRU, per-bucket batch caps in the MicroBatcher, the
engine-memory metrics export, and the mode-aware upsample FLOP
accounting in the cost model (core/rowband.program_band_costs)."""
import dataclasses

import numpy as np
import pytest

from repro.core import fuse
from repro.core.assembler import Assembler, LayerSpec, STORAGE_BYTES
from repro.core.memplan import (
    _END,
    MemPlan,
    admissible_batch,
    optimize_program,
    plan_disassembly,
    plan_program,
)
from repro.core.rowband import program_band_costs
from repro.launch.batching import FakeClock, LRUCache, MicroBatcher
from repro.runtime.planner import (
    CostParams,
    PlanFeatures,
    features_for_program,
    step_cost,
)
from repro.runtime.telemetry import CostBook, cost_params_from_dict

HW = (8, 8)


def asm(specs, outputs, hw=HW):
    return Assembler((hw[0], hw[1], 3)).assemble(specs, outputs)


def chain_program():
    return asm(
        [
            LayerSpec("c1", "conv", out_ch=4, kernel=3, relu=True),
            LayerSpec("c2", "conv", inputs=["c1"], out_ch=4, kernel=3),
            LayerSpec("c3", "conv", inputs=["c2"], out_ch=2, kernel=1),
        ],
        ["c3"],
    )


class TestLiveness:
    def test_chain_frees_each_region_at_last_use(self):
        p = chain_program()
        plan = plan_program(p)
        assert plan.dead_words == ()
        assert plan.dead_stores == ()
        assert plan.schedule == (0, 1, 2)
        w = plan.words
        assert w[0].free_after == (p.input_addr,)
        assert w[1].free_after == (p.words[0].out_addr,)
        assert w[2].free_after == (p.words[1].out_addr,)
        # the program output is never freed
        out_addr = p.outputs["c3"]
        assert all(out_addr not in wp.free_after for wp in w.values())

    def test_peak_naive_and_slots_exact(self):
        # f32 sizes on an 8x8 plane: input 768, c1/c2 1024, c3 512.
        # drop-at-last-use peak is input+c1 then c1+c2 = 2048; best-fit
        # slot reuse covers the chain with two 1024-byte slots.
        plan = plan_program(chain_program(), dtype_bytes=4)
        assert plan.peak_bytes == 2048
        assert plan.naive_bytes == 768 + 1024 + 1024 + 512
        assert plan.pool_bytes == 2048
        assert plan.slot_bytes == (1024, 1024)
        assert 0.0 < plan.reduction < 1.0

    def test_dtype_bytes_scales_linearly(self):
        p = chain_program()
        f32 = plan_program(p, dtype_bytes=4)
        fp16 = plan_program(p, dtype_bytes=2)
        assert f32.peak_bytes == 2 * fp16.peak_bytes
        assert f32.naive_bytes == 2 * fp16.naive_bytes

    def test_concat_walk_frees_both_members(self):
        p = asm(
            [
                LayerSpec("a", "conv", out_ch=4, kernel=3),
                LayerSpec("b", "conv", out_ch=4, kernel=3),
                LayerSpec("m", "conv", inputs=["a", "b"], out_ch=4,
                          kernel=1),
            ],
            ["m"],
        )
        plan = plan_program(p)
        assert plan.dead_words == ()
        # the concat consumer reads one 8-channel extent; liveness must
        # walk it back to BOTH member regions
        assert set(plan.words[2].free_after) == {
            p.words[0].out_addr, p.words[1].out_addr,
        }

    def test_binary_add_second_operand_read_via_ext_addr2(self):
        p = asm(
            [
                LayerSpec("a", "conv", out_ch=4, kernel=3),
                LayerSpec("b", "conv", out_ch=4, kernel=3),
                LayerSpec("s", "add", inputs=["a", "b"]),
            ],
            ["s"],
        )
        plan = plan_program(p)
        assert plan.dead_words == ()          # b is live ONLY via ext_addr2
        assert set(plan.words[2].free_after) == {
            p.words[0].out_addr, p.words[1].out_addr,
        }


class TestElimination:
    def dead_branch_program(self):
        return asm(
            [
                LayerSpec("c1", "conv", out_ch=4, kernel=3),
                LayerSpec("dead", "conv", inputs=["c1"], out_ch=8,
                          kernel=3),
                LayerSpec("c2", "conv", inputs=["c1"], out_ch=2,
                          kernel=1),
            ],
            ["c2"],
        )

    def test_unreachable_word_is_dead(self):
        plan = plan_program(self.dead_branch_program())
        assert plan.dead_words == (1,)
        assert plan.schedule == (0, 2)
        assert 1 not in plan.words

    def test_optimize_program_removes_and_remaps(self):
        p = self.dead_branch_program()
        opt = optimize_program(p)
        assert len(opt.words) == 2
        assert [opt.layer_specs[i].name for i in range(2)] == ["c1", "c2"]
        assert set(opt.weight_bindings.values()) == {"c1", "c2"}
        assert opt.outputs == p.outputs
        assert opt.addr_shapes == p.addr_shapes     # layout untouched
        assert plan_program(opt).dead_words == ()

    def test_optimize_is_identity_without_dead_words(self):
        p = chain_program()
        assert optimize_program(p) is p

    def test_register_only_cache_is_dead_store(self):
        # c1 caches into the res register; c2 reads the INPUT plane and
        # adds the register.  c1's arena region is never read -> it must
        # execute (the register needs its value) but skip the store.
        p = asm(
            [
                LayerSpec("c1", "conv", out_ch=4, kernel=3, res="cache"),
                LayerSpec("c2", "conv", out_ch=4, kernel=3, res="add"),
                LayerSpec("c3", "conv", inputs=["c2"], out_ch=2,
                          kernel=1),
            ],
            ["c3"],
        )
        plan = plan_program(p)
        assert plan.dead_words == ()
        assert plan.dead_stores == (0,)
        assert plan.words[0].store is False
        assert plan.words[1].drop_cache is True
        assert p.words[0].out_addr not in plan.slot_of

    def test_cached_and_read_region_is_stored(self):
        # here the cache source is ALSO read from the arena -> real store
        p = asm(
            [
                LayerSpec("c1", "conv", out_ch=4, kernel=3, res="cache"),
                LayerSpec("c2", "conv", inputs=["c1"], out_ch=4,
                          kernel=3, res="add"),
            ],
            ["c2"],
        )
        plan = plan_program(p)
        assert plan.dead_stores == ()
        assert plan.words[0].store is True
        assert plan.words[1].drop_cache is True

    def test_res_add_with_empty_cache_raises(self):
        with pytest.raises(ValueError, match="empty cache"):
            plan_program(asm(
                [LayerSpec("c1", "conv", out_ch=4, kernel=3, res="add")],
                ["c1"],
            ))

    def test_duplicate_out_addr_falls_back_to_identity_plan(self):
        p = chain_program()
        p.words[1] = dataclasses.replace(
            p.words[1], out_addr=p.words[0].out_addr)
        plan = plan_program(p)
        assert plan.dead_words == ()
        assert plan.peak_bytes == plan.naive_bytes
        assert all(wp.free_after == () for wp in plan.words.values())


class TestAdmissibleBatch:
    def test_floor_division_of_budget(self):
        assert admissible_batch(100, 450) == 4
        assert admissible_batch(100, 99) == 1       # never below the floor

    def test_rounds_down_to_multiple(self):
        assert admissible_batch(100, 790, multiple=4) == 4
        assert admissible_batch(100, 1600, multiple=4) == 16

    def test_never_below_multiple_or_floor(self):
        assert admissible_batch(100, 100, multiple=4) == 4
        assert admissible_batch(100, 250, floor=2) == 2
        assert admissible_batch(0, 1000) == 1       # degenerate plans
        assert admissible_batch(100, 0) == 1


class TestPlanDisassembly:
    def test_deterministic_and_annotated(self):
        p = chain_program()
        a = plan_disassembly(p)
        assert a == plan_disassembly(p)
        assert "# memplan: words=3 live=3" in a
        assert "# bytes: peak=2048" in a
        assert "# slots: n=2" in a
        assert "fuse_relu" in a                      # c1 carries the relu bit

    def test_dead_words_dropped_from_text(self):
        text = plan_disassembly(TestElimination().dead_branch_program())
        assert "dead_words=1" in text
        # only live words get annotation rows
        assert "# w001" not in text
        assert "# w000" in text and "# w002" in text


class TestZooPlans:
    @pytest.fixture(scope="class")
    def model(self):
        from repro.models.fcn import DetectionModel, build_head
        from repro.models.fcn.pixellink import STDConfig

        return DetectionModel(
            STDConfig(name="pixellink_vgg16", backbone="vgg16",
                      width=0.125, image_size=(64, 64),
                      merge_ch=(16, 16, 8), mode="reference",
                      storage_fp16=False),
            build_head("pixellink"),
        )

    def test_real_head_halves_the_naive_footprint(self, model):
        plan = plan_program(model.program)
        assert plan.dead_words == ()
        assert plan.dead_stores == ()
        assert plan.reduction > 0.5
        assert plan.peak_bytes < plan.pool_bytes <= plan.naive_bytes

    def test_fusion_facts_present(self, model):
        plan = plan_program(model.program)
        facts = list(plan.words.values())
        assert any(wp.fuse_relu for wp in facts)
        assert any(wp.fuse_upsample for wp in facts)


class TestUpsampleFlopModes:
    def upsample_program(self):
        return asm([LayerSpec("up", "upsample", out_ch=4)], ["up"])

    def test_optimized_counts_fused_macs(self):
        p = self.upsample_program()
        macs = fuse.upsample_mac_counts(HW[0], HW[1], 3, 4)
        opt = program_band_costs(p, mode="optimized")["flops"]
        ref = program_band_costs(p, mode="reference")["flops"]
        # fused path: one 9-tap MAC per INPUT position (4x fewer); the
        # cost model pins exactly the 75% MAC reduction of
        # fuse.upsample_mac_counts — mode="optimized" is the default
        assert opt == 2.0 * 9 * 3 * 4 * HW[0] * HW[1]
        assert ref == 4.0 * opt
        assert opt / ref == pytest.approx(1.0 - macs["reduction"])
        assert program_band_costs(p)["flops"] == opt

    def test_nearest_upsample_unaffected_by_mode(self):
        p = asm([LayerSpec("up", "upsample", out_ch=4,
                           upsample_mode="nearest")], ["up"])
        assert (program_band_costs(p, mode="optimized")["flops"]
                == program_band_costs(p, mode="reference")["flops"])

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError):
            program_band_costs(self.upsample_program(), mode="eager")


class TestPlannerFeatures:
    def test_features_carry_act_bytes(self):
        p = chain_program()
        f = features_for_program(p, 1)
        assert f.act_bytes == float(plan_program(p).peak_bytes)
        f16 = features_for_program(p, 1, dtype_bytes=2)
        assert f16.act_bytes == f.act_bytes / 2

    def test_step_cost_memory_term_monotone(self):
        base = PlanFeatures(flops=1e9, halo_bytes=0.0, deepest_stride=32)
        heavy = dataclasses.replace(base, act_bytes=1e9)
        c0 = step_cost(base, "single_device", 4)
        c1 = step_cost(heavy, "single_device", 4)
        assert c1 > c0
        # act_bytes defaults to 0 -> legacy features cost the same as
        # before the memory term existed
        assert c0 == step_cost(
            dataclasses.replace(base, act_bytes=0.0), "single_device", 4)

    def test_cost_params_dict_back_compat(self):
        # pre-memplan JSON files carry no hbm_bw field; loading them
        # must fall back to the default, not crash
        p = cost_params_from_dict({"peak_flops": 1e12})
        assert p.hbm_bw == CostParams().hbm_bw


class TestByteWeightedLRU:
    def test_evicts_lru_first_over_budget(self):
        c = LRUCache(capacity=10, byte_budget=100)
        c.put("a", 1, weight=60)
        c.put("b", 2, weight=60)
        assert "a" not in c and "b" in c
        assert c.weight_bytes == 60

    def test_most_recent_entry_always_survives(self):
        c = LRUCache(capacity=10, byte_budget=100)
        c.put("a", 1, weight=60)
        c.put("big", 2, weight=500)       # over budget alone: still kept
        assert "big" in c and "a" not in c
        assert len(c) == 1

    def test_zero_budget_disables_byte_rule(self):
        c = LRUCache(capacity=10)
        c.put("a", 1, weight=10**12)
        c.put("b", 2, weight=10**12)
        assert "a" in c and "b" in c

    def test_unweighted_entries_count_zero(self):
        c = LRUCache(capacity=10, byte_budget=100)
        c.put("a", 1)
        c.put("b", 2, weight=90)
        assert "a" in c and "b" in c
        assert c.weight_bytes == 90


class TestBatcherBucketCaps:
    def caps(self, key):
        return {"big": 2, "small": 16}.get(key, 0)

    def test_cap_replaces_max_batch(self):
        mb = MicroBatcher(lambda k, ps: ps, max_batch=8,
                          max_batch_for=self.caps)
        assert mb._cap("big") == 2
        assert mb._cap("small") == 16      # raised ABOVE max_batch
        assert mb._cap("other") == 8       # <=0 falls back

    def test_cap_errors_fall_back_to_max_batch(self):
        def boom(key):
            raise RuntimeError("no plan")

        mb = MicroBatcher(lambda k, ps: ps, max_batch=8,
                          max_batch_for=boom)
        assert mb._cap("big") == 8

    def test_capped_bucket_flushes_at_cap(self):
        clock = FakeClock()
        mb = MicroBatcher(lambda k, ps: [x * 2 for x in ps],
                          max_batch=8, max_wait_ms=5.0, clock=clock,
                          inflight=0, max_batch_for=self.caps)
        with mb:
            futs = [mb.submit("big", i) for i in range(4)]
            assert [f.result(timeout=30) for f in futs] == [0, 2, 4, 6]
            futs = [mb.submit("small", i) for i in range(3)]
            clock.advance(0.01)
            assert [f.result(timeout=30) for f in futs] == [0, 2, 4]
        flushed = [(b["key"], b["n"], b["reason"])
                   for b in mb.stats["batches"]]
        assert flushed.count(("big", 2, "full")) == 2
        assert all(n <= 2 for k, n, _ in flushed if k == "big")
        assert ("small", 3, "timeout") in flushed


class TestServiceIntegration:
    @pytest.fixture(scope="class")
    def svc(self):
        from repro.launch.serve import STDService

        # budget = 2 images' worth of the 64x64 plan -> cap 2 < max 4
        return STDService(width=0.125, buckets=(64,), max_batch=4,
                          engine_cache_capacity=0, book=CostBook(warmup=0),
                          activation_budget_bytes=2 * 262144)

    def test_bucket_cap_from_plan(self, svc):
        per_img = svc.factory.memplan((64, 64)).peak_bytes
        assert svc._bucket_cap((64, 64)) == admissible_batch(
            per_img, svc.activation_budget_bytes)
        assert svc._bucket_cap((64, 64)) < svc.max_batch

    def test_engine_weight_is_plan_peak_times_batch(self, svc):
        fac = svc.factory
        assert fac.engine_weight_bytes((64, 64), 3) == \
            3 * fac.memplan((64, 64)).peak_bytes
        # bfp engines store fp16 activations: half the planned bytes
        assert fac.memplan((64, 64), "bfp").peak_bytes == \
            fac.memplan((64, 64)).peak_bytes // 2

    def test_engine_memory_gauges_exported(self, svc):
        row = svc.measure_engine_memory((64, 64), batch=1)
        assert row["planned_peak_bytes"] == \
            svc.factory.memplan((64, 64)).peak_bytes
        snap = svc.metrics_snapshot()
        lbl = 'bucket="64x64",batch="1",plan="single_device"'
        planned = [k for k in snap
                   if k.startswith("std_engine_planned_peak_bytes")
                   and lbl in k and 'model="pixellink"' in k]
        assert len(planned) == 1
        assert snap[planned[0]] == float(row["planned_peak_bytes"])
        assert any(k.startswith("std_bucket_batch_cap{bucket=\"64x64\"")
                   for k in snap)
        if "temp_bytes" in row:          # backend exposes memory_analysis
            assert any(k.startswith("std_engine_temp_bytes") and lbl in k
                       for k in snap)
            # planned-vs-measured sanity: same order of magnitude (XLA
            # fuses aggressively, so only a generous band is stable)
            ratio = row["temp_bytes"] / row["planned_peak_bytes"]
            assert 0.1 < ratio < 50.0

    def test_lifetime_sentinel_exceeds_any_program(self):
        assert _END > 10**6
        assert isinstance(MemPlan.reduction, property)
