"""Device-postprocess parity suite: log-hop CC labeling and the Pallas
CCL kernel against the union-find oracle, on-device box extraction
against the host tail, the serpentine worst case that motivated pointer
jumping, the single-pass host extraction against its quadratic
reference, the best-IoU f_measure regression, and the STDService
device-postprocess wiring (overflow fallback + non-convergence counter).
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # bare interpreter: seeded fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.cc_label import cc_label_pallas, cc_label_ref
from repro.models.fcn import postprocess as pp

# fixed shape pool: repeated shapes keep the jitted pallas/batched calls
# cache-warm across property examples; the non-16-multiples exercise the
# phase-1 zero-padding path
SHAPES = ((8, 12), (13, 9), (16, 16), (24, 20))


def rand_maps(seed, H, W, p_link=0.5):
    """Random score/link planes around the 0.5 thresholds."""
    rng = np.random.default_rng(seed)
    score = rng.uniform(0.0, 1.0, (H, W)).astype(np.float32)
    links = (rng.uniform(0.0, 1.0, (H, W, 8)) < p_link).astype(np.float32)
    return score, links


def canon(labels):
    """Canonical relabeling (first appearance in row-major order) — the
    oracle roots components at the MIN linear index, cc_label at the MAX,
    so labelings compare canonically.  Fresh mapping per call."""
    labels = np.asarray(labels)
    mapping = {}
    out = np.zeros_like(labels, dtype=np.int32)
    for y in range(labels.shape[0]):
        for x in range(labels.shape[1]):
            v = int(labels[y, x])
            if v:
                out[y, x] = mapping.setdefault(v, len(mapping) + 1)
    return out


def serpentine_maps(S):
    """One S*S-pixel component linked only along a boustrophedon path —
    graph diameter S*S, the worst case for one-hop label propagation."""
    DIR = {off: d for d, off in enumerate(pp.NEIGHBORS)}
    score = np.ones((S, S), np.float32)
    links = np.zeros((S, S, 8), np.float32)
    for y in range(S):
        if y % 2 == 0:
            for x in range(S - 1):
                links[y, x, DIR[(0, 1)]] = 1.0
            end = S - 1
        else:
            for x in range(S - 1, 0, -1):
                links[y, x, DIR[(0, -1)]] = 1.0
            end = 0
        if y + 1 < S:
            links[y, end, DIR[(1, 0)]] = 1.0
    return score, links


class TestLogHop:
    """hop="log" pointer jumping: same components as the union-find
    oracle, same label VALUES as the legacy one-hop spread."""

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from(range(len(SHAPES))),
           st.sampled_from((0.3, 0.5, 0.7)))
    def test_matches_oracle_and_one_hop(self, seed, si, p_link):
        H, W = SHAPES[si]
        score, links = rand_maps(seed, H, W, p_link)
        log, _, conv = pp.cc_label_stats(
            jnp.asarray(score), jnp.asarray(links), hop="log")
        assert bool(conv)
        log = np.asarray(log)
        want = pp.cc_label_numpy(score, links)
        assert np.array_equal(log > 0, want > 0)
        assert np.array_equal(canon(log), canon(want))
        # both hops converge to component max linear index + 1: exact
        one = np.asarray(pp.cc_label(
            jnp.asarray(score), jnp.asarray(links), hop="one",
            max_iters=2048))
        assert np.array_equal(log, one)

    def test_unknown_hop_rejected(self):
        score, links = rand_maps(0, 8, 8)
        with pytest.raises(ValueError, match="unknown hop"):
            pp.cc_label(jnp.asarray(score), jnp.asarray(links), hop="warp")

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_batched_valid_mask(self, seed):
        """Batched log-hop with per-image valid regions: each row equals
        the unbatched call on the masked plane, zero outside the mask."""
        N, H, W = 3, 16, 16
        rng = np.random.default_rng(seed)
        score = rng.uniform(0, 1, (N, H, W)).astype(np.float32)
        links = (rng.uniform(0, 1, (N, H, W, 8)) < 0.6).astype(np.float32)
        mask = np.zeros((N, H, W), bool)
        for i, (vh, vw) in enumerate(((16, 16), (9, 12), (12, 7))):
            mask[i, :vh, :vw] = True
        out, iters, conv = pp.cc_label_batched(
            jnp.asarray(score), jnp.asarray(links),
            valid_mask=jnp.asarray(mask), return_stats=True)
        assert conv.shape == (N,) and iters.shape == (N,)
        assert bool(conv.all())
        out = np.asarray(out)
        for i in range(N):
            masked = np.where(mask[i], score[i], 0.0).astype(np.float32)
            want = np.asarray(pp.cc_label(jnp.asarray(masked),
                                          jnp.asarray(links[i])))
            assert np.array_equal(out[i], want)
            assert (out[i][~mask[i]] == 0).all()


class TestSerpentine:
    """The worst case pointer jumping exists for: one serpentine
    component of diameter S*S."""

    def test_log_hop_bound_s16(self):
        score, links = serpentine_maps(16)
        labels, iters, conv = pp.cc_label_stats(
            jnp.asarray(score), jnp.asarray(links), hop="log")
        assert bool(conv)
        # single component; every label is the max linear index + 1
        assert np.array_equal(np.asarray(labels),
                              np.full((16, 16), 256, np.int32))
        # pointer jumping squares the reach: a 256-pixel chain must close
        # in ~2*log2 rounds, not ~256
        assert int(iters) <= 2 * math.ceil(math.log2(256)) + 4

    def test_log_hop_beats_one_hop_s32(self):
        score, links = serpentine_maps(32)
        sj, lj = jnp.asarray(score), jnp.asarray(links)
        log_lab, log_it, log_conv = pp.cc_label_stats(sj, lj, hop="log")
        one_lab, one_it, one_conv = pp.cc_label_stats(sj, lj, hop="one",
                                                      max_iters=1024)
        assert bool(log_conv) and bool(one_conv)
        assert np.array_equal(np.asarray(log_lab), np.asarray(one_lab))
        # a 1024-pixel chain: one-hop needs ~diameter rounds, log-hop
        # stays an order of magnitude under it
        assert int(one_it) > 8 * int(log_it)

    def test_one_hop_exhaustion_reported(self):
        """max_iters hit while still changing must report converged=False
        (the silently-wrong case the serving counter exists for)."""
        score, links = serpentine_maps(32)
        _, iters, conv = pp.cc_label_stats(
            jnp.asarray(score), jnp.asarray(links), hop="one",
            max_iters=20)
        assert not bool(conv)
        assert int(iters) == 20


class TestPallasCCL:
    """cc_label_pallas (interpret mode off-TPU) against the pure-jnp
    reference (exact) and the union-find oracle (canonical)."""

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from(range(len(SHAPES))),
           st.sampled_from((0.4, 0.6)))
    def test_matches_ref_and_oracle(self, seed, si, p_link):
        H, W = SHAPES[si]
        score, links = rand_maps(seed, H, W, p_link)
        sj, lj = jnp.asarray(score), jnp.asarray(links)
        got, iters, conv = cc_label_pallas(sj, lj, th=8, tw=8,
                                           return_stats=True)
        assert bool(conv) and int(iters) >= 0
        got = np.asarray(got)
        # same label VALUES as the log-hop reference, not just the same
        # partition: both fixpoints are component max linear index + 1
        assert np.array_equal(got, np.asarray(cc_label_ref(sj, lj)))
        want = pp.cc_label_numpy(score, links)
        assert np.array_equal(canon(got), canon(want))

    def test_batched_with_valid_mask(self):
        """Batched + padded bucket semantics: the padding mask zeroes
        scores exactly like cc_label_batched's."""
        N, H, W = 3, 24, 20
        rng = np.random.default_rng(11)
        score = rng.uniform(0, 1, (N, H, W)).astype(np.float32)
        links = (rng.uniform(0, 1, (N, H, W, 8)) < 0.6).astype(np.float32)
        mask = np.zeros((N, H, W), bool)
        for i, (vh, vw) in enumerate(((24, 20), (17, 13), (8, 20))):
            mask[i, :vh, :vw] = True
        sj, lj, mj = jnp.asarray(score), jnp.asarray(links), jnp.asarray(mask)
        got, _, conv = cc_label_pallas(sj, lj, valid_mask=mj, th=8, tw=8,
                                       return_stats=True)
        assert conv.shape == (N,) and bool(conv.all())
        want = pp.cc_label_batched(sj, lj, valid_mask=mj)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        assert (np.asarray(got)[~mask] == 0).all()

    def test_tile_crossing_component(self):
        """One component spanning all four 8x8 tiles of a 16x16 plane:
        phase 2 must stitch what phase 1 cannot see."""
        score = np.zeros((16, 16), np.float32)
        score[8, :] = 1.0            # horizontal bar crossing tile cols
        score[:, 8] = 1.0            # vertical bar crossing tile rows
        links = np.ones((16, 16, 8), np.float32)
        got = np.asarray(cc_label_pallas(jnp.asarray(score),
                                         jnp.asarray(links), th=8, tw=8))
        pos = score > 0.5
        assert (got[pos] == got[8, 8]).all()     # one component
        assert (got[~pos] == 0).all()


class TestBoxes:
    """Single-pass host extraction vs the quadratic reference, and the
    device compact rows vs the host tail."""

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from(range(len(SHAPES))),
           st.sampled_from((1, 3)))
    def test_single_pass_matches_reference(self, seed, si, min_area):
        H, W = SHAPES[si]
        score, links = rand_maps(seed, H, W)
        labels = pp.cc_label_numpy(score, links)
        assert pp.boxes_from_labels(labels, min_area) == \
            pp.boxes_from_labels_reference(labels, min_area)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from(range(len(SHAPES))))
    def test_device_rows_match_host(self, seed, si):
        H, W = SHAPES[si]
        score, links = rand_maps(seed, H, W)
        labels = pp.cc_label(jnp.asarray(score), jnp.asarray(links))
        host = pp.boxes_from_labels(np.asarray(labels))
        rows, n = pp.boxes_from_labels_jax(labels, capacity=64)
        assert int(n) == len(host)               # exact component count
        rows = np.asarray(rows)
        assert pp.boxes_from_compact(rows) == host
        assert pp.boxes_from_compact(rows, min_area=3) == \
            pp.boxes_from_labels(np.asarray(labels), min_area=3)

    def test_overflow_detected_exactly(self):
        # 9 isolated positive pixels, no links -> 9 singleton components
        score = np.zeros((8, 8), np.float32)
        score[::3, ::3] = 1.0
        links = np.zeros((8, 8, 8), np.float32)
        labels = pp.cc_label(jnp.asarray(score), jnp.asarray(links))
        _, n_small = pp.boxes_from_labels_jax(labels, capacity=4)
        assert int(n_small) == 9                 # count exact past capacity
        rows, n = pp.boxes_from_labels_jax(labels, capacity=16)
        assert int(n) == 9
        assert pp.boxes_from_compact(np.asarray(rows)) == \
            pp.boxes_from_labels(np.asarray(labels))

    def test_batched_rows_match_per_image(self):
        score0, links0 = rand_maps(3, 16, 16)
        score1, links1 = rand_maps(4, 16, 16)
        labels = pp.cc_label_batched(
            jnp.asarray(np.stack([score0, score1])),
            jnp.asarray(np.stack([links0, links1])))
        rows, counts = pp.boxes_from_labels_batched_jax(labels, capacity=32)
        assert rows.shape == (2, 33, 6) and counts.shape == (2,)
        for i in range(2):
            want_rows, want_n = pp.boxes_from_labels_jax(labels[i],
                                                         capacity=32)
            assert np.array_equal(np.asarray(rows[i]),
                                  np.asarray(want_rows))
            assert int(counts[i]) == int(want_n)

    def test_empty_plane(self):
        labels = jnp.zeros((8, 8), jnp.int32)
        rows, n = pp.boxes_from_labels_jax(labels, capacity=4)
        assert int(n) == 0
        assert (np.asarray(rows) == 0).all()
        assert pp.boxes_from_compact(np.asarray(rows)) == []


class TestFMeasure:
    def test_perfect_match(self):
        preds = [{"label": 1, "box": (0, 0, 9, 9), "area": 100}]
        m = pp.f_measure(preds, [(0, 0, 9, 9)])
        assert m == {"precision": 1.0, "recall": 1.0,
                     "f_measure": pytest.approx(1.0)}

    def test_best_iou_not_first_past_threshold(self):
        """Overlapping GTs: P1 overlaps A at 0.538 and B at 0.667, P2
        overlaps A at 1.0 but B only at 0.33.  First-past-threshold
        matching burns A on P1 (its first IoU >= 0.5) and strands P2 at
        tp=1; best-IoU matching pairs P1-B and P2-A for tp=2."""
        gts = [(0, 0, 9, 9), (5, 0, 14, 9)]               # A, B
        preds = [{"label": 1, "box": (3, 0, 12, 9), "area": 100},   # P1
                 {"label": 2, "box": (0, 0, 9, 9), "area": 100}]    # P2
        m = pp.f_measure(preds, gts)
        assert m["precision"] == 1.0 and m["recall"] == 1.0


class TestServiceDevicePostprocess:
    """STDService(postprocess="device") wiring: box parity with the host
    tail on sync and batched paths, the overflow fallback, and the
    non-convergence counter."""

    @pytest.fixture(scope="class")
    def images(self):
        rng = np.random.default_rng(0)
        return [rng.uniform(0, 1, (int(rng.integers(48, 65)),
                                   int(rng.integers(48, 65)), 3)
                            ).astype(np.float32) for _ in range(6)]

    @pytest.fixture(scope="class")
    def host_svc(self):
        from repro.launch.serve import STDService

        return STDService(width=0.125, buckets=(64,), max_batch=2)

    def test_sync_and_batched_parity(self, images, host_svc):
        from repro.launch.serve import STDService

        dev = STDService(width=0.125, buckets=(64,), max_batch=2,
                         postprocess="device")
        want = [host_svc(img) for img in images]
        assert [dev(img) for img in images] == want
        assert dev.serve_batched(images) == want
        assert dev.stats["pp_overflow"] == 0
        # the tail walls landed under their own stage, keyed by kind
        kinds = {k[2] for k in dev.book.step_keys(stage="postprocess")}
        assert kinds == {"device"}
        assert {k[2] for k in host_svc.book.step_keys(stage="postprocess")} \
            == {"host"}

    def test_overflow_falls_back_to_host_tail(self, images, host_svc):
        """boxes_capacity=1 overflows on any multi-component image: the
        per-image fallback must keep boxes exactly right and count every
        overflow."""
        from repro.launch.serve import STDService

        dev = STDService(width=0.125, buckets=(64,), max_batch=2,
                         postprocess="device", boxes_capacity=1)
        assert [dev(img) for img in images] == \
            [host_svc(img) for img in images]
        assert dev.stats["pp_overflow"] > 0
        assert dev.book.counter("pp_overflow") == dev.stats["pp_overflow"]

    def test_nonconverged_counter(self, host_svc):
        host_svc._count_nonconverged(np.array([True, False, True, False]))
        assert host_svc.stats["nonconverged"] >= 2
        assert host_svc.book.counter("pp_nonconverged") >= 2

    def test_bad_config_rejected(self):
        from repro.launch.serve import STDService

        with pytest.raises(ValueError, match="postprocess"):
            STDService(width=0.125, postprocess="gpu")
        with pytest.raises(ValueError, match="boxes_capacity"):
            STDService(width=0.125, postprocess="device", boxes_capacity=0)
