"""Dynamic micro-batching scheduler tests: bucket grouping, full/timeout
flush (on the deterministic FakeClock harness — no real sleeps),
admission control, error propagation, the engine LRU, batched cc_label
vs the per-image reference, and end-to-end batched-vs-single-image box
parity (including the §IV.B transposed over-wide path)."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.batching import (FakeClock, LatencyRecorder, LRUCache,
                                   MicroBatcher, round_batch)
from repro.models.fcn import postprocess as pp


class TestRoundBatch:
    def test_pow2(self):
        assert [round_batch(n, 8) for n in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]

    def test_none(self):
        assert round_batch(5, 8, "none") == 5

    def test_unknown_mode(self):
        with pytest.raises(ValueError):
            round_batch(1, 8, "round-to-11")


class TestLRUCache:
    def test_evicts_least_recently_used(self):
        c = LRUCache(2)
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1          # refresh "a"
        c.put("c", 3)
        assert "b" not in c and "a" in c and "c" in c
        assert len(c) == 2

    def test_unbounded_when_capacity_zero(self):
        c = LRUCache(0)
        for i in range(64):
            c.put(i, i)
        assert len(c) == 64


class TestMicroBatcher:
    def test_groups_by_bucket_and_flushes_full(self):
        seen = []

        def infer(key, payloads):
            seen.append((key, list(payloads)))
            return [f"{key}:{p}" for p in payloads]

        with MicroBatcher(infer, max_batch=2, max_wait_ms=10_000) as mb:
            futs = [mb.submit(k, i) for i, k in
                    enumerate(["a", "b", "a", "b"])]
            got = [f.result(timeout=10) for f in futs]
        assert got == ["a:0", "b:1", "a:2", "b:3"]
        # every batch is single-bucket and flushed at max_batch
        assert sorted(k for k, ps in seen) == ["a", "b"]
        assert all(len(ps) == 2 for _, ps in seen)
        assert mb.stats["flush_full"] == 2
        assert mb.stats["flush_timeout"] == 0

    def test_timeout_flush_on_fake_clock(self):
        """Timeout flush driven entirely by the injected clock: the
        partial batch must NOT flush while fake time stands still (no
        flush reason can fire, so the assertions are race-free) and must
        flush exactly when the deadline passes — zero real sleeps."""
        clk = FakeClock()
        with MicroBatcher(lambda k, ps: ps, max_batch=8,
                          max_wait_ms=30, clock=clk) as mb:
            fut = mb.submit("a", 42)
            assert not fut.done()                # deadline not reached
            clk.advance(0.029)                   # 29 ms < 30 ms: still no
            assert not fut.done()
            clk.advance(0.002)                   # past the deadline
            assert fut.result(timeout=10) == 42
        assert mb.stats["flush_timeout"] == 1
        # latency accounting runs on the same clock: exactly the fake
        # interval, not wall time
        assert mb.stats["item_latency_s"] == [pytest.approx(0.031)]

    def test_timeout_flush_real_clock(self):
        """The default real-clock wait path still flushes on timeout."""
        with MicroBatcher(lambda k, ps: ps, max_batch=8,
                          max_wait_ms=30) as mb:
            t0 = time.perf_counter()
            fut = mb.submit("a", 42)
            assert fut.result(timeout=10) == 42
            dt = time.perf_counter() - t0
        assert mb.stats["flush_timeout"] == 1
        assert dt >= 0.025                       # waited for the deadline

    def test_timeout_flush_with_alternate_real_clock(self):
        """Any plain real-seconds callable works as the clock — only
        clocks that publish advances (subscribe) switch the scheduler
        to event-driven waits (regression: an identity check against
        perf_counter used to leave e.g. time.monotonic waiting forever
        on a partial batch)."""
        with MicroBatcher(lambda k, ps: ps, max_batch=8,
                          max_wait_ms=30, clock=time.monotonic) as mb:
            fut = mb.submit("a", 42)
            assert fut.result(timeout=10) == 42
        assert mb.stats["flush_timeout"] == 1

    def test_stop_drains_pending(self):
        mb = MicroBatcher(lambda k, ps: ps, max_batch=8,
                          max_wait_ms=60_000).start()
        futs = [mb.submit("a", i) for i in range(3)]
        mb.stop()                                # must flush, not strand
        assert [f.result(timeout=1) for f in futs] == [0, 1, 2]
        assert mb.stats["flush_drain"] >= 1
        with pytest.raises(RuntimeError):
            mb.submit("a", 99)

    def test_infer_error_propagates_to_futures(self):
        def infer(key, payloads):
            raise RuntimeError("engine on fire")

        with MicroBatcher(infer, max_batch=2, max_wait_ms=5) as mb:
            fut = mb.submit("a", 1)
            with pytest.raises(RuntimeError, match="engine on fire"):
                fut.result(timeout=10)

    def test_post_fn_runs_per_item(self):
        with MicroBatcher(lambda k, ps: ps,
                          post_fn=lambda payload, out: out * 10,
                          max_batch=2, max_wait_ms=5) as mb:
            futs = [mb.submit("a", i) for i in range(4)]
            assert [f.result(timeout=10) for f in futs] == [0, 10, 20, 30]

    def test_admission_reject_sheds_at_max_pending(self):
        from repro.launch.batching import QueueFull

        # no flush can fire (batch never full, timeout far away), so the
        # queue deterministically sits at max_pending when the 3rd
        # request arrives
        mb = MicroBatcher(lambda k, ps: ps, max_batch=8,
                          max_wait_ms=10_000, max_pending=2,
                          admission="reject").start()
        try:
            futs = [mb.submit("a", 0), mb.submit("a", 1)]
            with pytest.raises(QueueFull):
                mb.submit("a", 2)
        finally:
            mb.stop()                     # drains the two admitted items
        assert mb.stats["rejected"] == 1
        assert mb.stats["submitted"] == 2
        assert [f.result(timeout=5) for f in futs] == [0, 1]

    def test_admission_block_applies_backpressure(self):
        release = threading.Event()

        def infer(key, payloads):
            release.wait(5)
            return payloads

        done = []
        # max_batch=1 + queue_depth=1 + blocked infer: one batch in
        # flight, one queued, the scheduler stuck handing off a third,
        # a fourth item pending -> the fifth submit must block
        with MicroBatcher(infer, max_batch=1, max_wait_ms=10_000,
                          queue_depth=1, max_pending=1,
                          admission="block") as mb:
            futs = [mb.submit("a", i) for i in range(4)]

            def blocked_client():
                futs.append(mb.submit("a", 4))
                done.append(time.perf_counter())

            t = threading.Thread(target=blocked_client)
            t.start()
            time.sleep(0.2)
            assert not done               # backpressure held the caller
            release.set()                 # infer drains -> space frees
            t.join(timeout=5)
            assert done
            assert [f.result(timeout=5) for f in futs] == list(range(5))
        assert mb.stats["rejected"] == 0
        assert mb.stats["submitted"] == 5

    def test_admission_block_freed_by_timeout_flush_on_fake_clock(self):
        """Backpressure release on the deterministic harness: with the
        queue at max_pending and the fake clock frozen, a blocking
        submit CANNOT return (no flush reason can fire) — advancing the
        clock past the deadline flushes, frees capacity, and admits the
        blocked request.  No real sleeps anywhere."""
        clk = FakeClock()
        done = []
        mb = MicroBatcher(lambda k, ps: ps, max_batch=8,
                          max_wait_ms=100, max_pending=2,
                          admission="block", clock=clk).start()
        try:
            futs = [mb.submit("a", 0), mb.submit("a", 1)]
            attempted = threading.Event()

            def blocked_client():
                attempted.set()
                futs.append(mb.submit("a", 2))
                done.append(True)

            t = threading.Thread(target=blocked_client)
            t.start()
            attempted.wait(5)
            # frozen clock + queue at cap: submit cannot have returned
            assert not done
            clk.advance(0.2)              # past the 100 ms deadline
            t.join(timeout=5)
            assert done                   # flush freed the slot
        finally:
            mb.stop()                     # drains the late admit
        assert [f.result(timeout=5) for f in futs] == [0, 1, 2]
        assert mb.stats["rejected"] == 0
        assert mb.stats["pending_peak"] == 2

    def test_concurrent_submitters(self):
        results = {}

        def client(i):
            results[i] = mb.submit(i % 2, i).result(timeout=10)

        with MicroBatcher(lambda k, ps: ps, max_batch=4,
                          max_wait_ms=10) as mb:
            ts = [threading.Thread(target=client, args=(i,))
                  for i in range(16)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        assert results == {i: i for i in range(16)}


class TestAdmissionStress:
    """Admission control under CONCURRENT producers: "reject" sheds
    exactly the overflow (every attempt is either admitted-and-served or
    counted shed), "block" never lets the pending queue exceed
    ``max_pending``, and shutdown strands no future."""

    N_PRODUCERS = 8
    PER_PRODUCER = 25

    def _hammer(self, mb, on_full):
        """Submit from N_PRODUCERS threads; returns (futures, sheds)."""
        from concurrent.futures import ThreadPoolExecutor

        def producer(i):
            futs, shed = [], 0
            for j in range(self.PER_PRODUCER):
                try:
                    futs.append(mb.submit(("b", i % 2), (i, j)))
                except on_full:
                    shed += 1
            return futs, shed

        with ThreadPoolExecutor(self.N_PRODUCERS) as ex:
            out = list(ex.map(producer, range(self.N_PRODUCERS)))
        return [f for futs, _ in out for f in futs], sum(s for _, s in out)

    def test_reject_sheds_exactly_the_overflow(self):
        from repro.launch.batching import QueueFull

        gate = threading.Event()

        def infer(key, payloads):
            gate.wait(5)                 # hold the drain so the queue fills
            return payloads

        total = self.N_PRODUCERS * self.PER_PRODUCER
        mb = MicroBatcher(infer, max_batch=4, max_wait_ms=1.0,
                          max_pending=8, admission="reject").start()
        try:
            futs, shed = self._hammer(mb, QueueFull)
            gate.set()
        finally:
            mb.stop()
        # exactness: every attempt is accounted once — admitted requests
        # all resolve, sheds all hit the counter, nothing double-counted
        assert len(futs) + shed == total
        assert shed > 0                  # the gate guaranteed overflow
        assert mb.stats["submitted"] == len(futs)
        assert mb.stats["rejected"] == shed
        assert all(f.done() for f in futs)
        got = {f.result(timeout=5) for f in futs}
        assert len(got) == len(futs)     # no result lost or duplicated

    def test_block_never_exceeds_max_pending(self):
        """The scheduler's own pending_peak stat (updated under the
        queue lock, so it is exact — no sampling-thread race) must never
        exceed the admission bound."""
        max_pending = 6

        def infer(key, payloads):
            time.sleep(0.002)            # keep producers ahead of drain
            return payloads

        mb = MicroBatcher(infer, max_batch=4, max_wait_ms=1.0,
                          max_pending=max_pending,
                          admission="block").start()
        try:
            futs, shed = self._hammer(mb, ())
        finally:
            mb.stop()
        assert shed == 0                 # block policy never raises
        assert len(futs) == self.N_PRODUCERS * self.PER_PRODUCER
        assert all(f.done() for f in futs)
        assert 0 < mb.stats["pending_peak"] <= max_pending
        assert mb.stats["rejected"] == 0

    def test_shutdown_strands_no_future(self):
        """stop() racing concurrent producers: every future handed out
        resolves (drain flush), late submitters get a clean error, and
        nothing hangs."""
        accepted = []
        errors = []
        lock = threading.Lock()

        def infer(key, payloads):
            time.sleep(0.002)
            return payloads

        mb = MicroBatcher(infer, max_batch=4, max_wait_ms=1.0,
                          max_pending=8, admission="block").start()

        def producer(i):
            for j in range(self.PER_PRODUCER):
                try:
                    f = mb.submit(("b", i % 2), (i, j))
                    with lock:
                        accepted.append(f)
                except RuntimeError:
                    with lock:
                        errors.append((i, j))
                    return               # scheduler is shutting down

        ts = [threading.Thread(target=producer, args=(i,))
              for i in range(self.N_PRODUCERS)]
        for t in ts:
            t.start()
        time.sleep(0.05)                 # let the queue get busy
        mb.stop()                        # drains everything admitted
        for t in ts:
            t.join(timeout=10)
        assert not any(t.is_alive() for t in ts)
        assert accepted                  # the race admitted some work
        assert all(f.done() for f in accepted), "stranded futures"
        for f in accepted:
            f.result(timeout=5)          # none poisoned by shutdown
        assert mb.stats["submitted"] == len(accepted)


class TestFinalizeArity:
    def test_short_finalize_fails_stranded_futures(self):
        """Regression: _complete_one zipped items against finalize_fn's
        output, so a finalize returning FEWER outputs than items
        silently stranded the tail futures and their callers hung
        forever.  Now the stranded futures fail loudly and the arity
        error is counted."""

        def finalize(key, raw):
            return raw[:-1]              # drops the last item's output

        with MicroBatcher(lambda k, ps: ps, finalize_fn=finalize,
                          max_batch=3, max_wait_ms=5) as mb:
            futs = [mb.submit("a", i) for i in range(3)]
            # the covered items still resolve normally...
            assert [f.result(timeout=10) for f in futs[:2]] == [0, 1]
            # ...and the stranded one raises instead of hanging
            with pytest.raises(RuntimeError, match="finalize_fn returned "
                                                   "2 outputs for 3"):
                futs[2].result(timeout=10)
        assert mb.stats["finalize_short"] == 1

    def test_padded_finalize_output_is_legal(self):
        """MORE outputs than live items is the padded-batch contract
        (STDService._mb_finalize returns the full padded batch axis) —
        it must not count as an arity error."""

        def finalize(key, raw):
            return list(raw) + ["pad"]

        with MicroBatcher(lambda k, ps: ps, finalize_fn=finalize,
                          max_batch=2, max_wait_ms=5) as mb:
            futs = [mb.submit("a", i) for i in range(2)]
            assert [f.result(timeout=10) for f in futs] == [0, 1]
        assert mb.stats["finalize_short"] == 0


class TestBucketFairness:
    def test_oldest_ready_bucket_beats_insertion_order(self):
        """Regression: _next_batch scanned self._pending in
        dict-insertion order and took the FIRST ready bucket, so an
        early bucket under sustained full-batch load starved a later
        bucket's timeout flush indefinitely.  With bucket "a" (inserted
        first) full but younger, and bucket "b" past its flush deadline
        with the older head request, "b" must flush first."""
        from collections import deque
        from concurrent.futures import Future

        from repro.launch.batching import _Item

        clk = FakeClock()
        mb = MicroBatcher(lambda k, ps: ps, max_batch=2, max_wait_ms=10,
                          clock=clk)

        # craft the pending state directly — the scheduler thread is
        # never started, so _next_batch runs synchronously here
        def put(key, t_submit):
            mb._pending.setdefault(key, deque()).append(
                _Item(key, None, Future(), t_submit))
            mb._n_pending += 1

        put("a", 0.5)                    # dict-insertion order: "a" first
        put("b", 0.0)                    # oldest head, below max_batch
        put("a", 0.5)                    # "a" now full (max_batch=2)
        clk.advance(0.6)                 # b's 10 ms deadline long past
        key, reason, items = mb._next_batch()
        assert (key, reason) == ("b", "timeout")
        assert len(items) == 1
        # with b flushed, the full bucket goes next
        key, reason, items = mb._next_batch()
        assert (key, reason) == ("a", "full")
        assert len(items) == 2

    def test_sustained_full_bucket_does_not_starve_timeout_flush(self):
        """End-to-end on the FakeClock: bucket "hot" is refilled to
        max_batch on every flush while lone bucket "cold" waits on its
        timeout — the cold request must still complete."""
        clk = FakeClock()
        with MicroBatcher(lambda k, ps: ps, max_batch=2, max_wait_ms=10,
                          clock=clk) as mb:
            cold = mb.submit("cold", "c")
            hot = [mb.submit("hot", i) for i in range(6)]
            clk.advance(0.011)           # cold's deadline passes
            assert cold.result(timeout=10) == "c"
            assert [f.result(timeout=10) for f in hot] == list(range(6))
        assert mb.stats["flush_timeout"] >= 1


class TestLatencyRecorderThreadSafety:
    def test_lost_update_hammer(self):
        """samples is appended from done-callback threads: N threads x
        PER futures must land exactly N*PER samples (the PR 4
        lost-update pattern — appends hold the recorder lock)."""
        rec = LatencyRecorder()
        N_THREADS, PER = 8, 200
        from concurrent.futures import Future

        def worker(i):
            for _ in range(PER):
                f = Future()
                rec.track(f)
                f.set_result(None)

        ts = [threading.Thread(target=worker, args=(i,))
              for i in range(N_THREADS)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=30)
        assert not any(t.is_alive() for t in ts)
        samples = rec.wait(timeout_s=30)
        assert len(samples) == N_THREADS * PER

    def test_wait_returns_snapshot_not_live_list(self):
        """Regression: wait() returned self.samples itself, so a caller
        sorting/percentiling the return value raced later-tracked
        futures' appends.  It must be a snapshot."""
        from concurrent.futures import Future

        rec = LatencyRecorder()
        f = Future()
        rec.track(f)
        f.set_result(None)
        first = rec.wait(timeout_s=10)
        assert len(first) == 1
        g = Future()
        rec.track(g)
        g.set_result(None)
        rec.wait(timeout_s=10)
        assert len(first) == 1           # the earlier snapshot is frozen
        assert first is not rec.samples


class TestHostPipeline:
    def test_ordered_results(self):
        from repro.runtime.pipeline import HostPipeline

        pipe = HostPipeline([lambda x: x * 2, lambda x: x + 1], maxsize=2)
        assert pipe.run(list(range(20))) == [x * 2 + 1 for x in range(20)]

    def test_stage_error_propagates_and_unwinds(self):
        from repro.runtime.pipeline import HostPipeline

        before = threading.active_count()

        def boom(x):
            if x == 3:
                raise RuntimeError("stage on fire")
            return x

        pipe = HostPipeline([lambda x: x, boom, lambda x: x], maxsize=2)
        with pytest.raises(RuntimeError, match="stage on fire"):
            # many more items than queue capacity: the feeder and upstream
            # stage must unwind instead of blocking on full queues forever
            pipe.run(list(range(50)))
        time.sleep(0.3)
        assert threading.active_count() <= before + 1


class TestBatchedCCLabel:
    def _rand_maps(self, n, h, w, seed):
        rng = np.random.default_rng(seed)
        score = rng.random((n, h, w)).astype(np.float32)
        links = rng.random((n, h, w, 8)).astype(np.float32)
        return score, links

    def test_matches_per_image_cc_label(self):
        score, links = self._rand_maps(3, 12, 16, 0)
        batched = np.asarray(pp.cc_label_batched(
            jnp.asarray(score), jnp.asarray(links), 0.6, 0.6
        ))
        for i in range(3):
            single = np.asarray(pp.cc_label(
                jnp.asarray(score[i]), jnp.asarray(links[i]), 0.6, 0.6
            ))
            np.testing.assert_array_equal(batched[i], single)

    def test_matches_union_find_oracle(self):
        score, links = self._rand_maps(2, 10, 10, 1)
        batched = np.asarray(pp.cc_label_batched(
            jnp.asarray(score), jnp.asarray(links), 0.55, 0.55
        ))
        for i in range(2):
            oracle = pp.cc_label_numpy(score[i], links[i], 0.55, 0.55)
            # label ids differ (max-index vs min-index convention is the
            # same here, but be strict): require identical partitions
            np.testing.assert_array_equal(batched[i] > 0, oracle > 0)
            for lab in np.unique(batched[i]):
                if lab == 0:
                    continue
                members = oracle[batched[i] == lab]
                assert len(np.unique(members)) == 1

    def test_valid_mask_blocks_padding_merges(self):
        # two positive regions joined only through the padding area: with
        # the mask they must stay separate components
        h, w = 8, 12
        score = np.zeros((1, h, w), np.float32)
        links = np.ones((1, h, w, 8), np.float32)
        score[0, 2, :] = 1.0                     # full row, crosses padding
        mask = np.zeros((1, h, w), bool)
        mask[0, :, :4] = True                    # valid: left 4 columns
        unmasked = np.asarray(pp.cc_label_batched(
            jnp.asarray(score), jnp.asarray(links)
        ))
        masked = np.asarray(pp.cc_label_batched(
            jnp.asarray(score), jnp.asarray(links),
            valid_mask=jnp.asarray(mask),
        ))
        assert (unmasked[0, 2] > 0).all()
        assert (masked[0, 2, :4] > 0).all()
        assert (masked[0, 2, 4:] == 0).all()


@pytest.fixture(scope="module")
def svc():
    from repro.launch.serve import STDService

    return STDService(width=0.125, buckets=(64, 128), max_batch=4,
                      max_wait_ms=20)


class TestBatchedServiceParity:
    def test_mixed_resolution_stream_matches_single(self, svc):
        from repro.data.images import RequestStream

        images = RequestStream(
            6, seed=3, hw_range=((48, 64), (48, 128))
        ).images()
        single = [svc(img) for img in images]
        batched = svc.serve_batched(images)
        assert [[b["box"] for b in r] for r in single] == \
               [[b["box"] for b in r] for r in batched]
        sizes = [b["n"] for b in svc.stats["batching"]["batches"]]
        assert max(sizes) >= 2                  # real batching happened
        assert svc.stats["batched_tps"] > 0

    def test_transposed_over_wide_in_batch(self, svc, monkeypatch):
        import repro.launch.serve as srv

        monkeypatch.setattr(srv, "MAX_WIDTH", 100)   # force the trick
        rng = np.random.default_rng(7)
        wide = rng.random((64, 120, 3)).astype(np.float32)  # w > limit
        normal = rng.random((56, 64, 3)).astype(np.float32)
        before = svc.stats["transposed"]
        single = [svc(wide), svc(normal)]
        batched = svc.serve_batched([wide, normal])
        assert svc.stats["transposed"] - before >= 2
        assert [[b["box"] for b in r] for r in single] == \
               [[b["box"] for b in r] for r in batched]

    def test_async_submit_api(self, svc):
        from repro.data.images import RequestStream

        img = next(iter(RequestStream(1, seed=9,
                                      hw_range=((48, 64), (48, 64)))))
        svc.start_batched()
        try:
            fut = svc.submit(img["image"])
            boxes = fut.result(timeout=60)
        finally:
            svc.stop_batched()
        assert boxes == svc(img["image"])

    def test_engine_cache_lru_eviction(self):
        from repro.launch.serve import STDService

        s = STDService(width=0.125, buckets=(64,), max_batch=4,
                       engine_cache_capacity=1)
        img = np.random.default_rng(0).random((48, 48, 3)).astype(np.float32)
        s(img)                                   # compiles ((64,64), 1)
        assert len(s._engines) == 1
        s.serve_batched([img, img])              # compiles ((64,64), 2)
        assert len(s._engines) == 1              # LRU evicted the first
        s(img)                                   # recompile, still capped
        assert len(s._engines) == 1
