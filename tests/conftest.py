"""Shared pytest fixtures.  NOTE: no XLA_FLAGS here — smoke tests and
benches must see the host's real (single) device; multi-device tests
spawn subprocesses that set --xla_force_host_platform_device_count
themselves (see tests/test_distributed.py)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
