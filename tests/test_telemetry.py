"""Telemetry/calibration layer tests (runtime/telemetry.py + the
planner's MeasuredCost overlay) — fast tier.

What the measured-cost loop guarantees, pinned here:

  * CostBook mutations are lock-guarded read-modify-write — the PR 4
    lost-update hammer pattern applied to the new store;
  * with no measurements the planner routes EXACTLY like the analytic
    model (the golden table in test_planner.py stays authoritative);
    with a synthetic measurement set loaded, a pinned (bucket, batch)
    decision provably flips — and flips ONLY past the observation
    floor;
  * the calibration fit is exact on noiselessly-generated measurements
    (the step model is linear in the constants), and fit -> save ->
    load round-trips to identical routing across the canonical grid.
"""
import json
import sys
import threading

import numpy as np
import pytest

from repro.runtime.planner import (
    AnalyticCost,
    CostParams,
    MeasuredCost,
    PlanFeatures,
    Planner,
    choose_kind,
    eligible_kinds,
    step_cost,
)
from repro.runtime.telemetry import (
    CostBook,
    StepMeasurement,
    cost_params_from_dict,
    cost_params_to_dict,
    fit_cost_params,
    load_cost_params,
    prometheus_text,
    save_cost_params,
)

# same crossover-friendly constants as test_planner.py
TEST_PARAMS = CostParams(
    peak_flops=5e9, ici_bw=1e9,
    dispatch_overhead_s=50e-6, collective_overhead_s=20e-6,
)


def tall_features(h: int, w: int = 64) -> PlanFeatures:
    return PlanFeatures(flops=2e5 * h * w / 64.0,
                        halo_bytes=3e4 * w / 64.0,
                        deepest_stride=32, halo_layers=20)


class TestCostBook:
    def test_warmup_skips_first_sample(self):
        """The first engine call jit-compiles inside the call — a
        multi-second one-off that must never reach the EWMA."""
        book = CostBook()                      # warmup=1 default
        book.record_step((64, 64), 1, "single_device", 5.0)  # compile
        assert book.step_count((64, 64), 1, "single_device") == 0
        book.record_step((64, 64), 1, "single_device", 0.01)
        assert book.step_count((64, 64), 1, "single_device") == 1
        assert book.step_ewma((64, 64), 1, "single_device") == 0.01

    def test_step_series_stats(self):
        book = CostBook(warmup=0, ewma_alpha=0.5)
        for v in (0.010, 0.020, 0.030):
            book.record_step((64, 64), 4, "grid", v)
        assert book.step_count((64, 64), 4, "grid") == 3
        # 0.5-EWMA: 0.010 -> 0.015 -> 0.0225
        assert book.step_ewma((64, 64), 4, "grid") == \
            pytest.approx(0.0225)
        assert book.step_percentile((64, 64), 4, "grid", 50) == 0.020
        assert book.step_percentile((64, 64), 4, "grid", 99) == 0.030
        assert book.step_keys() == [((64, 64), 4, "grid")]
        # stages are independent series
        assert book.step_count((64, 64), 4, "grid",
                               stage="dispatch") == 0

    def test_named_series_counters_gauges(self):
        book = CostBook(warmup=0)
        book.observe("mb_dispatch_s", 0.5)
        book.incr("mb_shed")
        book.incr("mb_shed", 2)
        book.set_gauge("pool_capacity", 7)
        assert book.counter("mb_shed") == 3
        assert book.gauge("pool_capacity") == 7.0
        snap = book.snapshot()
        assert snap["std_mb_shed_total"] == 3.0
        assert snap["std_pool_capacity"] == 7.0
        assert snap["std_mb_dispatch_s_count"] == 1.0
        assert snap["std_mb_dispatch_s_ewma"] == 0.5

    def test_snapshot_embeds_step_labels(self):
        book = CostBook(warmup=0)
        book.record_step((128, 64), 4, "row_band", 0.02)
        snap = book.snapshot()
        key = ('std_step_ewma_s{bucket="128x64",batch="4",'
               'plan="row_band",stage="step"}')
        assert snap[key] == 0.02

    def test_prometheus_text_parses(self):
        book = CostBook(warmup=0)
        book.record_step((128, 64), 4, "row_band", 0.02)
        book.incr("mb_shed")
        txt = prometheus_text(book.snapshot())
        assert txt.endswith("\n")
        for line in txt.strip().splitlines():
            name, value = line.rsplit(" ", 1)
            float(value)                       # must parse
            assert name and " " not in name.split("{")[0]


class TestCostBookThreadSafety:
    """The PR 4 lost-update pattern on the new store: every mutation is
    read-modify-write, so the GIL alone would lose updates under thread
    preemption.  Hammer every writer from many threads and assert the
    counts are exact."""

    N_THREADS = 16
    PER_THREAD = 500

    def test_concurrent_record_no_lost_updates(self):
        book = CostBook(warmup=0)

        def writer():
            for _ in range(self.PER_THREAD):
                book.record_step((64, 64), 1, "single_device", 0.001)
                book.observe("mb_dispatch_s", 0.002)
                book.incr("mb_shed")

        old = sys.getswitchinterval()
        sys.setswitchinterval(1e-5)
        try:
            ts = [threading.Thread(target=writer)
                  for _ in range(self.N_THREADS)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        finally:
            sys.setswitchinterval(old)
        total = self.N_THREADS * self.PER_THREAD
        assert book.step_count((64, 64), 1, "single_device") == total, \
            "lost step records"
        snap = book.snapshot()
        assert snap["std_mb_dispatch_s_count"] == float(total), \
            "lost series observations"
        assert book.counter("mb_shed") == float(total), \
            "lost counter increments"


class TestMeasuredCostOverlay:
    """The routing-flip acceptance: with no measurements the overlay IS
    the analytic model; with the synthetic set loaded, the pinned
    (64, 64) batch-1 decision on a 4x1 data mesh provably flips from
    single_device (the golden-table analytic choice) to data_parallel
    (the measured winner) — and only once past the observation floor."""

    HW, BATCH = (64, 64), 1
    MESH = dict(data_n=4, model_n=1)

    def _provider(self, book, min_obs=3):
        return MeasuredCost(book, fallback=AnalyticCost(TEST_PARAMS),
                            min_observations=min_obs)

    def test_no_measurements_reproduces_analytic_routing(self):
        book = CostBook(warmup=0)
        cost = self._provider(book)
        f = tall_features(*self.HW)
        for hw, batch, mesh in [
            ((64, 64), 1, (4, 1)), ((64, 64), 8, (4, 1)),
            ((256, 64), 1, (1, 4)), ((512, 64), 4, (2, 4)),
        ]:
            kw = dict(data_n=mesh[0], model_n=mesh[1])
            assert choose_kind(tall_features(*hw), hw, batch,
                               cost=cost, **kw) == \
                choose_kind(tall_features(*hw), hw, batch,
                            params=TEST_PARAMS, **kw)
        # per-kind values match too, not just the argmin
        assert cost.step_cost(f, self.HW, "single_device", self.BATCH,
                              **self.MESH) == \
            step_cost(f, "single_device", self.BATCH,
                      params=TEST_PARAMS, **self.MESH)

    def test_measured_flip_is_pinned_and_gated(self):
        f = tall_features(*self.HW)
        analytic = choose_kind(f, self.HW, self.BATCH,
                               params=TEST_PARAMS, **self.MESH)
        assert analytic == "single_device"     # the golden-table row

        book = CostBook(warmup=0)
        cost = self._provider(book, min_obs=3)
        # measured reality disagrees with the napkin: the data-parallel
        # engine is 10x faster at this exact combo
        for _ in range(2):
            book.record_step(self.HW, self.BATCH, "single_device", 0.010)
            book.record_step(self.HW, self.BATCH, "data_parallel", 0.001)
        # below the observation floor: still the analytic choice
        assert choose_kind(f, self.HW, self.BATCH, cost=cost,
                           **self.MESH) == "single_device"
        book.record_step(self.HW, self.BATCH, "single_device", 0.010)
        book.record_step(self.HW, self.BATCH, "data_parallel", 0.001)
        # at the floor: the measured winner takes the route
        assert choose_kind(f, self.HW, self.BATCH, cost=cost,
                           **self.MESH) == "data_parallel"
        # unmeasured combos at other buckets still route analytically
        assert choose_kind(tall_features(2048), (2048, 64), 1,
                           cost=cost, **self.MESH) == \
            choose_kind(tall_features(2048), (2048, 64), 1,
                        params=TEST_PARAMS, **self.MESH)

    def test_min_observations_validated(self):
        with pytest.raises(ValueError, match="min_observations"):
            MeasuredCost(CostBook(), min_observations=0)


class TestPlannerProviderSeam:
    @pytest.fixture()
    def unit_mesh(self):
        from repro.launch.mesh import make_host_mesh

        return make_host_mesh((1, 1), ("data", "model"))

    def test_params_and_cost_are_exclusive(self, unit_mesh):
        with pytest.raises(ValueError, match="not both"):
            Planner(unit_mesh, params=TEST_PARAMS,
                    cost=AnalyticCost(TEST_PARAMS))
        with pytest.raises(ValueError, match="not both"):
            choose_kind(tall_features(64), (64, 64), 1, data_n=1,
                        model_n=1, params=TEST_PARAMS,
                        cost=AnalyticCost(TEST_PARAMS))

    def test_params_property_sees_through_overlay(self, unit_mesh):
        p = Planner(unit_mesh, params=TEST_PARAMS)
        assert p.params is TEST_PARAMS
        p.use_measurements(CostBook())
        assert isinstance(p.cost, MeasuredCost)
        assert p.params is TEST_PARAMS         # fallback chain exposed

    def test_use_measurements_idempotent_per_book(self, unit_mesh):
        p = Planner(unit_mesh)
        book = CostBook()
        p.use_measurements(book)
        cost = p.cost
        p.use_measurements(book)               # same book: no re-wrap
        assert p.cost is cost
        p.use_measurements(CostBook())         # new book: new overlay
        assert p.cost is not cost

    def test_planner_routes_by_measurements(self, unit_mesh):
        """End to end through Planner.choose: a unit mesh only admits
        single_device, so pin the measured value through costs()."""
        book = CostBook(warmup=0)
        p = Planner(unit_mesh, lambda hw: tall_features(*hw),
                    params=TEST_PARAMS).use_measurements(book)
        for _ in range(MeasuredCost.MIN_OBSERVATIONS):
            book.record_step((64, 64), 1, "single_device", 0.123)
        assert p.costs((64, 64), 1) == {"single_device": 0.123}


class TestCalibrationFit:
    """The fit is exact on noiseless data: the analytic step cost is
    linear in the five constants, so measurements GENERATED from a
    known CostParams must fit back to identical routing (and the
    constants themselves, where identifiable)."""

    TRUE = CostParams(peak_flops=4e9, ici_bw=2e9,
                      dispatch_overhead_s=80e-6,
                      collective_overhead_s=30e-6,
                      halo_launch_s=3e-6)
    GRID = [(hw, batch, mesh)
            for hw in ((64, 64), (128, 128), (256, 64), (512, 64),
                       (1024, 128), (2048, 64))
            for batch in (1, 4, 8)
            for mesh in ((1, 1), (4, 1), (1, 4), (2, 4))]

    def _measurements(self):
        rows = []
        for hw, batch, (dn, mn) in self.GRID:
            f = tall_features(*hw)
            for kind in eligible_kinds(hw, data_n=dn, model_n=mn,
                                       deepest_stride=f.deepest_stride):
                rows.append(StepMeasurement(
                    flops=f.flops, halo_bytes=f.halo_bytes,
                    halo_layers=f.halo_layers, kind=kind, batch=batch,
                    data_n=dn, model_n=mn,
                    seconds=step_cost(f, kind, batch, data_n=dn,
                                      model_n=mn, params=self.TRUE)))
        return rows

    def _routing(self, params):
        out = {}
        for hw, batch, (dn, mn) in self.GRID:
            out[(hw, batch, dn, mn)] = choose_kind(
                tall_features(*hw), hw, batch, data_n=dn, model_n=mn,
                params=params)
        return out

    def test_fit_recovers_constants_and_routing(self):
        fitted = fit_cost_params(self._measurements())
        for name, want in cost_params_to_dict(self.TRUE).items():
            assert getattr(fitted, name) == pytest.approx(want, rel=1e-6), \
                name
        assert self._routing(fitted) == self._routing(self.TRUE)

    def test_fit_save_load_identical_routing(self, tmp_path):
        """The acceptance round-trip: fit -> save -> load routes every
        canonical (bucket, batch, mesh) input identically."""
        fitted = fit_cost_params(self._measurements())
        path = str(tmp_path / "cost_params.json")
        save_cost_params(fitted, path, meta={"source": "test"})
        loaded = load_cost_params(path)
        assert loaded == fitted                # frozen dataclass eq
        assert self._routing(loaded) == self._routing(fitted)
        doc = json.loads(open(path).read())    # provenance round-trips
        assert doc["meta"]["source"] == "test"
        assert cost_params_from_dict(doc["cost_params"]) == fitted

    def test_unidentifiable_columns_keep_base(self):
        """A unit-mesh sweep never exercises halo/collective terms;
        those constants must come back as the base napkin values, not
        garbage from a singular solve."""
        rows = [StepMeasurement(
            flops=tall_features(h).flops, halo_bytes=0.0, halo_layers=0,
            kind="single_device", batch=1, data_n=1, model_n=1,
            seconds=step_cost(tall_features(h), "single_device", 1,
                              params=self.TRUE))
            for h in (64, 256, 1024)]
        base = CostParams()
        fitted = fit_cost_params(rows, base=base)
        assert fitted.peak_flops == pytest.approx(self.TRUE.peak_flops,
                                                  rel=1e-6)
        assert fitted.dispatch_overhead_s == pytest.approx(
            self.TRUE.dispatch_overhead_s, rel=1e-6)
        assert fitted.ici_bw == base.ici_bw
        assert fitted.collective_overhead_s == base.collective_overhead_s
        assert fitted.halo_launch_s == base.halo_launch_s

    def test_empty_measurements_return_base(self):
        base = CostParams(peak_flops=1.0)
        assert fit_cost_params([], base=base) is base

    def test_unknown_kind_rejected(self):
        bad = StepMeasurement(flops=1, halo_bytes=0, halo_layers=0,
                              kind="pod", batch=1, data_n=1, model_n=1,
                              seconds=1.0)
        with pytest.raises(ValueError, match="unknown plan kind"):
            fit_cost_params([bad])

    def test_unknown_json_field_rejected(self, tmp_path):
        path = tmp_path / "bad.json"
        path.write_text(json.dumps({"cost_params": {"peak_flops": 1.0,
                                                    "warp_drive": 9}}))
        with pytest.raises(ValueError, match="warp_drive"):
            load_cost_params(str(path))


class TestServiceMetrics:
    """The scrapeable export closing the ROADMAP autoscaling item:
    engine step series, scheduler gauges, and plan choices all surface
    through STDService.metrics_snapshot() / metrics_prometheus()."""

    @pytest.fixture(scope="class")
    def served(self):
        from repro.launch.mesh import make_host_mesh
        from repro.launch.serve import STDService

        svc = STDService(
            width=0.125, buckets=(64,), max_batch=2,
            planner=Planner(make_host_mesh((1, 1), ("data", "model"))))
        img = np.random.default_rng(0).random(
            (50, 48, 3)).astype(np.float32)
        for _ in range(3):                     # past the warmup skip
            svc(img)
        svc.serve_batched([img] * 4)
        return svc

    def test_engine_and_service_step_series_recorded(self, served):
        # sync path: 3 calls, first absorbs compile (warmup skip)
        assert served.book.step_count((64, 64), 1, "single_device") >= 2
        assert served.book.step_count((64, 64), 1, "single_device",
                                      stage="dispatch") >= 2
        assert served.book.step_ewma((64, 64), 1, "single_device") > 0

    def test_metrics_snapshot_flat_and_complete(self, served):
        m = served.metrics_snapshot()
        assert m["std_requests_total"] >= 3.0
        assert m["std_mb_submitted"] == 4.0
        assert "std_mb_queue_depth" in m
        assert "std_mb_batch_occupancy_ewma" in m
        key = ('std_plan_choice{bucket="64x64",'
               'plan="single_device"}')
        assert m[key] == 1.0
        step_keys = [k for k in m if k.startswith("std_step_ewma_s{")]
        assert step_keys, "no measured step series exported"
        assert all(isinstance(v, float) for v in m.values())

    def test_metrics_prometheus_form(self, served):
        txt = served.metrics_prometheus()
        assert "std_requests_total" in txt
        for line in txt.strip().splitlines():
            float(line.rsplit(" ", 1)[1])


class TestSnapshotLabels:
    """The per-replica label dimension: N books aggregate into one
    scrape without name (gauge) clobbering — launch/router.py's
    ServiceReplica names each service book this way."""

    def _filled(self, **labels):
        b = CostBook(warmup=0, labels=labels or None)
        b.record_step((64, 64), 2, "single_device", 0.05)
        b.incr("mb_shed")
        b.set_gauge("mb_queue_depth", 3.0)
        b.observe("mb_dispatch_s", 0.01)
        return b

    def test_labels_embed_in_every_metric_name(self):
        snap = self._filled(replica="r1").snapshot()
        assert snap, "empty snapshot"
        assert all('replica="r1"' in k for k in snap)
        # step series merge into the existing brace group...
        step = [k for k in snap if k.startswith("std_step_ewma_s{")]
        assert step and step[0].count("{") == 1
        # ...and plain counters/gauges grow a brace group
        assert snap['std_mb_shed_total{replica="r1"}'] == 1.0
        assert snap['std_mb_queue_depth{replica="r1"}'] == 3.0

    def test_unlabeled_book_keeps_historical_names(self):
        snap = self._filled().snapshot()
        assert snap["std_mb_shed_total"] == 1.0
        assert "replica=" not in "".join(snap)

    def test_two_replica_books_merge_without_clobbering(self):
        a = self._filled(replica="r0").snapshot()
        b = self._filled(replica="r1").snapshot()
        merged = {**a, **b}
        assert len(merged) == len(a) + len(b)
        assert merged['std_mb_queue_depth{replica="r0"}'] == 3.0
        assert merged['std_mb_queue_depth{replica="r1"}'] == 3.0
        # the merged scrape still renders as prometheus text
        assert "std_mb_queue_depth" in prometheus_text(merged)

    def test_relabel_skips_names_already_carrying_the_label(self):
        from repro.runtime.telemetry import relabel

        out = relabel({'x{replica="keep"}': 1.0, 'y{a="1"}': 2.0,
                       "z": 3.0}, replica="r9")
        assert out == {'x{replica="keep"}': 1.0,
                       'y{a="1",replica="r9"}': 2.0,
                       'z{replica="r9"}': 3.0}
