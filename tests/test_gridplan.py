"""GridPlan (DataParallel x RowBand composed on a 2-D mesh) — slow tier.

Each test spawns a subprocess with an 8-device host platform (the main
pytest process must keep seeing ONE device; see conftest).  Covers:

  * halo_exchange on a 2x4 (data, model) mesh: rows move along "model"
    only, each data-parallel batch shard keeps its own plane, true-border
    halos are zero, and both the ppermute and the all_gather fallback
    paths are exact;
  * the acceptance check — GridPlan boxes identical to SingleDevice for
    fixed-seed inputs, end to end through STDService (plus cost-model
    routing of over-tall and transposed over-wide images onto row-banded
    plans);
  * a property-based plan-parity suite (hypothesis shim): random seeds /
    buckets / batch sizes, identical boxes across SingleDevice vs
    DataParallel vs RowBand vs GridPlan, skipping assertions when any
    score or link lands within 1e-6 of the 0.5 threshold (Winograd tile
    regrouping at non-tile-multiple band offsets can shift scores by
    ~1e-6 — see runtime/executor.py).
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
TESTS = os.path.dirname(__file__)


def run_sub(body: str, timeout: int = 900) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, {SRC!r})
        sys.path.insert(0, {TESTS!r})
    """) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


class TestHaloExchange2D:
    def test_model_axis_only_on_2x4_mesh(self):
        """Direct unit test: on a (data=2, model=4) mesh the exchange is
        correct along "model" for the narrow (ppermute), band-equal, and
        wide (all_gather) halo paths, and never leaks rows between batch
        shards on the "data" axis."""
        out = run_sub("""
            import numpy as np
            import jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.launch.mesh import make_mesh
            from repro.runtime.collectives import halo_exchange
            from repro.runtime.sharding import shard_map_compat

            mesh = make_mesh((2, 4), ("data", "model"))
            # global (N=2, H=8, W=1, C=1): batch over "data", rows over
            # "model" -> local band (1, 2, 1, 1); the +100 offset makes
            # any cross-data leak change values, not just positions
            x = np.arange(2 * 8, dtype=np.float32).reshape(2, 8, 1, 1)
            x[1] += 100.0

            def want(halo):
                # reference: zero-pad each image's own plane, slice each
                # band's extended window back out
                bands = []
                for n in range(2):
                    padded = np.pad(x[n, :, 0, 0], (halo, halo))
                    bands.append(np.concatenate(
                        [padded[i * 2:i * 2 + 2 + 2 * halo]
                         for i in range(4)]
                    ))
                return np.stack(bands)

            # halo=1: ppermute path; halo=2: whole-band edge case;
            # halo=3 and 5: all_gather fallback (receptive field spans
            # several bands); axis_size both static and psum-derived
            for halo, axis_size in [(1, 4), (1, 0), (2, 4), (3, 4),
                                    (3, 0), (5, 4)]:
                f = shard_map_compat(
                    lambda a: halo_exchange(
                        a, "model", halo, axis=1, axis_size=axis_size),
                    mesh, in_specs=P("data", "model", None, None),
                    out_specs=P("data", "model", None, None),
                )
                got = np.asarray(f(jnp.asarray(x))).squeeze()
                np.testing.assert_array_equal(
                    got, want(halo),
                    err_msg=f"halo={halo} axis_size={axis_size}",
                )
            print("HALO_2D_OK")
        """, timeout=300)
        assert "HALO_2D_OK" in out

    def test_rejects_tuple_axis_names(self):
        """A tuple of mesh axes would silently band over the flattened
        product axis; it must be rejected up front (no devices needed —
        the check fires before any collective)."""
        from repro.runtime.collectives import halo_exchange

        import jax.numpy as jnp

        with pytest.raises(TypeError, match="single named mesh axis"):
            halo_exchange(jnp.ones((1, 4, 1, 1)), ("data", "model"), 1)


class TestGridPlanParity:
    def test_grid_boxes_identical_to_single_device(self):
        """The acceptance check: on an 8-device 2x4 host mesh GridPlan
        produces boxes identical to SingleDevice for fixed-seed inputs,
        sequential and micro-batched, and the cost-model planner routes
        over-tall / transposed over-wide images onto row-banded plans."""
        out = run_sub("""
            import numpy as np
            from repro.data.images import RequestStream
            from repro.launch.mesh import make_mesh
            from repro.launch.serve import STDService
            from repro.runtime.executor import GridPlan
            from repro.runtime.planner import Planner

            mesh = make_mesh((2, 4), ("data", "model"))
            # grid on model=4 needs H % (4*32) == 0 -> 128-row buckets
            kw = dict(width=0.125, buckets=(128,), max_batch=4)
            key = lambda rs: [[b["box"] for b in r] for r in rs]
            images = RequestStream(
                6, seed=3, hw_range=((48, 96), (48, 96))).images()

            base = STDService(**kw)
            want = key([base(img) for img in images])

            grid = STDService(**kw, plan=GridPlan(mesh))
            got_seq = key([grid(img) for img in images])
            assert got_seq == want, "grid sequential diverged"
            got_bat = key(grid.serve_batched(images))
            assert got_bat == want, "grid batched diverged"
            plans = {e["plan"] for e in grid.factory.stats["compiled"]}
            assert plans == {"grid[data=2,model=4]"}, plans

            # cost-model routing: over-tall images (bucket clamp 256,
            # already a band-unit multiple) are forced onto a row-banded
            # plan and match the single-device reference
            svc = STDService(width=0.125, buckets=(64,), max_batch=4,
                             planner=Planner(mesh))
            tall = np.random.default_rng(7).random(
                (200, 48, 3)).astype(np.float32)
            got_tall = [b["box"] for b in svc(tall)]
            choice = svc.stats["plan_choices"][(256, 64)]
            assert choice.startswith(("row_band", "grid")), choice
            ref = STDService(width=0.125, buckets=(64,), max_batch=4)
            assert got_tall == [b["box"] for b in ref(tall)], \\
                "planner-routed over-tall diverged"

            # transposed over-wide rides the same row-banded routing;
            # the reference must transpose too (a non-transposing
            # service pads the ORIGINAL orientation to a different
            # bucket), so compare against tall_plan=SingleDevice —
            # same §IV.B transpose trick, single-device engine
            from repro.runtime.executor import SingleDevice
            wide = np.random.default_rng(9).random(
                (48, 200, 3)).astype(np.float32)
            got_wide = [b["box"] for b in svc(wide)]
            assert svc.stats["transposed"] == 1
            choice = svc.stats["plan_choices"][(256, 64)]
            assert choice.startswith(("row_band", "grid")), choice
            ref_t = STDService(width=0.125, buckets=(64,), max_batch=4,
                               tall_plan=SingleDevice())
            assert got_wide == [b["box"] for b in ref_t(wide)], \\
                "planner-routed over-wide diverged"
            print("GRID_PARITY_OK")
        """)
        assert "GRID_PARITY_OK" in out

    def test_grid_rejects_misaligned_height(self):
        """Band-height invariant at compile time: H not divisible into
        bands x deepest stride must raise, not mis-shard."""
        out = run_sub("""
            from repro.launch.mesh import make_mesh
            from repro.models.fcn.pixellink import PixelLinkModel, STDConfig
            from repro.runtime.executor import EngineFactory, GridPlan

            fac = EngineFactory(lambda hw: PixelLinkModel(STDConfig(
                backbone="vgg16", width=0.125, image_size=hw,
                merge_ch=(16, 16, 8), mode="optimized",
                storage_fp16=False)))
            mesh = make_mesh((2, 4), ("data", "model"))
            try:
                fac.plan_fn((64, 64), 2, GridPlan(mesh))
            except ValueError as e:
                assert "band height" in str(e) or "divisible" in str(e)
            else:
                raise AssertionError("H=64 on 4 bands must be rejected")
            try:
                fac.plan_fn((128, 64), 3, GridPlan(mesh))
            except ValueError as e:
                assert "divisible" in str(e)
            else:
                raise AssertionError("batch=3 on data=2 must be rejected")

            # a data-sharded tall_plan is bound by the same max_batch
            # divisibility rule as the service default plan: padded
            # batches must never exceed the configured maximum
            from repro.launch.serve import STDService
            try:
                STDService(width=0.125, buckets=(64,), max_batch=5,
                           tall_plan=GridPlan(mesh))
            except ValueError as e:
                assert "multiple" in str(e)
            else:
                raise AssertionError(
                    "max_batch=5 with a data=2 tall_plan must be rejected")
            print("GRID_VALIDATION_OK")
        """, timeout=300)
        assert "GRID_VALIDATION_OK" in out


class TestPlanParityProperty:
    def test_random_seeds_buckets_batches(self):
        """Property suite: for random (seed, bucket, batch), all four
        plans label identically — modulo the 0.5-threshold guard."""
        out = run_sub("""
            import numpy as np
            import jax
            import jax.numpy as jnp
            from _hypothesis_compat import given, settings, strategies as st
            from repro.launch.mesh import make_mesh
            from repro.models.fcn.pixellink import PixelLinkModel, STDConfig
            from repro.runtime.executor import (DataParallel, EngineFactory,
                                                GridPlan, RowBand,
                                                SingleDevice)

            mesh = make_mesh((2, 4), ("data", "model"))
            fac = EngineFactory(lambda hw: PixelLinkModel(STDConfig(
                backbone="vgg16", width=0.125, image_size=hw,
                merge_ch=(16, 16, 8), mode="optimized",
                storage_fp16=False)))
            # (bucket, batch) combos bounded so engines compile once and
            # examples replay from the LRU; heights are band-unit
            # multiples of the 2x4 mesh (4 bands x stride 32)
            COMBOS = [((128, 64), 2), ((128, 64), 4), ((256, 64), 2)]
            guards = {}
            checked = [0]
            skipped = [0]

            def score_gap(hw, params, x):
                fn = guards.get((hw, x.shape[0]))
                if fn is None:
                    model = fac.model(hw)
                    fn = jax.jit(lambda p, a: model.apply(p, a))
                    guards[(hw, x.shape[0])] = fn
                out = fn(params, x)
                return float(jnp.minimum(
                    jnp.min(jnp.abs(out["score"] - 0.5)),
                    jnp.min(jnp.abs(out["links"] - 0.5)),
                ))

            @settings(max_examples=6)
            @given(st.integers(0, 2**31 - 1), st.sampled_from(COMBOS))
            def prop(seed, combo):
                hw, batch = combo
                params = fac.params(hw)
                rng = np.random.default_rng(seed)
                x = jnp.asarray(
                    rng.random((batch,) + hw + (3,)).astype(np.float32))
                vq = jnp.asarray(np.stack([
                    rng.integers(1, hw[0] // 4 + 1, size=batch),
                    rng.integers(1, hw[1] // 4 + 1, size=batch),
                ], axis=1).astype(np.int32))
                # the known guard: Winograd tile regrouping at band
                # offsets can shift scores ~1e-6, enough to flip a
                # threshold decision only when a score is already within
                # 1e-6 of 0.5 — skip those (never observed with these
                # seeds, min gap is typically ~1e-4)
                if score_gap(hw, params, x) < 1e-6:
                    skipped[0] += 1
                    return
                want = np.asarray(
                    fac.plan_fn(hw, batch, SingleDevice())(params, x, vq)[0])
                for plan in (DataParallel(mesh, "data"),
                             RowBand(mesh, axis="model"),
                             GridPlan(mesh)):
                    got = np.asarray(
                        fac.plan_fn(hw, batch, plan)(params, x, vq)[0])
                    assert np.array_equal(got, want), (
                        f"{type(plan).__name__} diverged: hw={hw} "
                        f"batch={batch} seed={seed}")
                checked[0] += 1

            prop()
            assert checked[0] >= 1, "every example hit the threshold guard"
            print(f"PROP_PARITY_OK checked={checked[0]} "
                  f"skipped={skipped[0]}")
        """)
        assert "PROP_PARITY_OK" in out
