"""Config exactness vs the assignment + HLO/roofline analysis units."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, all_configs, get_config
from repro.configs.base import input_specs, shape_applicable

# the assignment table, verbatim
ASSIGNED = {
    "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
                        d_ff=32768, vocab=131072, n_experts=8, top_k=2),
    "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64,
                            n_kv_heads=8, d_ff=2048, vocab=163840,
                            n_experts=384, top_k=8),
    "qwen2.5-14b": dict(n_layers=48, d_model=5120, n_heads=40,
                        n_kv_heads=8, d_ff=13824, vocab=152064,
                        qkv_bias=True),
    "mistral-nemo-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                             n_kv_heads=8, d_ff=14336, vocab=131072),
    "internlm2-1.8b": dict(n_layers=24, d_model=2048, n_heads=16,
                           n_kv_heads=8, d_ff=8192, vocab=92544),
    "tinyllama-1.1b": dict(n_layers=22, d_model=2048, n_heads=32,
                           n_kv_heads=4, d_ff=5632, vocab=32000),
    "whisper-tiny": dict(n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
                         d_ff=1536, vocab=51865),
    "internvl2-76b": dict(n_layers=80, d_model=8192, n_heads=64,
                          n_kv_heads=8, d_ff=28672, vocab=128256),
    "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32,
                        n_kv_heads=32, d_ff=10240, ssm_state=64),
    "mamba2-370m": dict(n_layers=48, d_model=1024, vocab=50280,
                        ssm_state=128),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_config_fields(arch):
    cfg = get_config(arch)
    for field, want in ASSIGNED[arch].items():
        assert getattr(cfg, field) == want, (arch, field)


def test_all_shapes_defined():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["decode_32k"].kind == "decode"


def test_long_context_applicability_matrix():
    runnable = {
        a: shape_applicable(c, SHAPES["long_500k"])[0]
        for a, c in all_configs().items()
    }
    assert runnable["mamba2-370m"] and runnable["zamba2-2.7b"]
    assert sum(runnable.values()) == 2          # exactly the sub-quadratic two


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "whisper-tiny",
                                  "internvl2-76b", "mamba2-370m"])
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_no_allocation(arch, shape):
    cfg = get_config(arch)
    specs = input_specs(cfg, SHAPES[shape])
    for v in specs.values():
        assert isinstance(v, jax.ShapeDtypeStruct)
    if SHAPES[shape].kind != "decode" and cfg.frontend != "none":
        assert "prefix_embed" in specs


class TestHLOAnalysis:
    def test_collective_bytes_parsing(self):
        from repro.launch.hlo_analysis import collective_bytes

        hlo = """
        %ag = bf16[8,128]{1,0} all-gather(%p0), dimensions={0}
        %ar.1 = f32[256]{0} all-reduce(%x), to_apply=%sum
        %cp = (f32[4,4]{1,0}, f32[4,4]{1,0}) collective-permute(%y)
        %done = f32[8]{0} all-gather-done(%h)
        not_a_collective = f32[9]{0} add(%a, %b)
        """
        out = collective_bytes(hlo)
        assert out["all-gather"] == 8 * 128 * 2
        assert out["all-reduce"] == 256 * 4
        assert out["collective-permute"] == 2 * 16 * 4
        assert out["count"] == 3                  # -done not double counted

    def test_op_histogram(self):
        from repro.launch.hlo_analysis import op_histogram

        hlo = "%a = f32[2]{0} add(%x, %y)\n%b = f32[2]{0} add(%a, %y)\n" \
              "%c = f32[2]{0} multiply(%a, %b)"
        hist = dict(op_histogram(hlo))
        assert hist["add"] == 2 and hist["multiply"] == 1


class TestRooflineUnits:
    def test_model_flops_moe_uses_active(self):
        import benchmarks.roofline as rl

        dense = rl.model_flops("mistral-nemo-12b", "train_4k")
        moe = rl.model_flops("kimi-k2-1t-a32b", "train_4k")
        # kimi has 80x the params but only ~2.5x active-param flops
        assert moe < dense * 4

    def test_terms_and_dominant(self):
        import benchmarks.roofline as rl

        rec = {
            "arch": "tinyllama-1.1b", "shape": "decode_32k", "status": "ok",
            "n_devices": 256,
            "flops_per_device": 1e10,
            "bytes_accessed_per_device": 3e10,
            "collective_bytes_per_device": {"total": 1e8},
            "memory": {"argument_size_bytes": 2**30,
                       "temp_size_bytes": 2**28},
        }
        row = rl.analyze(rec)
        assert row["dominant"] == "memory"
        assert row["fits_hbm"]
        assert 0 <= row["roofline_fraction"] <= 1.5
