"""Microcode ISA tests: pack/unpack roundtrip (property-based), field
bounds, program packing, Table II bit-width conformance."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # bare interpreter: seeded fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import microcode as M


def _field_strategy():
    return st.builds(
        M.Microcode,
        layer_type=st.integers(0, 3),
        transpose_relu=st.integers(0, 3),
        in_ch=st.integers(0, 2**16 - 1),
        out_ch=st.integers(0, 2**16 - 1),
        height=st.integers(0, 2**20 - 1),
        width=st.integers(0, 2**15 - 1),
        kernel=st.integers(0, 2),
        stride=st.integers(0, 1),
        res_op=st.integers(0, 2),
        in_addr=st.integers(0, 2**34 - 1),
        out_addr=st.integers(0, 2**34 - 1),
        ext_opcode=st.integers(0, 2**8 - 1),
        ext_table_idx=st.integers(0, 2**16 - 1),
        ext_addr2=st.integers(0, 2**34 - 1),
        ext_flags=st.integers(0, 2**16 - 1),
        reserved=st.integers(0, 2**38 - 1),
    )


class TestMicrocode:
    def test_word_is_256_bits(self):
        assert M.MICROCODE_BITS == 256
        assert sum(w for _, w in M._FIELDS) == 256
        assert M.pack(M.Microcode()).nbytes == 32

    @settings(max_examples=200, deadline=None)
    @given(_field_strategy())
    def test_roundtrip(self, mc):
        assert M.unpack(M.pack(mc)) == mc

    def test_table_ii_field_widths(self):
        """The first 144 bits must match Table II exactly."""
        widths = dict(M._FIELDS)
        assert widths["layer_type"] == 2
        assert widths["transpose_relu"] == 2
        assert widths["in_ch"] == 16
        assert widths["out_ch"] == 16
        assert widths["height"] == 20
        assert widths["width"] == 15
        assert widths["kernel"] == 2
        assert widths["stride"] == 1
        assert widths["res_op"] == 2
        assert widths["in_addr"] == 34
        assert widths["out_addr"] == 34
        # reserved page sums to 112
        reserved = (widths["ext_opcode"] + widths["ext_table_idx"]
                    + widths["ext_addr2"] + widths["ext_flags"]
                    + widths["reserved"])
        assert reserved == 112

    def test_field_overflow_rejected(self):
        with pytest.raises(ValueError):
            M.Microcode(in_ch=2**16).validate()
        with pytest.raises(ValueError):
            M.Microcode(width=2**15).validate()

    def test_kernel_codes(self):
        assert M.Microcode(kernel=int(M.Kernel.K1)).kernel_size == 1
        assert M.Microcode(kernel=int(M.Kernel.K3)).kernel_size == 3
        assert M.Microcode(kernel=int(M.Kernel.K7)).kernel_size == 7

    def test_relu_transpose_bits(self):
        assert M.Microcode(transpose_relu=0b01).relu
        assert not M.Microcode(transpose_relu=0b01).transpose
        assert M.Microcode(transpose_relu=0b10).transpose
        assert M.Microcode(transpose_relu=0b11).relu

    def test_program_roundtrip(self):
        words = [
            M.Microcode(layer_type=0, in_ch=64, out_ch=128, kernel=1),
            M.Microcode(layer_type=3, ext_opcode=int(M.ExtOp.ATTN)),
        ]
        raw = M.pack_program(words)
        assert raw.shape == (2, 32)
        assert M.unpack_program(raw) == words

    def test_disassemble_smoke(self):
        words = [M.Microcode(layer_type=0, in_ch=3, out_ch=8, kernel=1,
                             res_op=1)]
        text = M.disassemble(words)
        assert "conv" in text and "res=cache" in text
