"""ExecutionPlan layer tests (runtime/executor.py): plan-keyed engine
LRU, unit-mesh plan parity (DataParallel / RowBand == SingleDevice),
halo_exchange semantics, bucket_hw oversize clamping + row-band routing
for over-tall inputs, and — slow tier — the 8-device host-mesh parity
acceptance test (data-parallel and row-band boxes identical to single
device)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def make_factory(capacity=16):
    from repro.models.fcn.pixellink import PixelLinkModel, STDConfig
    from repro.runtime.executor import EngineFactory

    return EngineFactory(
        lambda hw: PixelLinkModel(STDConfig(
            backbone="vgg16", width=0.125, image_size=hw,
            merge_ch=(16, 16, 8), mode="optimized", storage_fp16=False,
        )),
        capacity=capacity,
    )


@pytest.fixture(scope="module")
def unit_mesh():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh((1, 1), ("data", "model"))


class TestPlanKeyedLRU:
    def _stub_factory(self, capacity):
        from repro.runtime.executor import EngineFactory

        fac = EngineFactory(lambda hw, precision="f32", model="pixellink":
                            None, capacity=capacity)
        fac._compile = (
            lambda hw, batch, plan, precision="f32", model="pixellink":
            ("engine", hw, batch, plan, precision, model))
        return fac

    def test_keyed_on_bucket_batch_plan(self, unit_mesh):
        from repro.runtime.executor import DataParallel, RowBand, SingleDevice

        fac = self._stub_factory(capacity=16)
        single = fac.plan_fn((64, 64), 2, SingleDevice())
        assert fac.plan_fn((64, 64), 2, SingleDevice()) is single  # hit
        # every key component is part of the identity
        assert fac.plan_fn((64, 128), 2, SingleDevice()) is not single
        assert fac.plan_fn((64, 64), 4, SingleDevice()) is not single
        assert fac.plan_fn((64, 64), 2, SingleDevice(),
                           "bfp") is not single
        dp = fac.plan_fn((64, 64), 2, DataParallel(unit_mesh, "data"))
        rb = fac.plan_fn((64, 64), 2, RowBand(unit_mesh, axis="model"))
        assert dp is not single and rb is not single and dp is not rb
        from repro.runtime.executor import GridPlan

        gr = fac.plan_fn((64, 64), 2, GridPlan(unit_mesh))
        assert gr not in (single, dp, rb)
        assert fac.plan_fn((64, 64), 2, GridPlan(unit_mesh)) is gr  # hit
        assert len(fac) == 7
        assert fac.engines.hits == 2 and fac.engines.misses == 7

    def test_eviction_at_capacity(self, unit_mesh):
        from repro.runtime.executor import DataParallel, SingleDevice

        fac = self._stub_factory(capacity=2)
        a = fac.plan_fn((64, 64), 1, SingleDevice())
        fac.plan_fn((64, 64), 1, DataParallel(unit_mesh, "data"))
        fac.plan_fn((64, 64), 2, SingleDevice())       # evicts `a`'s key
        assert len(fac) == 2
        assert fac.plan_fn((64, 64), 1, SingleDevice()) is not a  # recompiled

    def test_model_and_param_caches_are_bounded(self):
        from repro.runtime.executor import EngineFactory

        built = []
        fac = EngineFactory(lambda hw: built.append(hw) or object(),
                            capacity=1)
        a = fac.model((64, 64))
        assert fac.model((64, 64)) is a          # cached
        fac.model((128, 64))                     # evicts (64, 64)
        assert len(fac._models) == 1
        assert fac.model((64, 64)) is not a      # rebuilt after eviction
        assert built == [(64, 64), (128, 64), (64, 64)]

    def test_plans_are_hashable_dataclasses(self, unit_mesh):
        from repro.runtime.executor import DataParallel, RowBand, SingleDevice

        assert SingleDevice() == SingleDevice()
        assert hash(DataParallel(unit_mesh)) == hash(DataParallel(unit_mesh))
        assert RowBand(unit_mesh) != DataParallel(unit_mesh)


class TestPlanBatchMultiple:
    def test_single_and_rowband_are_one(self, unit_mesh):
        from repro.runtime.executor import (RowBand, SingleDevice,
                                            plan_batch_multiple)

        assert plan_batch_multiple(SingleDevice()) == 1
        assert plan_batch_multiple(RowBand(unit_mesh)) == 1

    def test_data_parallel_is_axis_size(self, unit_mesh):
        from repro.runtime.executor import DataParallel, plan_batch_multiple

        assert plan_batch_multiple(DataParallel(unit_mesh, "data")) == 1

    def test_grid_is_data_axis_size(self, unit_mesh):
        from repro.runtime.executor import GridPlan, plan_batch_multiple

        assert plan_batch_multiple(GridPlan(unit_mesh)) == 1

    def test_band_height_unit_covers_all_plans(self, unit_mesh):
        from repro.runtime.executor import (GridPlan, RowBand, SingleDevice,
                                            band_height_unit)

        assert band_height_unit(SingleDevice(), 32) == 32
        assert band_height_unit(RowBand(unit_mesh, "model", bands=8),
                                32) == 256
        assert band_height_unit(GridPlan(unit_mesh, bands=4), 32) == 128


class TestHaloExchange:
    def test_unit_axis_is_zero_padding(self, unit_mesh):
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from repro.runtime.collectives import halo_exchange
        from repro.runtime.sharding import shard_map_compat

        x = jnp.arange(24.0).reshape(1, 4, 3, 2)
        f = shard_map_compat(
            lambda a: halo_exchange(a, "model", 2, axis=1, axis_size=1),
            unit_mesh, in_specs=P(), out_specs=P(),
        )
        got = np.asarray(f(x))
        want = np.asarray(jnp.pad(x, ((0, 0), (2, 2), (0, 0), (0, 0))))
        np.testing.assert_array_equal(got, want)

    def test_zero_halo_is_identity(self, unit_mesh):
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from repro.runtime.collectives import halo_exchange
        from repro.runtime.sharding import shard_map_compat

        x = jnp.ones((1, 4, 3, 2))
        f = shard_map_compat(
            lambda a: halo_exchange(a, "model", 0, axis=1, axis_size=1),
            unit_mesh, in_specs=P(), out_specs=P(),
        )
        np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x))


class TestFCNActivationSpecs:
    def test_batch_and_rows_axes(self):
        from jax.sharding import PartitionSpec as P

        from repro.runtime.sharding import fcn_activation_specs

        dp = fcn_activation_specs(batch_axis="data")
        assert dp["image"] == P("data", None, None, None)
        assert dp["labels"] == P("data", None, None)
        rb = fcn_activation_specs(rows_axis="model")
        assert rb["image"] == P(None, "model", None, None)
        assert rb["score"] == P(None, "model", None)

    def test_grid_composes_both_axes(self):
        """The 2-D specs the GridPlan shard_map runs under: batch over
        "data" AND rows over "model" in one layout."""
        from jax.sharding import PartitionSpec as P

        from repro.runtime.sharding import fcn_activation_specs

        g = fcn_activation_specs(batch_axis="data", rows_axis="model")
        assert g["image"] == P("data", "model", None, None)
        assert g["score"] == P("data", "model", None)
        assert g["links"] == P("data", "model", None, None)
        assert g["labels"] == P("data", "model", None)

    def test_fcn_batch_axis_divisibility(self, unit_mesh):
        from repro.runtime.sharding import fcn_batch_axis

        # size-1 axes replicate; divisibility rules exercised on the
        # multi-device mesh in the slow tier
        assert fcn_batch_axis(unit_mesh, 8, "data") is None


class TestUnitMeshPlanParity:
    """DataParallel and RowBand on a 1x1 host mesh must match the
    SingleDevice plan exactly — same program, same numerics, shard_map
    plumbing only (the multi-device version runs in the slow tier)."""

    def test_labels_identical_across_plans(self, unit_mesh):
        import jax.numpy as jnp

        from repro.runtime.executor import DataParallel, RowBand, SingleDevice

        fac = make_factory()
        hw = (64, 64)
        params = fac.params(hw)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.random((2, 64, 64, 3)).astype(np.float32))
        vq = jnp.asarray(np.array([[16, 16], [12, 14]], np.int32))
        want, want_conv = fac.plan_fn(hw, 2, SingleDevice())(params, x, vq)
        want = np.asarray(want)
        assert np.asarray(want_conv).all()
        from repro.runtime.executor import GridPlan

        for plan in (DataParallel(unit_mesh, "data"),
                     RowBand(unit_mesh, axis="model"),
                     GridPlan(unit_mesh)):
            got, conv = fac.plan_fn(hw, 2, plan)(params, x, vq)
            np.testing.assert_array_equal(np.asarray(got), want)
            assert np.asarray(conv).shape == (2,)
            assert np.asarray(conv).all()

    def test_rowband_rejects_misaligned_bands(self):
        from repro.launch.mesh import make_host_mesh
        from repro.runtime.executor import RowBand

        fac = make_factory()
        mesh = make_host_mesh((1, 1), ("data", "model"))
        with pytest.raises(ValueError, match="bands"):
            fac.plan_fn((64, 64), 1, RowBand(mesh, axis="model", bands=2))

    def test_data_parallel_rejects_missing_axis(self, unit_mesh):
        from repro.runtime.executor import DataParallel

        fac = make_factory()
        with pytest.raises(ValueError, match="no axis"):
            fac.plan_fn((64, 64), 2, DataParallel(unit_mesh, "nope"))

    def test_grid_rejects_missing_or_equal_axes(self, unit_mesh):
        from repro.runtime.executor import GridPlan

        fac = make_factory()
        with pytest.raises(ValueError, match="no axis"):
            fac.plan_fn((64, 64), 2, GridPlan(unit_mesh, data_axis="nope"))
        with pytest.raises(ValueError, match="axes must differ"):
            fac.plan_fn((64, 64), 2,
                        GridPlan(unit_mesh, data_axis="model"))

    def test_grid_rejects_misaligned_bands(self, unit_mesh):
        from repro.runtime.executor import GridPlan

        fac = make_factory()
        with pytest.raises(ValueError, match="bands"):
            fac.plan_fn((64, 64), 1, GridPlan(unit_mesh, bands=2))


class TestOversizeBuckets:
    def test_bucket_hw_clamps_instead_of_raising(self):
        from repro.launch.serve import bucket_hw

        assert bucket_hw(48, 100, (64, 128)) == (64, 128)
        # regression: used to raise ValueError (min() of empty sequence)
        assert bucket_hw(300, 80, (64, 128, 256)) == (512, 128)
        assert bucket_hw(100, 3000, (64, 128)) == (128, 3072)

    def test_bucket_hw_fails_fast_beyond_max_width(self):
        from repro.launch.serve import MAX_WIDTH, bucket_hw

        with pytest.raises(ValueError, match="serving limit"):
            bucket_hw(MAX_WIDTH + 8, 64, (64,))
        with pytest.raises(ValueError, match="serving limit"):
            bucket_hw(64, MAX_WIDTH + 8, (64,))

    def test_over_tall_request_served_not_crashed(self):
        from repro.launch.serve import STDService

        svc = STDService(width=0.125, buckets=(64,), max_batch=2)
        img = np.random.default_rng(0).random((100, 48, 3)).astype(np.float32)
        boxes = svc(img)                          # clamped to (128, 64)
        assert isinstance(boxes, list)
        assert any(e["hw"] == (128, 64) for e in svc.factory.stats["compiled"])

    def test_row_band_height_unit(self, unit_mesh):
        from repro.runtime.executor import RowBand, row_band_height_unit

        assert row_band_height_unit(RowBand(unit_mesh, "model"), 32) == 32
        assert row_band_height_unit(
            RowBand(unit_mesh, "model", bands=8), 32) == 256

    def test_tall_height_rounds_to_band_unit(self, unit_mesh):
        from repro.launch.serve import STDService
        from repro.runtime.executor import RowBand

        # an 8-band tall plan needs H % 256 == 0 (8 bands x stride 32);
        # the naive bucket clamp alone (192) used to crash the plan
        # compiler for heights like 150
        # (bands=8 on a 1-wide axis would be rejected at plan-compile
        # time, but _tall_height is pure arithmetic over the plan shape)
        svc = STDService(width=0.125, buckets=(64,),
                         tall_plan=RowBand(unit_mesh, "model", bands=8))
        assert svc._tall_height(192) == 256
        assert svc._tall_height(256) == 256
        assert svc._tall_height(257) == 512

    def test_over_tall_routes_to_rowband_plan(self, unit_mesh):
        from repro.launch.serve import STDService
        from repro.runtime.executor import RowBand

        svc = STDService(width=0.125, buckets=(64,), max_batch=2,
                         tall_plan=RowBand(unit_mesh, axis="model"))
        img = np.random.default_rng(0).random((100, 48, 3)).astype(np.float32)
        boxes = svc(img)
        plans = [e["plan"] for e in svc.factory.stats["compiled"]]
        assert "row_band[model=1]" in plans
        # on the unit mesh the row-band plan is numerically the single
        # device plan: boxes must agree with the clamped-bucket service
        ref = STDService(width=0.125, buckets=(64,), max_batch=2)
        assert [b["box"] for b in boxes] == [b["box"] for b in ref(img)]

    def test_over_wide_transposes_onto_rowband_plan(self, unit_mesh):
        from repro.launch.serve import STDService
        from repro.runtime.executor import RowBand

        svc = STDService(width=0.125, buckets=(64,), max_batch=2,
                         tall_plan=RowBand(unit_mesh, axis="model"))
        wide = np.random.default_rng(1).random((48, 100, 3)).astype(np.float32)
        boxes = svc(wide)
        assert svc.stats["transposed"] == 1      # rides the §IV.B trick
        assert any(e["hw"] == (128, 64) and e["plan"].startswith("row_band")
                   for e in svc.factory.stats["compiled"])
        # boxes come back in original (un-transposed) coordinates:
        # (x0, y0, x1, y1) at 1/4 scale of the 48x100 image
        assert all(b["box"][2] <= 100 // 4 and b["box"][3] <= 48 // 4
                   for b in boxes)


@pytest.mark.slow
class TestHostMeshParity:
    """The acceptance check: on an 8-device host mesh, a data-parallel
    plan and a row-band plan produce boxes identical to the single-device
    plan on the same inputs, end to end through STDService."""

    def test_plans_produce_identical_boxes(self):
        code = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys
            sys.path.insert(0, {SRC!r})
            import numpy as np
            from repro.data.images import RequestStream
            from repro.launch.mesh import make_mesh
            from repro.launch.serve import STDService
            from repro.runtime.executor import DataParallel, RowBand

            images = RequestStream(
                6, seed=3, hw_range=((48, 64), (48, 64))).images()
            kw = dict(width=0.125, buckets=(64,), max_batch=4)
            key = lambda rs: [[b["box"] for b in r] for r in rs]

            base = STDService(**kw)
            want = key([base(img) for img in images])

            mesh = make_mesh((4, 2), ("data", "model"))
            try:
                STDService(width=0.125, buckets=(64,), max_batch=3,
                           plan=DataParallel(mesh, "data"))
                raise AssertionError("max_batch=3 on a 4-wide data axis "
                                     "must be rejected")
            except ValueError:
                pass
            dp = STDService(**kw, plan=DataParallel(mesh, "data"))
            got_seq = key([dp(img) for img in images])
            got_bat = key(dp.serve_batched(images))
            assert got_seq == want, "data-parallel sequential diverged"
            assert got_bat == want, "data-parallel batched diverged"
            plans = {{e["plan"] for e in dp.factory.stats["compiled"]}}
            assert plans == {{"data_parallel[data=4]"}}, plans

            rb = STDService(**kw, plan=RowBand(mesh, axis="model"))
            got_rb = key([rb(img) for img in images])
            assert got_rb == want, "row-band diverged"

            # over-tall image exceeding the largest bucket routes to the
            # row-band plan and matches the clamped single-device result
            # (200 -> bucket 256, already a multiple of 8 bands x 32)
            tall = np.random.default_rng(7).random(
                (200, 48, 3)).astype(np.float32)
            mesh8 = make_mesh((1, 8), ("data", "model"))
            svc_tall = STDService(**kw, tall_plan=RowBand(mesh8, axis="model"))
            got_tall = [b["box"] for b in svc_tall(tall)]
            assert any(e["plan"] == "row_band[model=8]"
                       for e in svc_tall.factory.stats["compiled"])
            ref_tall = [b["box"] for b in base(tall)]
            assert got_tall == ref_tall, "over-tall row-band diverged"

            # regression: heights whose bucket clamp (192) is NOT a
            # multiple of bands*stride must pad up to 256 and serve,
            # not crash the plan compiler
            awkward = np.random.default_rng(8).random(
                (150, 48, 3)).astype(np.float32)
            boxes = svc_tall(awkward)
            assert isinstance(boxes, list)
            assert any(e["hw"] == (256, 64) and e["plan"] == "row_band[model=8]"
                       for e in svc_tall.factory.stats["compiled"])
            print("HOST_MESH_PLANS_OK")
        """)
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=900,
        )
        assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
        assert "HOST_MESH_PLANS_OK" in out.stdout
