"""Per-kernel allclose vs ref.py oracles, with hypothesis shape/dtype
sweeps (interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # bare interpreter: seeded fallback shim
    from _hypothesis_compat import given, settings, strategies as st


class TestBFPMatmulKernel:
    @settings(max_examples=12, deadline=None)
    @given(
        st.integers(0, 1000),
        st.sampled_from([(8, 64, 8), (48, 100, 36), (128, 256, 128),
                         (17, 33, 9)]),
        st.sampled_from([7, 10]),
    )
    def test_vs_ref(self, seed, mkn, mb):
        from repro.kernels.bfp_matmul import bfp_matmul
        from repro.kernels.bfp_matmul.ref import bfp_matmul_ref

        M, K, N = mkn
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        a = jax.random.normal(k1, (M, K))
        b = jax.random.normal(k2, (K, N))
        got = bfp_matmul(a, b, mantissa_bits=mb, interpret=True)
        want = bfp_matmul_ref(a, b, mantissa_bits=mb)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_dtype_bf16_inputs(self):
        from repro.kernels.bfp_matmul import bfp_matmul

        a = jax.random.normal(jax.random.PRNGKey(0), (16, 64), jnp.bfloat16)
        b = jax.random.normal(jax.random.PRNGKey(1), (64, 16), jnp.bfloat16)
        got = bfp_matmul(a, b, mantissa_bits=7, interpret=True)
        ref = a.astype(jnp.float32) @ b.astype(jnp.float32)
        assert float(jnp.max(jnp.abs(got - ref))) / float(
            jnp.max(jnp.abs(ref))) < 0.05


class TestWinogradKernel:
    @settings(max_examples=10, deadline=None)
    @given(
        st.integers(0, 1000),
        st.sampled_from([(5, 7, 3, 5), (19, 23, 6, 10), (32, 32, 16, 8),
                         (12, 4, 1, 1)]),
    )
    def test_vs_direct(self, seed, hwcc):
        from repro.kernels.winograd_conv import winograd_conv2d
        from repro.kernels.winograd_conv.ref import direct_conv2d

        h, w, cin, cout = hwcc
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(k1, (2, h, w, cin))
        ker = jax.random.normal(k2, (3, 3, cin, cout))
        got = winograd_conv2d(x, ker, interpret=True)
        want = direct_conv2d(x, ker)
        np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)

    def test_bias_fusion(self):
        from repro.kernels.winograd_conv import winograd_conv2d
        from repro.kernels.winograd_conv.ref import direct_conv2d

        x = jax.random.normal(jax.random.PRNGKey(0), (1, 9, 9, 4))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 6))
        b = jax.random.normal(jax.random.PRNGKey(2), (6,))
        got = winograd_conv2d(x, w, b, interpret=True)
        np.testing.assert_allclose(got, direct_conv2d(x, w) + b, atol=2e-3)

    def test_bias_relu_fusion(self):
        """The full in-kernel epilogue (bias + ReLU inside the output
        transform flush) against the unfused reference."""
        from repro.kernels.winograd_conv import winograd_conv2d
        from repro.kernels.winograd_conv.ref import direct_conv2d

        x = jax.random.normal(jax.random.PRNGKey(3), (2, 10, 7, 5))
        w = jax.random.normal(jax.random.PRNGKey(4), (3, 3, 5, 6))
        b = jax.random.normal(jax.random.PRNGKey(5), (6,))
        got = winograd_conv2d(x, w, b, relu=True, interpret=True)
        want = jnp.maximum(direct_conv2d(x, w) + b, 0.0)
        np.testing.assert_allclose(got, want, atol=2e-3)
        assert float(jnp.min(got)) >= 0.0

    def test_relu_without_bias(self):
        from repro.kernels.winograd_conv import winograd_conv2d
        from repro.kernels.winograd_conv.ref import direct_conv2d

        x = jax.random.normal(jax.random.PRNGKey(6), (1, 8, 8, 4))
        w = jax.random.normal(jax.random.PRNGKey(7), (3, 3, 4, 4))
        got = winograd_conv2d(x, w, relu=True, interpret=True)
        np.testing.assert_allclose(
            got, jnp.maximum(direct_conv2d(x, w), 0.0), atol=2e-3)

    @settings(max_examples=8, deadline=None)
    @given(
        st.integers(0, 1000),
        # non-multiple-of-4 H/W so the output crop and tile padding both
        # bite; includes H or W below one 4x4 tile after VALID shrink
        st.sampled_from([(6, 9, 3, 5), (7, 7, 2, 3), (13, 5, 4, 4),
                         (5, 17, 1, 2)]),
    )
    def test_valid_padding_vs_direct(self, seed, hwcc):
        from repro.kernels.winograd_conv import winograd_conv2d
        from repro.kernels.winograd_conv.ref import direct_conv2d

        h, w, cin, cout = hwcc
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(k1, (2, h, w, cin))
        ker = jax.random.normal(k2, (3, 3, cin, cout))
        got = winograd_conv2d(x, ker, padding="VALID", interpret=True)
        want = direct_conv2d(x, ker, padding="VALID")
        assert got.shape == want.shape == (2, h - 2, w - 2, cout)
        np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)

    def test_channels_below_block_sizes(self):
        """Cin/Cout far below the bn/bk tile sizes: the _pad_axis and
        bp_=min(bp, P) clamp paths must still produce the exact conv."""
        from repro.kernels.winograd_conv import winograd_conv2d
        from repro.kernels.winograd_conv.ref import direct_conv2d

        x = jax.random.normal(jax.random.PRNGKey(8), (1, 6, 6, 2))
        w = jax.random.normal(jax.random.PRNGKey(9), (3, 3, 2, 3))
        got = winograd_conv2d(x, w, bp=128, bn=128, bk=128,
                              interpret=True)
        np.testing.assert_allclose(got, direct_conv2d(x, w), atol=2e-3)


class TestFlashAttentionKernel:
    @settings(max_examples=8, deadline=None)
    @given(
        st.integers(0, 1000),
        st.sampled_from([(1, 4, 4, 64, 16), (2, 8, 2, 257, 32),
                         (1, 6, 6, 100, 64), (2, 4, 1, 128, 32)]),
        st.booleans(),
    )
    def test_vs_dense(self, seed, shape, causal):
        from repro.kernels.flash_attention import flash_attention
        from repro.kernels.flash_attention.ref import mha_reference

        B, Hq, Hkv, L, D = shape
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (B, Hq, L, D)) * 0.3
        k = jax.random.normal(ks[1], (B, Hkv, L, D)) * 0.3
        v = jax.random.normal(ks[2], (B, Hkv, L, D))
        got = flash_attention(q, k, v, causal=causal, bq=64, bk=64,
                              interpret=True)
        want = mha_reference(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)

    def test_decode_attention_matches_full(self):
        from repro.kernels.flash_attention.ops import decode_attention
        from repro.kernels.flash_attention.ref import mha_reference

        B, H, K, S, D = 2, 8, 2, 64, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, 1, D))
        kc = jax.random.normal(ks[1], (B, K, S, D))
        vc = jax.random.normal(ks[2], (B, K, S, D))
        got = decode_attention(q, kc, vc, S)
        want = mha_reference(q, kc, vc, causal=False, kv_len=S)
        np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)


class TestSSDKernel:
    @settings(max_examples=8, deadline=None)
    @given(
        st.integers(0, 1000),
        st.sampled_from([(1, 64, 2, 8, 1, 16), (2, 256, 4, 16, 2, 24),
                         (1, 128, 8, 32, 1, 64)]),
        st.sampled_from([32, 64]),
    )
    def test_vs_recurrence(self, seed, shape, chunk):
        from repro.kernels.ssd_scan import ssd_scan
        from repro.kernels.ssd_scan.ref import ssd_reference

        Bz, L, H, P, G, N = shape
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        x = jax.random.normal(ks[0], (Bz, L, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, L, H))) * 0.5
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (Bz, L, G, N)) * 0.3
        Cm = jax.random.normal(ks[4], (Bz, L, G, N)) * 0.3
        D = jax.random.normal(ks[5], (H,))
        got = ssd_scan(x, dt, A, Bm, Cm, D, chunk=min(chunk, L),
                       interpret=True)
        want = ssd_reference(x, dt, A, Bm, Cm, D)
        np.testing.assert_allclose(got, want, atol=3e-3, rtol=3e-3)

    def test_decode_step_consistency(self):
        from repro.kernels.ssd_scan.ops import ssd_decode_step
        from repro.kernels.ssd_scan.ref import ssd_reference

        Bz, L, H, P, G, N = 2, 16, 4, 8, 2, 12
        ks = jax.random.split(jax.random.PRNGKey(3), 6)
        x = jax.random.normal(ks[0], (Bz, L, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, L, H))) * 0.5
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (Bz, L, G, N)) * 0.3
        Cm = jax.random.normal(ks[4], (Bz, L, G, N)) * 0.3
        D = jax.random.normal(ks[5], (H,))
        want = ssd_reference(x, dt, A, Bm, Cm, D)
        h = jnp.zeros((Bz, H, P, N))
        for t in range(L):
            h, y = ssd_decode_step(h, x[:, t], dt[:, t], A, Bm[:, t],
                                   Cm[:, t], D)
            np.testing.assert_allclose(y, want[:, t], atol=2e-3, rtol=2e-3)
