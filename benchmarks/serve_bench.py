"""STD serving benchmark (the Fig. 9a comparison), two load models:

closed-loop — sequential vs C4-pipelined vs dynamic micro-batched on a
seeded mixed-resolution request stream; reports TPS and p50/p99
per-request latency per mode.

open-loop (``--open-loop``) — Poisson arrivals at one or more offered
rates (``--rates``, requests/s): requests are submitted on a seeded
exponential-interarrival clock regardless of completions, the way real
traffic hits a service.  Reports offered vs achieved TPS, p50/p99
latency, and admission-control sheds per rate — the knee where achieved
TPS flattens and latency diverges is the service's capacity.  Each rate
sweeps the async pipeline depth (``--inflight``, always including the
fully synchronous ``0`` baseline) and reports the overlap gain:
achieved TPS at depth N over achieved TPS on the serialized path at the
same offered load.

Each mode is warmed on the same stream first (compiles are a one-time
deployment cost in the paper's serving story; the steady-state pass is
the measurement), then timed.

``--plan`` picks the ExecutionPlan the service runs on a host device
mesh (``--mesh-shape DATA MODEL``): ``single`` (default), ``data``
(batch over "data"), ``rowband`` (rows over "model"), ``grid`` (both at
once — the composed §IV plan), or ``auto`` (cost-model routing per
bucket via runtime/planner.py).  For row-banded plans the buckets are
rounded up to the band-height unit.  Every run also prints a
``serve_plan`` line per bucket: the plan the cost model would choose and
its estimated step cost — under ``auto`` that choice is also what
actually ran.

calibration (``--calibrate out.json``) — no load model at all: time
every eligible (bucket, plan, batch) combo with blocked steps, fit the
``runtime/planner.CostParams`` constants by least squares
(runtime/telemetry.fit_cost_params), save them to JSON.
``--cost-params out.json`` reloads the fit into the planner, so
``--plan auto`` routes on measured constants instead of the v5e napkin
defaults (the ROADMAP "calibrated cost model" loop).

precision A/B (``--precision bfp``) — the numerics sweep: build one f32
and one bfp service over the same buckets (PRNGKey(0) determinism means
both run ONE underlying weight set — the bfp side through the paper's
Fig. 4 normalization), time blocked steps per (bucket, batch) into each
service's CostBook, and report per-bucket per-precision step walls plus
the bfp/f32 speedup.  Every bucket must pass the accuracy-parity gate
first (docs/serving.md "Precision modes"): bfp score/link maps stay
within an eps accuracy budget of f32 AND the recovered boxes match
exactly once pixels inside the eps margin of the 0.5 threshold are
excluded — confident disagreements fail the run.

memplan A/B (``--memplan``) — the memory-planner sweep: a memplan-off
service at the fixed ``--max-batch`` vs a memplan-on service whose
``activation_budget_bytes`` is sized from the largest bucket's planned
peak (core/memplan.py) so that bucket's admissible batch caps below the
fixed max while a smaller bucket is admitted above it.  Gates: EXACT
box parity over the model x plan x precision matrix, >= 20% measured
temp-bytes reduction (AOT buffer assignment, hlo_analysis) on the
largest bucket, and at least one raised cap; reports planned-vs-
measured bytes, per-bucket caps, and serve_batched TPS for both sides.

fleet A/B (``--replicas N --router round_robin p99 least_loaded``) —
the pod-scale sweep: N replicated services, each with its own
replica-labelled CostBook, behind a launch/router.Router; ONE seeded
request stream (alternating interactive/batch deadline classes) runs
once per named routing policy, and the report carries a per-policy
``--router`` axis: TPS, p50/p99 request latency, placements per
replica, sheds per deadline class, and how many replicas the online
refit re-calibrated from their live books.  One host makes the
replicas homogeneous, so policies should land within noise of each
other here — the heterogeneous-fleet separations (p99 routing beating
round robin on tail latency, batch shedding before interactive) are
pinned deterministically on a FakeClock in tests/test_router.py.

postprocess A/B (``--postprocess device``) — the serving-tail sweep:
serve one seeded request stream through a host-postprocess and a
device-postprocess service (identical weights and routing), gate on
EXACT box parity for every request and bucket, and report per-mode
complete-stage busy time, total ``stage="postprocess"`` walls, TPS, and
p50/p99 — the run fails unless the device path measurably reduces the
postprocess wall (docs/serving.md "Postprocess pipeline").

Run:  PYTHONPATH=src python -m benchmarks.serve_bench --requests 32
      PYTHONPATH=src python -m benchmarks.serve_bench --requests 64 \
          --open-loop --rates 8 32 128 --inflight 1 2 4
      XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.serve_bench --requests 16 \
          --plan grid --mesh-shape 2 4 --open-loop --rates 8
      PYTHONPATH=src python -m benchmarks.serve_bench \
          --calibrate /tmp/cost.json --buckets 64 128 --max-batch 4
      PYTHONPATH=src python -m benchmarks.serve_bench --plan auto \
          --cost-params /tmp/cost.json
      PYTHONPATH=src python -m benchmarks.serve_bench --precision bfp \
          --buckets 64 --width 0.125 --max-batch 4
      PYTHONPATH=src python -m benchmarks.serve_bench --replicas 2 \
          --router round_robin p99 --buckets 64 --width 0.125
      PYTHONPATH=src python -m benchmarks.serve_bench --memplan \
          --width 0.125 --buckets 64 128 --max-batch 4 \
          --model pixellink --memplan-plans single \
          --memplan-precisions f32
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _pctl(xs, q):
    return float(np.percentile(np.asarray(xs), q) * 1e3) if len(xs) else 0.0


DEEPEST_STRIDE = 32      # vgg16 stride pyramid -> band-height unit factor
                         # (assumption checked against the real model by
                         # _check_band_units once the service exists)


def _check_band_units(svc, planner, plan_kind, buckets):
    """The bucket rounding in _plan_setup assumed DEEPEST_STRIDE; verify
    it against the stride pyramid of the model the service actually
    built, so a backbone/merge change fails here with a clear message
    instead of a ValueError from the plan compiler mid-sweep."""
    if plan_kind not in ("rowband", "grid"):
        return
    top = max(buckets)
    deepest = svc.factory.deepest_stride((top, top))
    unit = planner.height_unit(deepest)
    bad = [b for b in buckets if b % unit]
    if bad:
        raise SystemExit(
            f"buckets {bad} are not multiples of the band-height unit "
            f"{unit} (model deepest stride {deepest} x {planner.model_n} "
            f"bands != assumed {DEEPEST_STRIDE}); adjust --buckets or "
            f"--mesh-shape"
        )


def _plan_setup(plan_kind, mesh_shape, buckets, max_batch,
                cost_params=None):
    """Resolve ``--plan``/``--mesh-shape`` into STDService kwargs, the
    cost-model planner used for the per-bucket report column, and the
    (possibly band-unit-rounded) buckets.  ``cost_params`` is a fitted
    constants file from ``--calibrate`` (see run_calibration); when
    given, the planner's analytic model runs on the fitted constants
    instead of the v5e napkin defaults."""
    import jax
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.executor import DataParallel, GridPlan, RowBand
    from repro.runtime.planner import Planner
    from repro.runtime.telemetry import load_cost_params

    n = jax.device_count()
    if mesh_shape is None:
        mesh_shape = {
            "single": (1, 1),
            "data": (n, 1),
            "rowband": (1, n),
        }.get(plan_kind, (2, n // 2) if n % 2 == 0 and n > 1 else (1, n))
    mesh = make_host_mesh(tuple(mesh_shape), ("data", "model"))
    params = load_cost_params(cost_params) if cost_params else None
    planner = Planner(mesh, params=params)
    kw = {}
    if plan_kind == "data":
        kw["plan"] = DataParallel(mesh)
    elif plan_kind == "rowband":
        kw["plan"] = RowBand(mesh)
    elif plan_kind == "grid":
        kw["plan"] = GridPlan(mesh)
    elif plan_kind == "auto":
        kw["planner"] = planner
    elif plan_kind != "single":
        raise SystemExit(f"unknown --plan {plan_kind!r}")
    if plan_kind in ("rowband", "grid"):
        # every bucket height must divide into bands x deepest stride
        unit = planner.height_unit(DEEPEST_STRIDE)
        buckets = tuple(sorted({-(-b // unit) * unit for b in buckets}))
    dn = planner.data_n if plan_kind in ("data", "grid", "auto") else 1
    if max_batch % max(dn, 1):
        raise SystemExit(
            f"--max-batch {max_batch} must be a multiple of the mesh "
            f"data axis {dn} for --plan {plan_kind}"
        )
    return kw, planner, tuple(buckets)


def report_plan_choices(svc, planner, max_batch, verbose=True):
    """The planner-choice column: for every bucket the service compiled,
    what the cost model routes it to (and at what estimated step cost)
    next to what actually ran.  Under --plan auto the service records
    its live routing decisions in stats["plan_choices"] — report those
    (they were made at the batches that actually formed); for fixed
    plans fall back to a hypothetical choice at max_batch."""
    from repro.runtime.executor import describe_plan

    planner.bind_features(svc._plan_features)
    routed = svc.stats.get("plan_choices", {})
    ran = {}
    for e in svc.factory.stats["compiled"]:
        ran.setdefault(e["hw"], set()).add(e["plan"])
    rows = {}
    for hw in sorted(ran):
        choice = routed.get(hw) or describe_plan(
            planner.choose(hw, max_batch))
        # the estimate must belong to the plan named on the row — a
        # routed choice may not be the argmin (force_banded, or routing
        # happened at a different live batch)
        table = planner.costs(hw, max_batch)
        kind = choice.split("[", 1)[0]
        est_us = table.get(kind, min(table.values())) * 1e6
        rows[hw] = {"planner": choice, "est_us": est_us,
                    "ran": sorted(ran[hw])}
        if verbose:
            print(f"serve_plan,bucket={hw[0]}x{hw[1]},"
                  f"planner={choice},est {est_us:.0f} us,"
                  f"ran={'/'.join(sorted(ran[hw]))}")
    return rows


def run_calibration(out_path: str, *, width: float = 0.25,
                    buckets=(64, 128), max_batch: int = 8,
                    mesh_shape=None, steps: int = 3,
                    verbose: bool = True):
    """The measured half of the cost model: sweep every eligible
    (bucket, plan_kind, batch) combo on the current mesh, time ``steps``
    blocked-until-materialized engine steps each (after one warmup call
    that absorbs the jit compile), least-squares fit the CostParams
    constants from the measurements (runtime/telemetry.fit_cost_params
    — the analytic step cost is linear in them), and save the fit to
    ``out_path`` JSON.  ``--cost-params out_path`` reloads it into the
    planner, so routing on THIS backend runs on constants this backend
    actually exhibited."""
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import STDService
    from repro.runtime.executor import plan_batch_multiple
    from repro.runtime.planner import Planner, eligible_kinds
    from repro.runtime.telemetry import (
        CostBook,
        StepMeasurement,
        fit_cost_params,
        save_cost_params,
    )

    if steps < 1:
        raise SystemExit("--calib-steps must be >= 1")
    n = jax.device_count()
    if mesh_shape is None:
        mesh_shape = (2, n // 2) if n % 2 == 0 and n > 1 else (1, n)
    mesh = make_host_mesh(tuple(mesh_shape), ("data", "model"))
    planner = Planner(mesh)
    if planner.model_n > 1:
        unit = planner.height_unit(DEEPEST_STRIDE)
        buckets = tuple(sorted({-(-b // unit) * unit for b in buckets}))
    if max_batch % max(planner.data_n, 1):
        raise SystemExit(
            f"--max-batch {max_batch} must be a multiple of the mesh "
            f"data axis {planner.data_n}"
        )
    # measured_routing off: the sweep must visit every plan kind at
    # fixed, analytic-routing-independent combos, not chase its own
    # measurements around
    svc = STDService(width=width, buckets=tuple(buckets),
                     max_batch=max_batch, planner=planner,
                     engine_cache_capacity=0, measured_routing=False)
    _check_band_units(svc, planner,
                      "grid" if planner.model_n > 1 else "single", buckets)

    book = CostBook(warmup=0)      # the sweep warms explicitly below
    rows = []
    batch_points = sorted({1, max(1, max_batch // 2), max_batch})
    for bkt in buckets:
        hw = (bkt, bkt)
        feats = svc._plan_features(hw)
        kinds = eligible_kinds(hw, data_n=planner.data_n,
                               model_n=planner.model_n,
                               deepest_stride=feats.deepest_stride)
        seen = set()
        for kind in kinds:
            plan = planner.plan_for_kind(kind)
            m = plan_batch_multiple(plan)
            for b0 in batch_points:
                b = -(-b0 // m) * m          # divisibility padding
                if b > max_batch or (kind, b) in seen:
                    continue
                seen.add((kind, b))
                fn = svc.factory.plan_fn(hw, b, plan)
                params = svc.factory.params(hw)
                x = jnp.zeros((b, hw[0], hw[1], 3), jnp.float32)
                vq = jnp.asarray([[hw[0] // 4, hw[1] // 4]] * b,
                                 jnp.int32)
                jax.block_until_ready(fn(params, x, vq))   # compile+warm
                for _ in range(steps):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(params, x, vq))
                    dt = time.perf_counter() - t0
                    book.record_step(hw, b, kind, dt)
                    rows.append(StepMeasurement(
                        flops=feats.flops, halo_bytes=feats.halo_bytes,
                        halo_layers=feats.halo_layers, kind=kind,
                        batch=b, data_n=planner.data_n,
                        model_n=planner.model_n, seconds=dt,
                    ))
                if verbose:
                    p50 = book.step_percentile(hw, b, kind, 50)
                    print(f"calibrate,bucket={hw[0]}x{hw[1]},"
                          f"plan={kind},batch={b},"
                          f"p50 {p50 * 1e3:.2f} ms,steps={steps}")
    fitted = fit_cost_params(rows)
    save_cost_params(fitted, out_path, measurements=rows, meta={
        "width": width, "buckets": list(buckets),
        "mesh_shape": list(mesh_shape), "max_batch": max_batch,
        "steps": steps, "backend": jax.default_backend(),
    })
    if verbose:
        from repro.runtime.telemetry import cost_params_to_dict

        for k, v in cost_params_to_dict(fitted).items():
            print(f"calibrate_fit,{k}={v:.6g}")
        fit_planner = Planner(mesh, params=fitted)
        fit_planner.bind_features(svc._plan_features)
        for bkt in buckets:
            hw = (bkt, bkt)
            for b in (1, max_batch):
                from repro.runtime.executor import describe_plan

                print(f"calibrate_route,bucket={hw[0]}x{hw[1]},"
                      f"batch={b},"
                      f"plan={describe_plan(fit_planner.choose(hw, b))}")
        print(f"calibrate_saved,{out_path},rows={len(rows)}")
    return fitted


PARITY_EPS = 0.05      # accuracy budget for the bfp-vs-f32 parity gate:
                       # max |bfp - f32| over score/link probabilities,
                       # and the 0.5-threshold margin inside which pixel
                       # decisions are excluded from box comparison


def precision_parity_gate(score_f, links_f, score_b, links_b, *,
                          eps: float = PARITY_EPS,
                          score_thr: float = 0.5, link_thr: float = 0.5):
    """The bfp-vs-f32 accuracy-parity check, per batch of probability
    maps (same weights, two numerics).  Two conditions:

      1. ``0 < max|bfp - f32| < eps`` — the upper bound is the accuracy
         budget; the LOWER bound proves the bfp side actually quantized
         (a cross-precision engine-cache bug would produce exact zeros).
      2. boxes under the 0.5-threshold guard: pixels whose f32
         probability sits within ``eps`` of the threshold are excluded
         (clamped to the f32 value — a near-threshold flip is noise, not
         an accuracy loss); every remaining pixel decision, and so the
         recovered boxes, must match EXACTLY.  A confident disagreement
         (f32 says 0.9 text, bfp says 0.2) breaks the equality.

    Returns ``(max_delta, boxes_equal)``.
    """
    import jax.numpy as jnp

    from repro.models.fcn import postprocess as pp

    d = max(float(jnp.max(jnp.abs(score_b - score_f))),
            float(jnp.max(jnp.abs(links_b - links_f))))
    sc = jnp.where(jnp.abs(score_f - score_thr) <= eps, score_f, score_b)
    lc = jnp.where(jnp.abs(links_f - link_thr) <= eps, links_f, links_b)

    def boxes(s, l):
        return [
            sorted(bx["box"] for bx in pp.boxes_from_labels(
                np.asarray(pp.cc_label(s[i], l[i], score_thr, link_thr))))
            for i in range(s.shape[0])
        ]

    return d, boxes(score_f, links_f) == boxes(sc, lc)


def run_precision_ab(*, width: float = 0.25, buckets=(64, 128),
                     max_batch: int = 8, steps: int = 3,
                     eps: float = PARITY_EPS, seed: int = 0,
                     verbose: bool = True):
    """f32-vs-bfp A/B over the full bucket grid: per (bucket, batch)
    blocked step walls from each service's CostBook (the per-precision
    ``stage="step"`` series measured routing reads), gated by the
    accuracy-parity check on every bucket.  Both services are seeded
    identically, so the bfp side serves the SAME weights through the
    paper's Fig. 4 normalization — the comparison is numerics-only."""
    import jax.numpy as jnp

    from repro.launch.serve import STDService
    from repro.runtime.telemetry import CostBook

    if steps < 1:
        raise SystemExit("--calib-steps must be >= 1")
    svcs = {
        prec: STDService(width=width, buckets=tuple(buckets),
                         max_batch=max_batch, engine_cache_capacity=0,
                         book=CostBook(warmup=0), precision=prec)
        for prec in ("f32", "bfp")
    }
    rng = np.random.default_rng(seed)
    batch_points = sorted({1, max(1, max_batch // 2), max_batch})
    out = {}
    for bkt in buckets:
        hw = (bkt, bkt)
        # -- parity gate first: a bucket that fails accuracy must not
        # report a speedup
        x1 = rng.random((1, hw[0], hw[1], 3)).astype(np.float32)
        maps = {}
        for prec, svc in svcs.items():
            model = svc.factory.model(hw, prec)
            params = svc.factory.params(hw, prec)
            o = model.apply(params, jnp.asarray(x1))
            maps[prec] = (o["score"], o["links"])
        d, boxes_equal = precision_parity_gate(
            *maps["f32"], *maps["bfp"], eps=eps,
            score_thr=svcs["f32"].factory.score_thr,
            link_thr=svcs["f32"].factory.link_thr)
        if verbose:
            print(f"precision_parity,bucket={hw[0]}x{hw[1]},"
                  f"max_delta={d:.4g},boxes_equal={boxes_equal}")
        if not 0.0 < d < eps:
            raise SystemExit(
                f"precision parity FAILED at bucket {hw}: max bfp-f32 "
                f"delta {d:.4g} outside (0, {eps}) — zero means the bfp "
                f"engine never quantized (cross-precision cache hit?), "
                f"past eps means the accuracy budget is blown"
            )
        if not boxes_equal:
            raise SystemExit(
                f"precision parity FAILED at bucket {hw}: boxes diverge "
                f"beyond the {eps}-margin 0.5-threshold guard"
            )
        # -- timed A/B: blocked steps into each service's book
        for b in batch_points:
            x = rng.random((b, hw[0], hw[1], 3)).astype(np.float32)
            vhws = [(hw[0], hw[1])] * b
            row = {}
            for prec, svc in svcs.items():
                svc.infer_labels(x, vhws)          # compile + warm
                for _ in range(steps):
                    svc.infer_labels(x, vhws)
                row[prec] = svc.book.step_percentile(
                    hw, b, "single_device", 50, precision=prec)
            row["speedup"] = (row["f32"] / row["bfp"]
                              if row["bfp"] else float("nan"))
            out[(hw, b)] = dict(row, max_delta=d)
            if verbose:
                print(f"precision_ab,bucket={hw[0]}x{hw[1]},batch={b},"
                      f"f32 p50 {row['f32'] * 1e3:.2f} ms,"
                      f"bfp p50 {row['bfp'] * 1e3:.2f} ms,"
                      f"speedup x{row['speedup']:.2f}")
    return out


def run_postprocess_ab(*, requests: int = 48, width: float = 0.25,
                       buckets=(64, 128), max_batch: int = 8,
                       max_wait_ms: float = 8.0, seed: int = 0,
                       boxes_capacity: int = 256, pre_workers: int = 4,
                       steps: int = 3, verbose: bool = True):
    """Host-vs-device postprocess A/B on ONE seeded request stream.

    Both services share weights (PRNGKey(0) determinism) and routing;
    only the serving tail differs — full label-plane D2H + host box
    extraction vs compact on-device rows + trivial decode.  The gate is
    EXACT box parity on every request, reported per bucket; the
    measurement is each mode's ``stage="postprocess"`` wall over a
    BLOCKED single-threaded pass (``steps`` repeats per request — the
    serving-concurrent walls also land in each book, but post workers
    contend for the GIL with dispatch/completion there, so the blocked
    pass is what the reduction gate reads, the same pattern as the
    precision A/B's blocked steps).  Completion-stage busy time, TPS,
    and p50/p99 from the concurrent serving pass are reported alongside.
    Fails unless boxes match everywhere AND the device path's blocked
    postprocess wall is below the host's (the tail reduction this mode
    exists for)."""
    from repro.data.images import RequestStream
    from repro.launch.serve import STDService, bucket_hw
    from repro.runtime.telemetry import CostBook

    if requests < 1:
        raise SystemExit("--requests must be >= 1")
    images = RequestStream(
        requests, seed=seed,
        hw_range=((48, max(buckets)), (48, max(buckets))),
    ).images()
    svcs, results = {}, {}
    for mode in ("host", "device"):
        svc = STDService(width=width, buckets=tuple(buckets),
                         max_batch=max_batch, max_wait_ms=max_wait_ms,
                         engine_cache_capacity=0, inflight=1,
                         book=CostBook(warmup=0), postprocess=mode,
                         boxes_capacity=boxes_capacity)
        svc.serve_batched(images, pre_workers=pre_workers)   # warm/compile
        results[mode] = svc.serve_batched(images,
                                          pre_workers=pre_workers)
        svcs[mode] = svc

    # -- exact-parity gate, reported per bucket ----------------------------
    per_bucket: dict = {}
    for i, img in enumerate(images):
        bkt = bucket_hw(img.shape[0], img.shape[1], tuple(buckets))
        ok = ([b["box"] for b in results["host"][i]]
              == [b["box"] for b in results["device"][i]])
        n_ok, n_all = per_bucket.get(bkt, (0, 0))
        per_bucket[bkt] = (n_ok + ok, n_all + 1)
    for bkt, (n_ok, n_all) in sorted(per_bucket.items()):
        if verbose:
            print(f"postprocess_parity,bucket={bkt[0]}x{bkt[1]},"
                  f"boxes_equal={n_ok}/{n_all}")
        if n_ok != n_all:
            raise SystemExit(
                f"postprocess parity FAILED at bucket {bkt}: "
                f"{n_all - n_ok}/{n_all} requests' device boxes diverge "
                f"from the host path"
            )

    # -- blocked postprocess measurement (single-threaded, the gate) -------
    def pp_wall_sum(svc):
        return sum(
            svc.book.step_total(hw, b, kind, stage="postprocess")
            for (hw, b, kind) in svc.book.step_keys(stage="postprocess")
        )

    blocked = {}
    for mode, svc in svcs.items():
        before = pp_wall_sum(svc)
        for img in images:
            x, valid, tr = svc.preprocess(img)
            payload = svc._finalize(svc._dispatch(x[None], [valid]))[0]
            for _ in range(max(steps, 1)):
                svc.postprocess(payload, valid, tr,
                                bucket_hw=tuple(x.shape[:2]))
        blocked[mode] = pp_wall_sum(svc) - before

    # -- busy-time / throughput report -------------------------------------
    out = {}
    for mode, svc in svcs.items():
        mb = svc.stats["batching"]
        lat = svc.stats["batched_latency_s"]
        out[mode] = {
            "tps": svc.stats["batched_tps"],
            "p50_ms": _pctl(lat, 50),
            "p99_ms": _pctl(lat, 99),
            "complete_busy_s": mb["complete_busy_s"],
            "post_busy_s": mb["post_busy_s"],
            "postprocess_wall_s": blocked[mode],
            "overflows": svc.stats["pp_overflow"],
            "nonconverged": svc.stats["nonconverged"],
        }
        if verbose:
            r = out[mode]
            print(f"postprocess_ab,mode={mode},"
                  f"tps {r['tps']:.2f},"
                  f"p50 {r['p50_ms']:.1f} ms,p99 {r['p99_ms']:.1f} ms,"
                  f"complete_busy {r['complete_busy_s'] * 1e3:.1f} ms,"
                  f"pp_wall {r['postprocess_wall_s'] * 1e3:.1f} ms,"
                  f"overflows {r['overflows']}")
    host_w, dev_w = (out["host"]["postprocess_wall_s"],
                     out["device"]["postprocess_wall_s"])
    if verbose:
        red = 1.0 - dev_w / host_w if host_w > 0 else float("nan")
        dc = (out["host"]["complete_busy_s"]
              - out["device"]["complete_busy_s"])
        print(f"postprocess_ab,pp_wall_reduction {red * 100:.1f}%,"
              f"complete_busy_delta {dc * 1e3:+.1f} ms")
    if not dev_w < host_w:
        raise SystemExit(
            f"postprocess A/B FAILED: device pp wall {dev_w * 1e3:.2f} ms "
            f"not below host {host_w * 1e3:.2f} ms — the compact tail "
            f"should always beat full-plane host extraction"
        )
    return out


def run_model_zoo(models, *, requests: int = 8, width: float = 0.25,
                  buckets=(64,), max_batch: int = 4,
                  max_wait_ms: float = 8.0, seed: int = 0,
                  pre_workers: int = 4, verbose: bool = True):
    """Per-model box-parity gate + serving smoke over the detection zoo.

    For each model, every request in ONE seeded stream runs a single
    eager forward to materialize the head's maps, then BOTH decoders
    consume those same maps: the serving path (the head's device tail +
    ``decode``) and the head's pure-NumPy ``reference_decode`` oracle.
    Comparing decodes of one map set gates the decode algorithms
    themselves — jit-vs-eager forward numerics stay out of it, which
    matters because random-init sigmoid scores cluster near the 0.5
    threshold where a float-reassociation wiggle flips pixels.  The
    gate is exact box-set equality per bucket (SystemExit on any
    mismatch), followed by a micro-batched serving smoke through the
    model's own compiled engines."""
    import jax.numpy as jnp

    from repro.data.images import RequestStream
    from repro.launch.serve import STDService, bucket_hw
    from repro.runtime.telemetry import CostBook

    if requests < 1:
        raise SystemExit("--requests must be >= 1")
    images = RequestStream(
        requests, seed=seed,
        hw_range=((48, max(buckets)), (48, max(buckets))),
    ).images()
    out = {}
    for name in models:
        svc = STDService(width=width, buckets=tuple(buckets),
                         max_batch=max_batch, max_wait_ms=max_wait_ms,
                         engine_cache_capacity=0,
                         book=CostBook(warmup=0), model=name)
        head = svc.head
        per_bucket: dict = {}
        for img in images:
            x, valid, tr = svc.preprocess(img)
            hw = tuple(x.shape[:2])
            model = svc.factory.model(hw, "f32", name)
            params = svc.factory.params(hw, "f32", name)
            maps = model.apply(params, jnp.asarray(x[None]))
            vq = jnp.asarray([[valid[0] // 4, valid[1] // 4]], jnp.int32)
            tail = head.tail(svc.factory, maps, vq)
            arrs = [np.asarray(a)[0] for a in tail[:head.n_payload]]
            payload = arrs[0] if head.n_payload == 1 else tuple(arrs)
            got, _ = head.decode(payload, valid)
            ref = head.reference_decode(
                {k: np.asarray(v[0]) for k, v in maps.items()
                 if k != "logits"},
                valid,
            )
            ok = (sorted(b["box"] for b in got)
                  == sorted(b["box"] for b in ref))
            bkt = bucket_hw(img.shape[0], img.shape[1], tuple(buckets))
            n_ok, n_all = per_bucket.get(bkt, (0, 0))
            per_bucket[bkt] = (n_ok + ok, n_all + 1)
        for bkt, (n_ok, n_all) in sorted(per_bucket.items()):
            if verbose:
                print(f"model_parity,model={name},"
                      f"bucket={bkt[0]}x{bkt[1]},"
                      f"boxes_equal={n_ok}/{n_all}")
            if n_ok != n_all:
                raise SystemExit(
                    f"model-zoo parity FAILED for {name!r} at bucket "
                    f"{bkt}: {n_all - n_ok}/{n_all} requests' serving "
                    f"decode diverges from the NumPy reference decode"
                )
        results = svc.serve_batched(images, pre_workers=pre_workers)
        out[name] = {
            "tps": svc.stats["batched_tps"],
            "boxes": [len(r) for r in results],
            "parity": {f"{b[0]}x{b[1]}": v for b, v in per_bucket.items()},
            "compiled": list(svc.factory.stats["compiled"]),
        }
        if verbose:
            print(f"model_zoo,model={name},"
                  f"tps {out[name]['tps']:.2f},"
                  f"boxes {sum(out[name]['boxes'])},"
                  f"engines {len(out[name]['compiled'])}")
    return out


def run_memplan_ab(*, width: float = 0.25, buckets=(64, 128),
                   max_batch: int = 8, requests: int = 16,
                   max_wait_ms: float = 8.0, seed: int = 0,
                   models=("pixellink", "east", "db"),
                   plans=("single", "data", "rowband", "grid"),
                   precisions=("f32", "bfp"),
                   parity_images: int = 2, min_reduction: float = 0.2,
                   pre_workers: int = 4, verbose: bool = True):
    """Memory-planner A/B (docs/plans.md "Memory planning").

    XLA already schedules buffers liveness-optimally inside one engine,
    so the plan's lever on MEASURED memory is batching: the memplan-on
    service gets an ``activation_budget_bytes`` sized so the largest
    bucket's admissible batch (budget // planned-peak-per-image, the
    core.memplan ``admissible_batch`` rule) lands BELOW the fixed
    ``--max-batch`` while smaller buckets — smaller footprints — are
    admitted ABOVE it.  Per-image boxes are batch-invariant, so this is
    free of accuracy cost, and the run proves both halves:

      parity — memplan-on vs memplan-off services (same PRNGKey(0)
      weights) must produce EXACTLY equal box sets for every request
      across the full ``models`` x ``plans`` x ``precisions`` matrix
      (the planned schedule, fusion facts, and drop-at-last-use must
      not change a single output);

      memory — on the LARGEST bucket, the memplan-on engine's measured
      temp bytes (AOT buffer assignment via hlo_analysis) must be at
      least ``min_reduction`` below the memplan-off engine's at the
      fixed max batch, while at least one smaller bucket's admissible
      cap exceeds ``--max-batch`` (the throughput the planner buys
      back with the bytes it saved).

    A closing ``serve_batched`` pass on one seeded stream reports TPS
    for both services — the caps must also hold up under the live
    scheduler, not just in the gauge math."""
    from repro.data.images import RequestStream
    from repro.launch.serve import STDService
    from repro.runtime.telemetry import CostBook

    if requests < 1:
        raise SystemExit("--requests must be >= 1")
    if max_batch < 2:
        raise SystemExit("--max-batch must be >= 2 so the budget can cap "
                         "the largest bucket strictly below it")
    buckets = tuple(sorted(set(buckets)))
    if len(buckets) < 2:
        raise SystemExit("--memplan needs >= 2 buckets: the A/B shows the "
                         "largest capped below --max-batch AND a smaller "
                         "one admitted above it")
    models = list(dict.fromkeys(models))
    plans = list(dict.fromkeys(plans))
    precisions = list(dict.fromkeys(precisions))
    rng = np.random.default_rng(seed)
    out = {}
    for name in models:
        # -- budget: cap the largest bucket at ~half the fixed max batch
        probe = STDService(width=width, buckets=buckets,
                           max_batch=max_batch, engine_cache_capacity=0,
                           book=CostBook(warmup=0), model=name)
        big = (buckets[-1], buckets[-1])
        peak_img = int(probe.factory.memplan(big, "f32", name).peak_bytes)
        cap_target = max(1, max_batch // 2)
        budget = peak_img * cap_target
        svc_pair = {}          # the single/f32 pair the memory gate reads
        parity = {}
        for plan_kind in plans:
            kw, _, bkts = _plan_setup(plan_kind, None, buckets, max_batch)
            for prec in precisions:
                mk = lambda on: STDService(
                    width=width, buckets=bkts, max_batch=max_batch,
                    max_wait_ms=max_wait_ms, engine_cache_capacity=0,
                    book=CostBook(warmup=0), model=name, precision=prec,
                    memplan=on,
                    activation_budget_bytes=budget if on else None,
                    **kw)
                svcs = {"off": mk(False), "on": mk(True)}
                if plan_kind == "single" and prec == "f32":
                    svc_pair = dict(svcs)
                lo = 48
                for bkt in bkts:
                    hw = (max(lo, bkt - 5), max(lo, bkt - 7))
                    lo = bkt + 1
                    n_ok = 0
                    for _ in range(parity_images):
                        img = (rng.random((hw[0], hw[1], 3)) * 255.0
                               ).astype(np.float32)
                        got = {
                            side: sorted(b["box"] for b in svc(img))
                            for side, svc in svcs.items()
                        }
                        n_ok += got["on"] == got["off"]
                    parity[(plan_kind, prec, bkt)] = (n_ok, parity_images)
                    if verbose:
                        print(f"memplan_parity,model={name},"
                              f"plan={plan_kind},precision={prec},"
                              f"bucket={bkt}x{bkt},"
                              f"boxes_equal={n_ok}/{parity_images}")
                    if n_ok != parity_images:
                        raise SystemExit(
                            f"memplan parity FAILED for {name!r} "
                            f"plan={plan_kind} precision={prec} at bucket "
                            f"{bkt}: {parity_images - n_ok}/{parity_images}"
                            f" requests' boxes diverge between the "
                            f"planned and unplanned engines"
                        )
        # -- admissible-batch caps: largest below max_batch, some bucket
        # above it (svc_pair exists: plans/precisions are non-empty and
        # the single/f32 combo is required for the gate)
        if "on" not in svc_pair:
            raise SystemExit("--memplan-plans must include 'single' and "
                             "--memplan-precisions 'f32' (the memory gate "
                             "measures that pair)")
        svc_on, svc_off = svc_pair["on"], svc_pair["off"]
        caps = {(b, b): svc_on._bucket_cap((b, b)) for b in buckets}
        for hw, cap in sorted(caps.items()):
            if verbose:
                print(f"memplan_cap,model={name},bucket={hw[0]}x{hw[1]},"
                      f"cap={cap},max_batch={max_batch}")
        if caps[big] >= max_batch:
            raise SystemExit(
                f"memplan cap at the largest bucket {big} is {caps[big]} "
                f">= --max-batch {max_batch}; the budget failed to bind"
            )
        if not any(c > max_batch for c in caps.values()):
            raise SystemExit(
                f"no bucket's admissible batch exceeds --max-batch "
                f"{max_batch} under budget {budget} — caps {caps}"
            )
        # -- measured memory on the largest bucket: off at the fixed max
        # batch vs on at its capped batch
        rows = {side: svc.measure_engine_memory(big)
                for side, svc in (("off", svc_off), ("on", svc_on))}
        if any("temp_bytes" not in r for r in rows.values()):
            raise SystemExit(
                "backend exposes no memory_analysis(); the --memplan "
                "reduction gate cannot run here"
            )
        reduction = 1.0 - (rows["on"]["temp_bytes"]
                           / max(rows["off"]["temp_bytes"], 1))
        if verbose:
            print(f"memplan_mem,model={name},bucket={big[0]}x{big[1]},"
                  f"batch_off={rows['off']['batch']},"
                  f"temp_off={rows['off']['temp_bytes']},"
                  f"batch_on={rows['on']['batch']},"
                  f"temp_on={rows['on']['temp_bytes']},"
                  f"planned_on={rows['on']['planned_peak_bytes']},"
                  f"reduction={reduction:.2f}")
        if reduction < min_reduction:
            raise SystemExit(
                f"memplan memory gate FAILED for {name!r}: temp bytes "
                f"reduction {reduction:.2f} < {min_reduction} at bucket "
                f"{big} ({rows['off']['temp_bytes']} -> "
                f"{rows['on']['temp_bytes']})"
            )
        # -- serving smoke: the caps must hold under the live scheduler
        images = RequestStream(
            requests, seed=seed,
            hw_range=((48, buckets[-1]), (48, buckets[-1])),
        ).images()
        tps = {}
        for side, svc in (("off", svc_off), ("on", svc_on)):
            svc.serve_batched(images, pre_workers=pre_workers)
            tps[side] = svc.stats["batched_tps"]
        n_caps = sum(1 for k in svc_on.metrics_snapshot()
                     if k.startswith("std_bucket_batch_cap"))
        if verbose:
            print(f"memplan_serve,model={name},"
                  f"tps_off {tps['off']:.2f},tps_on {tps['on']:.2f},"
                  f"cap_gauges={n_caps}")
        out[name] = {
            "budget_bytes": budget,
            "caps": {f"{h}x{w}": c for (h, w), c in sorted(caps.items())},
            "parity": {f"{p}/{pr}/{b}": v
                       for (p, pr, b), v in sorted(parity.items())},
            "temp_bytes": {s: rows[s]["temp_bytes"] for s in rows},
            "planned_peak_bytes": rows["on"]["planned_peak_bytes"],
            "reduction": reduction,
            "tps": tps,
        }
    return out


def run_fleet_ab(policies, *, replicas: int = 2, requests: int = 16,
                 width: float = 0.25, buckets=(64,), max_batch: int = 4,
                 max_wait_ms: float = 8.0, seed: int = 0,
                 max_outstanding: int = 0, verbose: bool = True):
    """Replicated-serving A/B (docs/serving.md "Fleet"): ONE seeded
    request stream through ``replicas`` STDServices behind a
    launch/router.Router, once per routing policy — the ``--router``
    axis of the report.

    The fleet is built once and reused across policies (compiles are a
    one-time deployment cost; every engine the scheduler can form is
    warmed up front), so later policies also run on books the earlier
    passes populated — exactly the telemetry the p99 policy scores on.
    Requests alternate interactive/batch deadline classes; with
    ``max_outstanding`` 0 admission is unbounded (no sheds), a positive
    bound exercises the batch-sheds-first policy.  After each pass the
    router's online refit runs once against every replica's live book
    (each replica carries a single-device planner, so the fit swaps in
    without changing what ran)."""
    from repro.data.images import RequestStream
    from repro.launch.batching import LatencyRecorder, QueueFull, round_batch
    from repro.launch.mesh import make_host_mesh
    from repro.launch.router import POLICIES, Router, ServiceReplica
    from repro.launch.serve import STDService
    from repro.runtime.fault_tolerance import Watchdog
    from repro.runtime.planner import Planner
    from repro.runtime.telemetry import CostBook

    if requests < 1:
        raise SystemExit("--requests must be >= 1")
    if replicas < 1:
        raise SystemExit("--replicas must be >= 1")
    policies = list(dict.fromkeys(policies))      # dedupe, keep order
    for p in policies:
        if p not in POLICIES:
            raise SystemExit(f"unknown --router policy {p!r}; "
                             f"expected one of {POLICIES}")
    images = RequestStream(
        requests, seed=seed,
        hw_range=((48, max(buckets)), (48, max(buckets))),
    ).images()

    fleet = []
    for i in range(replicas):
        # a (1, 1) mesh keeps routing on single_device while still
        # giving the replica a planner for the online refit to update
        planner = Planner(make_host_mesh((1, 1), ("data", "model")))
        svc = STDService(width=width, buckets=tuple(buckets),
                         max_batch=max_batch, max_wait_ms=max_wait_ms,
                         engine_cache_capacity=0, book=CostBook(warmup=0),
                         planner=planner, measured_routing=False)
        # warm every pow2 (bucket, batch) engine the scheduler can form
        # (same reasoning as bench_open_loop: steady state is the
        # measurement)
        shapes = {svc.preprocess(img)[0].shape[:2] for img in images}
        sizes = {round_batch(n, max_batch)
                 for n in range(1, max_batch + 1)}
        for b in sorted(sizes):
            for hw in shapes:
                svc.infer_labels(
                    np.zeros((b, hw[0], hw[1], 3), np.float32),
                    [(hw[0], hw[1])] * b,
                )
        # health exclusion stays out of the on-host smoke: real-clock
        # jitter (GC, compile cache misses) must not bench one replica
        # out of a homogeneous fleet mid-measurement
        fleet.append(ServiceReplica(
            f"r{i}", svc,
            watchdog=Watchdog(threshold=float("inf"), ema=0.5,
                              warmup_steps=0)))

    out = {}
    for policy in policies:
        router = Router(fleet, policy=policy,
                        max_outstanding=max_outstanding)
        rec = LatencyRecorder()
        shed = 0
        with router:
            t0 = time.perf_counter()
            futs = []
            for i, img in enumerate(images):
                cls = "interactive" if i % 2 == 0 else "batch"
                try:
                    fut = router.submit(img, deadline_class=cls)
                except QueueFull:
                    shed += 1
                    continue
                futs.append(rec.track(fut, t0=time.perf_counter()))
            for f in futs:
                f.result(timeout=600)
            rec.wait()
            wall = time.perf_counter() - t0
            refit = router.refit_now()
        out[policy] = {
            "tps": len(futs) / wall if wall > 0 else 0.0,
            "p50_ms": _pctl(rec.samples, 50),
            "p99_ms": _pctl(rec.samples, 99),
            "placed": dict(router.stats["placed"]),
            "shed": dict(router.stats["shed"]),
            "submitted": dict(router.stats["submitted"]),
            "refit_replicas": sorted(refit),
        }
        if verbose:
            r = out[policy]
            placed = "/".join(f"{k}={v}"
                              for k, v in sorted(r["placed"].items()))
            print(f"fleet_ab,router={policy},replicas={replicas},"
                  f"tps {r['tps']:.2f},"
                  f"p50 {r['p50_ms']:.1f} ms,p99 {r['p99_ms']:.1f} ms,"
                  f"placed {placed},"
                  f"shed int={r['shed']['interactive']}"
                  f"/batch={r['shed']['batch']},"
                  f"refit={len(r['refit_replicas'])} replicas")
    if verbose:
        # the aggregated scrape: one flat surface for the whole fleet,
        # per-replica series disjoint via the book labels
        snap = Router(fleet, policy=policies[-1]).metrics_snapshot()
        per_replica = sum(1 for k in snap if 'replica="' in k)
        print(f"fleet_metrics,series={len(snap)},"
              f"replica_labelled={per_replica}")
    return out


def bench_serving(requests: int = 32, width: float = 0.25,
                  buckets=(64, 128), max_batch: int = 8,
                  max_wait_ms: float = 8.0, seed: int = 0,
                  pre_workers: int = 4, verbose: bool = True,
                  plan_kind: str = "single", mesh_shape=None,
                  inflight: int = 1, cost_params=None):
    """Returns {mode: {tps, p50_ms, p99_ms}} plus parity/batching info."""
    from repro.data.images import RequestStream
    from repro.launch.serve import STDService

    if requests < 1:
        raise SystemExit("--requests must be >= 1")
    extra_kw, planner, buckets = _plan_setup(
        plan_kind, mesh_shape, tuple(buckets), max_batch,
        cost_params=cost_params,
    )
    images = RequestStream(
        requests, seed=seed,
        hw_range=((48, max(buckets)), (48, max(buckets))),
    ).images()
    svc = STDService(width=width, buckets=tuple(buckets),
                     max_batch=max_batch, max_wait_ms=max_wait_ms,
                     engine_cache_capacity=0,      # hold every warm shape
                     inflight=inflight,
                     # benchmarks need REPRODUCIBLE routing: the live
                     # measured overlay would flip plans mid-measurement
                     # (compile stalls inside the timed phase).  The
                     # measured->fitted loop here is --calibrate +
                     # --cost-params instead.
                     measured_routing=False, **extra_kw)
    _check_band_units(svc, planner, plan_kind, buckets)

    results = {}

    # -- sequential: warm (compiles every (bucket, 1) engine), then time
    seq_boxes = [svc(img) for img in images]
    lat = []
    t0 = time.perf_counter()
    for img in images:
        t = time.perf_counter()
        svc(img)
        lat.append(time.perf_counter() - t)
    results["sequential"] = {
        "tps": requests / (time.perf_counter() - t0),
        "p50_ms": _pctl(lat, 50), "p99_ms": _pctl(lat, 99),
    }

    # -- pipelined: engines already warm; per-request latency is not
    # observable inside the 3-stage pipeline, report the stage-bound
    # approximation (wall / n is the throughput-side view)
    svc.serve_pipelined(images)                       # warm thread path
    t0 = time.perf_counter()
    pipe_boxes = svc.serve_pipelined(images)
    wall = time.perf_counter() - t0
    results["pipelined"] = {
        "tps": requests / wall,
        "p50_ms": wall / requests * 1e3, "p99_ms": wall / requests * 1e3,
    }

    # -- micro-batched: warm pass compiles the (bucket, batch) variants
    # the scheduler actually forms, timed pass measures steady state
    svc.serve_batched(images, pre_workers=pre_workers)
    t0 = time.perf_counter()
    batch_boxes = svc.serve_batched(images, pre_workers=pre_workers)
    wall = time.perf_counter() - t0
    lat = svc.stats["batched_latency_s"]
    results["batched"] = {
        "tps": requests / wall,
        "p50_ms": _pctl(lat, 50), "p99_ms": _pctl(lat, 99),
    }

    key = lambda rs: [[b["box"] for b in r] for r in rs]
    parity = (key(seq_boxes) == key(pipe_boxes) == key(batch_boxes))
    sizes = [b["n"] for b in svc.stats["batching"]["batches"]]
    info = {
        "parity": parity,
        "mean_batch": float(np.mean(sizes)) if sizes else 0.0,
        "flush_full": svc.stats["batching"]["flush_full"],
        "flush_timeout": svc.stats["batching"]["flush_timeout"],
    }
    if verbose:
        for mode, r in results.items():
            print(f"serve_{mode},{r['tps']:.2f} TPS,"
                  f"p50 {r['p50_ms']:.1f} ms,p99 {r['p99_ms']:.1f} ms")
        print(f"serve_info,parity={parity},mean_batch={info['mean_batch']:.2f},"
              f"flush_full={info['flush_full']},"
              f"flush_timeout={info['flush_timeout']}")
    info["plans"] = report_plan_choices(svc, planner, max_batch, verbose)
    return {"modes": results, **info}


def bench_open_loop(requests: int = 32, rates=(8.0, 32.0),
                    width: float = 0.25, buckets=(64, 128),
                    max_batch: int = 8, max_wait_ms: float = 8.0,
                    seed: int = 0, max_pending: int = 0,
                    admission: str = "block", verbose: bool = True,
                    plan_kind: str = "single", mesh_shape=None,
                    inflight_values=(2,), cost_params=None):
    """Open-loop (Poisson arrival) serving: offered load vs achieved TPS
    and p50/p99 latency, per offered rate and per async pipeline depth
    (``inflight_values``; the synchronous depth 0 is always swept as
    the overlap-gain baseline).  Returns {rate: {inflight: {...}}}."""
    from repro.data.images import RequestStream
    from repro.launch.batching import LatencyRecorder, QueueFull
    from repro.launch.serve import STDService

    extra_kw, planner, buckets = _plan_setup(
        plan_kind, mesh_shape, tuple(buckets), max_batch,
        cost_params=cost_params,
    )
    images = RequestStream(
        requests, seed=seed,
        hw_range=((48, max(buckets)), (48, max(buckets))),
    ).images()
    svc = STDService(width=width, buckets=tuple(buckets),
                     max_batch=max_batch, max_wait_ms=max_wait_ms,
                     engine_cache_capacity=0,
                     measured_routing=False,       # see bench_serving
                     **extra_kw)
    _check_band_units(svc, planner, plan_kind, buckets)
    # warm every pow2 (bucket, batch) engine the open-loop phase can form
    # (at low offered rates batches trickle in as 1s and 2s, sizes the
    # closed-loop pass never compiles) — steady state is the measurement
    from repro.launch.batching import round_batch

    shapes = {svc.preprocess(img)[0].shape[:2] for img in images}
    sizes = {round_batch(n, max_batch) for n in range(1, max_batch + 1)}
    for b in sorted(sizes):
        for hw in shapes:
            svc.infer_labels(
                np.zeros((b, hw[0], hw[1], 3), np.float32),
                [(hw[0], hw[1])] * b,
            )
    # admission control applies to the measured open-loop phase only (the
    # warm pass must compile every shape, not shed)
    svc.max_pending = max_pending
    svc.admission = admission

    # depth 0 (fully serialized dispatch->completion) is the overlap
    # baseline every async depth is reported against
    depths = sorted({0, *(int(n) for n in inflight_values)})

    results = {}
    for rate in rates:
        per_depth = {}
        for n in depths:
            rng = np.random.default_rng(seed)
            arrivals = np.cumsum(rng.exponential(1.0 / rate, size=requests))
            svc.inflight = n             # next start_batched picks it up
            svc.start_batched()
            rec = LatencyRecorder()
            futs, shed = [], 0
            t0 = time.perf_counter()
            try:
                for img, due in zip(images, arrivals):
                    now = time.perf_counter() - t0
                    if due > now:
                        time.sleep(due - now)
                    t = time.perf_counter()
                    try:
                        fut = svc.submit(img)
                    except QueueFull:
                        shed += 1
                        continue
                    futs.append(rec.track(fut, t0=t))
                for f in futs:
                    f.result(timeout=600)
                # event-driven: every sample has landed once this returns
                rec.wait()
            finally:
                svc.stop_batched()
            wall = time.perf_counter() - t0
            mb = svc.stats["batching"]
            per_depth[n] = {
                "offered_tps": rate,
                "inflight": n,
                "achieved_tps": len(futs) / wall,
                "completed": len(futs),
                "shed": shed,
                "p50_ms": _pctl(rec.samples, 50),
                "p99_ms": _pctl(rec.samples, 99),
                "inflight_peak": mb["inflight_peak"],
                "stage_occupancy": mb["stage_occupancy"],
            }
        base_tps = per_depth[0]["achieved_tps"]
        for n in depths:
            r = per_depth[n]
            r["overlap_gain"] = (r["achieved_tps"] / base_tps
                                 if base_tps > 0 else 0.0)
            if verbose:
                occ = r["stage_occupancy"]
                print(f"serve_open_loop,offered {rate:.1f} rps,"
                      f"inflight {n},"
                      f"achieved {r['achieved_tps']:.2f} TPS,"
                      f"p50 {r['p50_ms']:.1f} ms,"
                      f"p99 {r['p99_ms']:.1f} ms,"
                      f"shed {r['shed']},"
                      f"gain x{r['overlap_gain']:.2f},"
                      f"occ d{occ.get('dispatch', 0.0):.2f}"
                      f"/c{occ.get('complete', 0.0):.2f}")
        results[rate] = per_depth
    results["plans"] = report_plan_choices(svc, planner, max_batch, verbose)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--buckets", type=int, nargs="+", default=[64, 128])
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pre-workers", type=int, default=4)
    ap.add_argument("--open-loop", action="store_true",
                    help="also run Poisson-arrival open-loop sweeps")
    ap.add_argument("--rates", type=float, nargs="+", default=[8.0, 32.0],
                    help="offered open-loop rates, requests/s")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="admission-control queue bound (0 = unbounded)")
    ap.add_argument("--inflight", type=int, nargs="+", default=[2],
                    help="async pipeline depths to sweep in open-loop "
                         "mode (0 = fully synchronous dispatch, always "
                         "included as the overlap-gain baseline); the "
                         "closed-loop pass runs at max(inflight)")
    ap.add_argument("--admission", default="block",
                    choices=["block", "reject"])
    ap.add_argument("--plan", default="single",
                    choices=["single", "data", "rowband", "grid", "auto"],
                    help="ExecutionPlan: fixed single/data/rowband/grid, "
                         "or auto (cost-model routing per bucket)")
    ap.add_argument("--mesh-shape", type=int, nargs=2, default=None,
                    metavar=("DATA", "MODEL"),
                    help="host mesh (data, model) axis sizes; default "
                         "derives from the visible device count")
    ap.add_argument("--calibrate", metavar="OUT_JSON", default=None,
                    help="run the calibration sweep ONLY: time every "
                         "eligible (bucket, plan, batch) combo, "
                         "least-squares fit the CostParams constants, "
                         "save them to OUT_JSON, and exit")
    ap.add_argument("--calib-steps", type=int, default=3,
                    help="timed steps per (bucket, plan, batch) combo "
                         "in --calibrate mode (one extra warmup call "
                         "absorbs the compile)")
    ap.add_argument("--cost-params", metavar="IN_JSON", default=None,
                    help="load fitted CostParams from a --calibrate "
                         "file; the planner (--plan auto and the "
                         "serve_plan report) routes on them instead of "
                         "the napkin defaults")
    ap.add_argument("--precision", default="f32",
                    choices=["f32", "bfp"],
                    help="'bfp' runs the precision A/B sweep ONLY: "
                         "f32-vs-bfp blocked step walls per (bucket, "
                         "batch) from the CostBook, gated by the "
                         "accuracy-parity check on every bucket")
    ap.add_argument("--postprocess", default="host",
                    choices=["host", "device"],
                    help="'device' runs the postprocess A/B sweep ONLY: "
                         "host vs device serving tail on one stream, "
                         "gated on exact box parity per bucket and on a "
                         "measured postprocess-wall reduction")
    ap.add_argument("--boxes-capacity", type=int, default=256,
                    help="device-postprocess compact-rows capacity "
                         "(components past it fall back to the host "
                         "path per image)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="run the fleet A/B sweep ONLY: N replicated "
                         "services behind launch/router.Router, one "
                         "seeded stream per --router policy; "
                         "--max-pending bounds router admission "
                         "(0 = unbounded)")
    ap.add_argument("--router", nargs="+",
                    default=["round_robin", "p99"],
                    choices=["round_robin", "p99", "least_loaded"],
                    help="routing policies the fleet A/B sweeps (the "
                         "--router axis of the report)")
    ap.add_argument("--model", nargs="+", default=None,
                    choices=["pixellink", "east", "db"],
                    help="run the model-zoo sweep ONLY: for each named "
                         "detection head, gate its serving decode "
                         "against the NumPy reference decode on one "
                         "seeded stream (exact box parity per bucket), "
                         "then smoke-serve the stream through its "
                         "compiled engines")
    ap.add_argument("--memplan", action="store_true",
                    help="memory-planner A/B ONLY: memplan-on vs "
                         "memplan-off services — exact box parity over "
                         "the model x plan x precision matrix, measured "
                         "temp-bytes reduction >= 20%% on the largest "
                         "bucket, and a smaller bucket admitted above "
                         "--max-batch (restrict the matrix with --model/"
                         "--memplan-plans/--memplan-precisions)")
    ap.add_argument("--memplan-plans", nargs="+",
                    default=["single", "data", "rowband", "grid"],
                    choices=["single", "data", "rowband", "grid"],
                    help="plan kinds the --memplan parity matrix covers "
                         "(must include 'single': the memory gate "
                         "measures the single/f32 pair)")
    ap.add_argument("--memplan-precisions", nargs="+",
                    default=["f32", "bfp"], choices=["f32", "bfp"],
                    help="precisions the --memplan parity matrix covers "
                         "(must include 'f32')")
    args = ap.parse_args(argv)
    if args.memplan:
        return run_memplan_ab(width=args.width,
                              buckets=tuple(args.buckets),
                              max_batch=args.max_batch,
                              requests=args.requests,
                              max_wait_ms=args.max_wait_ms,
                              seed=args.seed,
                              models=tuple(args.model
                                           or ("pixellink", "east", "db")),
                              plans=tuple(args.memplan_plans),
                              precisions=tuple(args.memplan_precisions),
                              pre_workers=args.pre_workers)
    if args.replicas:
        return run_fleet_ab(args.router,
                            replicas=args.replicas,
                            requests=args.requests,
                            width=args.width,
                            buckets=tuple(args.buckets),
                            max_batch=args.max_batch,
                            max_wait_ms=args.max_wait_ms,
                            seed=args.seed,
                            max_outstanding=args.max_pending)
    if args.model:
        return run_model_zoo(args.model,
                             requests=args.requests,
                             width=args.width,
                             buckets=tuple(args.buckets),
                             max_batch=args.max_batch,
                             max_wait_ms=args.max_wait_ms,
                             seed=args.seed,
                             pre_workers=args.pre_workers)
    if args.postprocess == "device":
        return run_postprocess_ab(requests=args.requests,
                                  width=args.width,
                                  buckets=tuple(args.buckets),
                                  max_batch=args.max_batch,
                                  max_wait_ms=args.max_wait_ms,
                                  seed=args.seed,
                                  boxes_capacity=args.boxes_capacity,
                                  pre_workers=args.pre_workers)
    if args.precision == "bfp":
        return run_precision_ab(width=args.width,
                                buckets=tuple(args.buckets),
                                max_batch=args.max_batch,
                                steps=args.calib_steps,
                                seed=args.seed)
    if args.calibrate:
        run_calibration(args.calibrate, width=args.width,
                        buckets=tuple(args.buckets),
                        max_batch=args.max_batch,
                        mesh_shape=args.mesh_shape,
                        steps=args.calib_steps)
        return None
    out = bench_serving(args.requests, args.width, tuple(args.buckets),
                        args.max_batch, args.max_wait_ms, args.seed,
                        args.pre_workers, plan_kind=args.plan,
                        mesh_shape=args.mesh_shape,
                        inflight=max(args.inflight),
                        cost_params=args.cost_params)
    if args.plan == "auto":
        # routing is batch-dependent, so sequential (batch 1) and
        # micro-batched modes may legitimately run DIFFERENT plans for
        # one bucket; banded vs single engines can differ by ~1e-6
        # Winograd tile-regrouping noise, enough to flip a box at an
        # unlucky 0.5-threshold score — report instead of failing
        if not out["parity"]:
            print("serve_warn,auto-mode modes routed to different plans; "
                  "box parity not guaranteed bit-exact")
    else:
        assert out["parity"], \
            "batched/pipelined boxes diverged from sequential"
    if args.open_loop:
        out["open_loop"] = bench_open_loop(
            args.requests, tuple(args.rates), args.width,
            tuple(args.buckets), args.max_batch, args.max_wait_ms,
            args.seed, args.max_pending, args.admission,
            plan_kind=args.plan, mesh_shape=args.mesh_shape,
            inflight_values=tuple(args.inflight),
            cost_params=args.cost_params,
        )
    return out


if __name__ == "__main__":
    main()
