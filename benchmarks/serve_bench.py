"""Closed-loop STD serving throughput benchmark (the Fig. 9a comparison):
sequential vs C4-pipelined vs dynamic micro-batched serving on a seeded
mixed-resolution request stream.  Reports TPS and p50/p99 per-request
latency per mode.

Each mode is warmed on the same stream first (compiles are a one-time
deployment cost in the paper's serving story; the steady-state pass is
the measurement), then timed.

Run:  PYTHONPATH=src python -m benchmarks.serve_bench --requests 32
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _pctl(xs, q):
    return float(np.percentile(np.asarray(xs), q) * 1e3) if len(xs) else 0.0


def bench_serving(requests: int = 32, width: float = 0.25,
                  buckets=(64, 128), max_batch: int = 8,
                  max_wait_ms: float = 8.0, seed: int = 0,
                  pre_workers: int = 4, verbose: bool = True):
    """Returns {mode: {tps, p50_ms, p99_ms}} plus parity/batching info."""
    from repro.data.images import RequestStream
    from repro.launch.serve import STDService

    if requests < 1:
        raise SystemExit("--requests must be >= 1")
    images = RequestStream(
        requests, seed=seed,
        hw_range=((48, max(buckets)), (48, max(buckets))),
    ).images()
    svc = STDService(width=width, buckets=tuple(buckets),
                     max_batch=max_batch, max_wait_ms=max_wait_ms,
                     engine_cache_capacity=0)      # hold every warm shape

    results = {}

    # -- sequential: warm (compiles every (bucket, 1) engine), then time
    seq_boxes = [svc(img) for img in images]
    lat = []
    t0 = time.perf_counter()
    for img in images:
        t = time.perf_counter()
        svc(img)
        lat.append(time.perf_counter() - t)
    results["sequential"] = {
        "tps": requests / (time.perf_counter() - t0),
        "p50_ms": _pctl(lat, 50), "p99_ms": _pctl(lat, 99),
    }

    # -- pipelined: engines already warm; per-request latency is not
    # observable inside the 3-stage pipeline, report the stage-bound
    # approximation (wall / n is the throughput-side view)
    svc.serve_pipelined(images)                       # warm thread path
    t0 = time.perf_counter()
    pipe_boxes = svc.serve_pipelined(images)
    wall = time.perf_counter() - t0
    results["pipelined"] = {
        "tps": requests / wall,
        "p50_ms": wall / requests * 1e3, "p99_ms": wall / requests * 1e3,
    }

    # -- micro-batched: warm pass compiles the (bucket, batch) variants
    # the scheduler actually forms, timed pass measures steady state
    svc.serve_batched(images, pre_workers=pre_workers)
    t0 = time.perf_counter()
    batch_boxes = svc.serve_batched(images, pre_workers=pre_workers)
    wall = time.perf_counter() - t0
    lat = svc.stats["batched_latency_s"]
    results["batched"] = {
        "tps": requests / wall,
        "p50_ms": _pctl(lat, 50), "p99_ms": _pctl(lat, 99),
    }

    key = lambda rs: [[b["box"] for b in r] for r in rs]
    parity = (key(seq_boxes) == key(pipe_boxes) == key(batch_boxes))
    sizes = [b["n"] for b in svc.stats["batching"]["batches"]]
    info = {
        "parity": parity,
        "mean_batch": float(np.mean(sizes)) if sizes else 0.0,
        "flush_full": svc.stats["batching"]["flush_full"],
        "flush_timeout": svc.stats["batching"]["flush_timeout"],
    }
    if verbose:
        for mode, r in results.items():
            print(f"serve_{mode},{r['tps']:.2f} TPS,"
                  f"p50 {r['p50_ms']:.1f} ms,p99 {r['p99_ms']:.1f} ms")
        print(f"serve_info,parity={parity},mean_batch={info['mean_batch']:.2f},"
              f"flush_full={info['flush_full']},"
              f"flush_timeout={info['flush_timeout']}")
    return {"modes": results, **info}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--buckets", type=int, nargs="+", default=[64, 128])
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pre-workers", type=int, default=4)
    args = ap.parse_args(argv)
    out = bench_serving(args.requests, args.width, tuple(args.buckets),
                        args.max_batch, args.max_wait_ms, args.seed,
                        args.pre_workers)
    assert out["parity"], "batched/pipelined boxes diverged from sequential"
    return out


if __name__ == "__main__":
    main()
