"""Benchmark harness (deliverable d): one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  fig8   — per-image STD latency vs image size, ResNet-50 & VGG-16
  fig9   — serving TPS, sequential vs C4-pipelined (+ derived OpEx ratio)
  tableIV— kernel VMEM utilization from BlockSpec math (resource table)
  tableV — conv engine GOPS: Winograd vs direct, measured + TPU-derived
  tableVI— precision: FP32 reference vs FP16-storage BFP (wide/narrow
           accumulator), f-measure + numeric deltas
  microcode — versatility cost: config-RAM bytes per architecture

Run:  PYTHONPATH=src python -m benchmarks.run [fig8 fig9 ...]
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def _time_call(fn, *args, repeat=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6        # us


def bench_fig8_latency():
    """Paper Fig. 8: latency vs image size for both extractors (reduced
    width on CPU; the relative size scaling is the measurement)."""
    from repro.models.fcn.pixellink import PixelLinkModel, STDConfig

    rows = []
    for backbone in ("resnet50", "vgg16"):
        for size in (64, 128, 256):
            cfg = STDConfig(backbone=backbone, width=0.125,
                            image_size=(size, size), merge_ch=(16, 16, 8),
                            mode="optimized", storage_fp16=False)
            m = PixelLinkModel(cfg)
            params = m.init_params(jax.random.PRNGKey(0))
            x = jnp.zeros((1, size, size, 3))
            apply = jax.jit(lambda p, im: m.apply(p, im)["score"])
            us = _time_call(apply, params, x)
            name = f"fig8_latency_{backbone}_{size}x{size}"
            rows.append((name, us, f"{us/1e3:.1f}ms/img"))
            print(f"{name},{us:.0f},{us/1e3:.2f}ms")
    return rows


def bench_fig9_tps():
    """Paper Fig. 9a: TPS sequential vs pipelined + OpEx ratio analogue."""
    from repro.data.images import SyntheticSTDData
    from repro.launch.serve import STDService

    svc = STDService(width=0.125, buckets=(64, 96, 128))
    rng = np.random.default_rng(0)
    images = [
        SyntheticSTDData(
            (int(rng.integers(6, 14)) * 8, int(rng.integers(6, 14)) * 8),
            seed=i,
        ).sample(0, 1)["images"][0]
        for i in range(10)
    ]
    for img in images:                       # warm (compiles buckets)
        svc(img)
    t0 = time.perf_counter()
    for img in images:
        svc(img)
    seq_tps = len(images) / (time.perf_counter() - t0)
    svc.serve_pipelined(images)
    pipe_tps = svc.stats["pipelined_tps"]
    print(f"fig9_tps_sequential,{1e6/seq_tps:.0f},{seq_tps:.2f}tps")
    print(f"fig9_tps_pipelined,{1e6/pipe_tps:.0f},{pipe_tps:.2f}tps")
    # OpEx = TCO / throughput: at fixed TCO the pipelining speedup IS the
    # OpEx reduction (the paper's 46% combines this with the TCO ratio)
    opex_gain = 1 - seq_tps / max(pipe_tps, 1e-9)
    print(f"fig9_opex_reduction_from_pipelining,0,{opex_gain*100:.0f}%")
    return seq_tps, pipe_tps


def bench_tableIV_vmem():
    """Paper Table IV analogue: per-kernel VMEM budget from BlockSpecs
    (the resource-utilization table; v5e-class core ~ 128 MiB VMEM)."""
    VMEM = 128 * 2**20
    rows = [
        ("bfp_matmul_bm256_bn256_bk512",
         2 * (256 * 512 + 512 * 256 + 256 * 16 + 256 * 16)
         + 256 * 256 * 4),
        ("winograd_bp128_bn128_bk128",
         2 * (128 * 36 * 128 * 4 + 36 * 128 * 128 * 4)
         + 36 * 128 * 128 * 4 + 128 * 16 * 128 * 4),
        ("flash_attn_bq512_bk512_d128",
         2 * (512 * 128 * 4 * 3) + 512 * 128 * 4 + 2 * 512 * 4),
        ("ssd_chunk_Lc128_N128_P64",
         2 * (2 * 128 * 128 * 4 + 128 * 64 * 4 + 128 * 4)
         + 128 * 64 * 4 + 64 * 128 * 4),
    ]
    for name, b in rows:
        print(f"tableIV_vmem_{name},0,{b/2**20:.1f}MiB({100*b/VMEM:.0f}%)")
    return rows


def bench_tableV_gops():
    """Paper Table V: conv engine throughput, Winograd vs direct.

    Measured: pure-jnp Winograd vs lax direct conv wall time on CPU.
    Derived: the 4x multiply reduction and the TPU-side verdict (DESIGN.md
    §2: on the MXU the win is bounded by the transforms' bandwidth)."""
    from repro.core import winograd as wg
    from repro.kernels.winograd_conv.ref import direct_conv2d

    n, h, w, cin, cout = 1, 128, 128, 64, 64
    x = jax.random.normal(jax.random.PRNGKey(0), (n, h, w, cin))
    k = jax.random.normal(jax.random.PRNGKey(1), (3, 3, cin, cout))
    flops = 2 * n * h * w * 9 * cin * cout
    f_dir = jax.jit(direct_conv2d)
    f_win = jax.jit(wg.winograd_conv2d)
    us_d = _time_call(f_dir, x, k)
    us_w = _time_call(f_win, x, k)
    print(f"tableV_direct_conv,{us_d:.0f},{flops/us_d/1e3:.1f}GOPS")
    print(f"tableV_winograd_conv,{us_w:.0f},{flops/us_w/1e3:.1f}GOPS")
    c = wg.multiply_count(h, w, cin, cout)
    print(f"tableV_mac_reduction,0,{c['mac_reduction']:.2f}x")
    return us_d, us_w


def bench_tableVI_precision():
    """Paper Table VI: precision deltas under BFP numerics.  FP32 engine
    output is the 'GPU' reference; FP16-storage + BFP MAC is the 'FPGA'
    side; the narrow accumulator shows what §IV.C maintenance saves."""
    from repro.core import BFPConfig
    from repro.data.images import SyntheticSTDData
    from repro.models.fcn import postprocess as pp
    from repro.models.fcn.pixellink import PixelLinkModel, STDConfig

    base = dict(backbone="vgg16", width=0.25, image_size=(96, 96),
                merge_ch=(16, 16, 8))
    m_ref = PixelLinkModel(STDConfig(mode="reference", storage_fp16=False,
                                     **base))
    params = m_ref.init_params(jax.random.PRNGKey(0))
    data = SyntheticSTDData((96, 96), seed=3).sample(0, 4)
    x = jnp.asarray(data["images"])
    out_ref = m_ref.apply(params, x)

    def run_bfp(mantissa_bits, wide):
        cfg = STDConfig(
            mode="reference", storage_fp16=True,
            bfp=BFPConfig(mantissa_bits=mantissa_bits, wide_accum=wide),
            **base,
        )
        m = PixelLinkModel(cfg)
        return m.apply(m.normalize_weights(params), x)

    def boxes(out, i):
        lab = pp.cc_label(out["score"][i].astype(jnp.float32),
                          out["links"][i].astype(jnp.float32),
                          score_thr=0.55)
        return pp.boxes_from_labels(np.asarray(lab), min_area=2)

    for tag, mb, wide in (("bfp10_wide", 10, True),
                          ("bfp10_narrow", 10, False),
                          ("bfp7_wide", 7, True)):
        t0 = time.perf_counter()
        out = run_bfp(mb, wide)
        us = (time.perf_counter() - t0) * 1e6
        derr = float(jnp.mean(jnp.abs(
            out["score"].astype(jnp.float32) - out_ref["score"])))
        fms = []
        for i in range(x.shape[0]):
            ref_boxes = [b["box"] for b in boxes(out_ref, i)]
            got = boxes(out, i)
            fms.append(pp.f_measure(got, ref_boxes)["f_measure"]
                       if ref_boxes else 1.0)
        print(f"tableVI_{tag},{us:.0f},score_mae={derr:.4f}"
              f";f_measure_vs_fp32={np.mean(fms):.4f}")
    return True


def bench_microcode():
    """Versatility cost: one engine, every arch — config RAM per model."""
    from repro.configs import ARCH_IDS, get_smoke_config
    from repro.core.microcode import pack_program
    from repro.models.lm import LMModel

    for arch in ARCH_IDS:
        model = LMModel(get_smoke_config(arch))
        total = len(model.block.words)
        extra = ""
        if hasattr(model, "shared"):
            total += len(model.shared.words)
            extra = "+shared"
        if hasattr(model, "enc_block"):
            total += len(model.enc_block.words)
            extra = "+enc"
        print(f"microcode_{arch},0,{total}words{extra}/{total*32}B")
    return True


BENCHES = {
    "fig8": bench_fig8_latency,
    "fig9": bench_fig9_tps,
    "tableIV": bench_tableIV_vmem,
    "tableV": bench_tableV_gops,
    "tableVI": bench_tableVI_precision,
    "microcode": bench_microcode,
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()


if __name__ == "__main__":
    main()
