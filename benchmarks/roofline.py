"""Roofline analysis (deliverable g): three terms per (arch x shape) cell
from the compiled dry-run artifacts.

    compute    = HLO_FLOPs/dev / peak_FLOPs          (197 bf16 TFLOP/s)
    memory     = HLO_bytes/dev / HBM_bw              (819 GB/s)
    collective = collective_bytes/dev / ICI link bw  (50 GB/s/link)

HLO numbers come from the UNROLLED analysis compile when available
(reports/dryrun/*__unrolled.json) because XLA cost_analysis counts
while-loop bodies once (measured: a length-8 scan of matmuls reports 1x);
the looped compile's memory_analysis is used for the fits-in-HBM check.

MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill/decode), N = active params
for MoE.  The ratio MODEL_FLOPS / (HLO_FLOPs x chips) exposes remat
recompute (ratio < 1 in train is expected ~0.75 with full remat: 8·N·D
compiled vs 6·N·D useful) and replicated compute (qwen's 40-head
attention on a 16-way TP axis).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--write-md]
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.mesh import (  # noqa: E402
    HBM_BW, HBM_PER_CHIP, ICI_BW_PER_LINK, PEAK_FLOPS_BF16,
)

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_act = cfg.active_param_count()
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind in ("train", "prefill") else 1
    )
    per_tok = 6 * n_act if shape.kind == "train" else 2 * n_act
    return float(per_tok) * tokens


def analytic_floor_bytes(arch: str, shape_name: str, n_devices: int) -> float:
    """Analytic LOWER bound on per-device HBM bytes/step: parameter
    shards + remat-saved activations + KV/state caches + logits.  XLA's
    'bytes accessed' counts every op's operands pre-fusion (an upper
    bound), so the true memory term lies between the two — both are
    reported."""
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    P = cfg.param_count() * 2                   # bf16 storage
    Pa = cfg.active_param_count() * 2
    data_par = max(n_devices // 16, 1)          # data axes product
    b_local = max(shape.global_batch // data_par, 1)
    act = cfg.n_layers * b_local * min(shape.seq_len, 2**20) * cfg.d_model * 2
    logits = b_local * shape.seq_len * max(cfg.vocab // 16, 1) * 4
    if shape.kind == "train":
        # params: fwd+bwd reads; opt: p r/w + 2 moments r/w (f32-ish)
        param_traffic = P / n_devices * (2 + 2) + P / n_devices * 8
        return param_traffic + act * 3 + logits * 3
    if shape.kind == "prefill":
        kv = (cfg.n_layers * shape.global_batch * shape.seq_len
              * cfg.n_kv_heads * cfg.hd * 2 * 2) / n_devices
        return Pa / n_devices + act * 1.5 + kv + logits / shape.seq_len
    # decode: read active param shard + KV cache read/write per token
    kv = (cfg.n_layers * shape.global_batch * shape.seq_len
          * cfg.n_kv_heads * cfg.hd * 2 * 2) / n_devices
    if cfg.family in ("ssm", "hybrid"):
        kv = (cfg.n_layers * shape.global_batch * cfg.ssm_heads
              * cfg.ssm_headdim * cfg.ssm_state * 4 * 2) / n_devices
    return Pa / n_devices + kv


def load_cells(report_dir: str = REPORT_DIR,
               mesh: str = "singlepod") -> List[Dict]:
    cells = {}
    for path in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("mesh") != mesh:
            continue
        key = (rec["arch"], rec["shape"])
        unrolled = path.endswith("__unrolled.json")
        slot = cells.setdefault(key, {})
        slot["unrolled" if unrolled else "looped"] = rec
    out = []
    for (arch, shape), slot in sorted(cells.items()):
        looped = slot.get("looped")
        unrolled = slot.get("unrolled")
        base = unrolled if (unrolled and unrolled.get("status") == "ok") \
            else looped
        if base is None:
            continue
        rec = dict(base)
        rec["analysis_source"] = (
            "unrolled" if base is unrolled else "looped(while-undercount)"
        )
        if looped and looped.get("status") == "ok":
            rec["memory_looped"] = looped["memory"]
        out.append(rec)
    return out


def analyze(rec: Dict) -> Optional[Dict]:
    if rec.get("status") == "skipped":
        return {
            "arch": rec["arch"], "shape": rec["shape"], "status": "skipped",
            "reason": rec.get("reason", ""),
        }
    if rec.get("status") != "ok":
        return {
            "arch": rec["arch"], "shape": rec["shape"], "status": "error",
            "reason": rec.get("error", ""),
        }
    flops = rec["flops_per_device"]
    nbytes = rec["bytes_accessed_per_device"]
    coll = rec["collective_bytes_per_device"]["total"]
    t_c = flops / PEAK_FLOPS_BF16
    t_m_hi = nbytes / HBM_BW                      # pre-fusion upper bound
    t_m_lo = analytic_floor_bytes(
        rec["arch"], rec["shape"], rec["n_devices"]) / HBM_BW
    t_m = min(t_m_hi, max(t_m_lo, t_m_hi * 0.15))  # fused estimate: XLA
    # typically fuses ~5-7x of naive op traffic; clamp into [floor, hi]
    t_m = max(t_m, t_m_lo)
    t_x = coll / ICI_BW_PER_LINK
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops * rec["n_devices"]
    mem = rec.get("memory_looped") or rec["memory"]
    per_dev_bytes = (mem.get("argument_size_bytes") or 0) + (
        mem.get("temp_size_bytes") or 0)
    bound = max(t_c, t_m, t_x)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
        "compute_s": t_c, "memory_s": t_m, "memory_hi_s": t_m_hi,
        "memory_lo_s": t_m_lo, "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else float("nan"),
        "roofline_fraction": (mf / rec["n_devices"] / PEAK_FLOPS_BF16)
        / bound if bound else float("nan"),
        "mem_per_dev_gib": per_dev_bytes / 2**30,
        "fits_hbm": per_dev_bytes <= HBM_PER_CHIP,
        "analysis_source": rec.get("analysis_source", "?"),
    }


_FIX_HINTS = {
    "compute": "raise MXU utilization: larger per-device tiles / fewer "
               "replicated-head FLOPs / drop remat recompute where memory "
               "allows",
    "memory": "cut HBM traffic: BFP8/bf16 streams, fuse elementwise chains, "
              "larger fusion blocks, avoid re-reading the KV cache",
    "collective": "cut ICI bytes: BFP8-compressed all-reduce, shard "
                  "activations so all-gathers shrink, overlap collectives "
                  "with compute (latency-hiding scheduler), PP over pods",
}


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPS | useful ratio | roofline frac | mem/dev GiB | "
           "fits 16G | source |\n|---|---|---|---|---|---|---|---|---|---|"
           "---|---|")
    lines = [hdr]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped: "
                f"{r['reason'][:60]}... | — | — | — | — | — | — |")
            continue
        if r["status"] == "error":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR "
                         f"{r['reason'][:60]} |" + " — |" * 10)
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{r['mem_per_dev_gib']:.1f} | "
            f"{'Y' if r['fits_hbm'] else 'N'} | {r['analysis_source']} |"
        )
    return "\n".join(lines)


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="singlepod")
    ap.add_argument("--write-md", action="store_true")
    ap.add_argument("--report-dir", default=REPORT_DIR)
    args = ap.parse_args(argv)
    rows = [analyze(r) for r in load_cells(args.report_dir, args.mesh)]
    rows = [r for r in rows if r]
    md = to_markdown(rows)
    print(md)
    ok = [r for r in rows if r["status"] == "ok"]
    for kind in ("compute", "memory", "collective"):
        doms = [r for r in ok if r["dominant"] == kind]
        print(f"\n{kind}-bound cells: {len(doms)}  -> fix: "
              f"{_FIX_HINTS[kind]}")
    if args.write_md:
        out = os.path.join(os.path.dirname(__file__), "..", "reports",
                           "roofline.md")
        with open(out, "w") as f:
            f.write(md + "\n")
        print(f"\nwrote {out}")
    return rows


if __name__ == "__main__":
    main()
