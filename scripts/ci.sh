#!/usr/bin/env bash
# Tiered CI: fast tier first for quick signal (property tests capped to
# a few seeded examples — the cap applies to the _hypothesis_compat shim;
# with real hypothesis installed, per-test @settings win and the smoke
# tier is full-size — slow-marked multi-process tests excluded), then the
# full fast tier, then the slow tier.  Extra args pass to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== smoke tier (capped property examples) =="
HYPOTHESIS_COMPAT_MAX_EXAMPLES=5 python -m pytest -q -x -m "not slow" "$@"

echo "== fast tier (full example counts) =="
python -m pytest -q -m "not slow" "$@"

echo "== tier-2: GridPlan parity + cost-model planner on an 8-device (2x4) host mesh =="
# Grid-parity property suite and planner routing: the gridplan tests
# spawn 8-device (2x4 data x model) subprocesses themselves; the fast
# planner suite rides along so a planner regression fails this stage
# even when invoked with path args that skip the fast tiers.
python -m pytest -q -m "slow" tests/test_gridplan.py
python -m pytest -q tests/test_planner.py

echo "== tier-2: async pipelined dispatch parity + in-flight stress on the 8-device mesh =="
# Async-vs-sync box parity (GridPlan, 0.5-threshold guard) and the
# bounded in-flight stress run; the subprocess sets the 8-device
# (2x4 data x model) host platform itself.  The fast-tier async tests
# (dispatch/completion semantics, fake-clock harness, stats hammer)
# already ran in the tiers above.
python -m pytest -q -m "slow" tests/test_async_serving.py

echo "== tier-2: calibrate smoke — fit CostParams on host CPU, reload, route =="
# A tiny serve_bench --calibrate sweep must produce a params file that
# parses, reloads into CostParams, and routes the canonical bucket grid
# identically to the in-memory fit (the --cost-params seam).
calib_tmp="$(mktemp -d)"
trap 'rm -rf "$calib_tmp"' EXIT
python -m benchmarks.serve_bench --calibrate "$calib_tmp/cost_params.json" \
  --width 0.125 --buckets 64 --max-batch 2 --calib-steps 2
python - "$calib_tmp/cost_params.json" <<'PYEOF'
import json, sys
from repro.runtime.planner import CostParams, choose_kind, PlanFeatures
from repro.runtime.telemetry import cost_params_from_dict, load_cost_params

path = sys.argv[1]
doc = json.load(open(path))
assert doc["measurements"], "calibration saved no measurement rows"
p1 = cost_params_from_dict(doc["cost_params"])
p2 = load_cost_params(path)
assert p1 == p2 and isinstance(p2, CostParams)
feats = lambda h, w: PlanFeatures(flops=2e5 * h * w / 64.0,
                                  halo_bytes=3e4 * w / 64.0,
                                  deepest_stride=32)
grid = [((h, w), b, (dn, mn))
        for (h, w) in ((64, 64), (128, 128), (512, 64), (2048, 64))
        for b in (1, 8) for (dn, mn) in ((1, 1), (4, 1), (1, 4), (2, 4))]
route = lambda p: [choose_kind(feats(*hw), hw, b, data_n=dn, model_n=mn,
                               params=p) for hw, b, (dn, mn) in grid]
assert route(p1) == route(p2), "reloaded params routed differently"
print(f"calibrate smoke OK: {len(doc['measurements'])} rows, "
      f"{len(grid)} routes identical after reload")
PYEOF

echo "== tier-2: precision modes — bfp-vs-f32 box parity + engine-state regressions =="
# The bfp-vs-f32 accuracy-parity smoke (0.5-threshold guard on the
# bucket grid), the per-precision engine LRU keying, the concurrent
# transposed-tracing regression, and the in-call BFP weight
# quantization regression all live in test_precision.py; the kernel
# interpret-default regressions ride along.  These also run in the fast
# tiers — this stage keeps them failing loudly when CI is invoked with
# path args that skip the fast tiers.
python -m pytest -q tests/test_precision.py

echo "== tier-2: device postprocess — parity suite + serve_bench A/B smoke =="
# The postprocess parity suite (log-hop + Pallas CCL vs the union-find
# oracle, device-vs-host box extraction, serpentine worst case, service
# wiring) plus a tiny serve_bench --postprocess device run proving the
# exact-box-parity gate passes and the device tail measurably reduces
# the blocked stage="postprocess" wall.  The suite also runs in the
# fast tiers; this stage keeps it failing loudly under path args.
python -m pytest -q tests/test_postprocess_device.py
python -m benchmarks.serve_bench --postprocess device \
  --width 0.125 --buckets 64 --max-batch 2 --requests 8

echo "== tier-2: model zoo — EAST/DB parity suite + serve_bench --model smoke =="
# The three detection heads through the one assembler->microcode seam:
# golden disassembly byte-stability, cross-model engine-LRU keying,
# per-model service routing, and each head's serving decode vs its
# NumPy reference oracle — plus a tiny serve_bench --model sweep
# proving the per-model box-parity gate passes end to end.  The suite
# also runs in the fast tiers; this stage keeps it failing loudly when
# CI is invoked with path args that skip them.
python -m pytest -q tests/test_model_zoo.py
python scripts/regen_golden_models.py --check
python -m benchmarks.serve_bench --model pixellink east db \
  --width 0.125 --buckets 64 --max-batch 2 --requests 6

echo "== tier-2: fleet router — deterministic multi-replica sim + serve_bench --replicas smoke =="
# The pod-scale serving suite: FakeClock fleet sim pinning p99-vs-round-
# robin tail separation, batch-sheds-before-interactive admission, the
# online refit flipping a routing decision without restart, and replica
# health exclusion/recovery — plus a tiny serve_bench --replicas A/B
# proving two real replicated services route, refit, and aggregate one
# labelled scrape end to end.  The suite also runs in the fast tiers;
# this stage keeps it failing loudly under path args.
python -m pytest -q tests/test_router.py
python -m benchmarks.serve_bench --replicas 2 \
  --router round_robin p99 \
  --width 0.125 --buckets 64 --max-batch 2 --requests 8

echo "== tier-2: memplan — static memory planner suite + serve_bench --memplan smoke =="
# The static microcode optimizer / data-pool memory planner: liveness,
# dead-word/dead-store elimination, arena slot accounting, the
# byte-weighted engine LRU, per-bucket batch caps, memplan golden
# snapshots (--check above already gates them), and a tiny
# serve_bench --memplan A/B — the run itself FAILS unless the planned
# budget caps the largest bucket below --max-batch, a smaller bucket
# is admitted above it, measured temp bytes drop >= 20% on the largest
# bucket, and memplan-on/off boxes match exactly.
python -m pytest -q tests/test_memplan.py
python -m benchmarks.serve_bench --memplan \
  --width 0.125 --buckets 64 128 --max-batch 4 --requests 6 \
  --model pixellink --memplan-plans single --memplan-precisions f32

echo "== tier-2: slow distributed/serving tests on a multi-device host mesh =="
# The pytest process itself sees 8 host CPU devices, activating any
# in-process multi-device tests; subprocess-based tests override
# XLA_FLAGS themselves before importing jax, so they are unaffected.
# exit 5 = nothing collected (e.g. a path argument with no slow tests)
# (test_gridplan.py / test_async_serving.py already ran in their stages)
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
python -m pytest -q -m "slow" --ignore=tests/test_gridplan.py \
  --ignore=tests/test_async_serving.py "$@" \
  || { rc=$?; [ "$rc" -eq 5 ] || exit "$rc"; }
