#!/usr/bin/env bash
# Tiered CI: fast tier first for quick signal (property tests capped to
# a few seeded examples — the cap applies to the _hypothesis_compat shim;
# with real hypothesis installed, per-test @settings win and the smoke
# tier is full-size — slow-marked multi-process tests excluded), then the
# full fast tier, then the slow tier.  Extra args pass to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== smoke tier (capped property examples) =="
HYPOTHESIS_COMPAT_MAX_EXAMPLES=5 python -m pytest -q -x -m "not slow" "$@"

echo "== fast tier (full example counts) =="
python -m pytest -q -m "not slow" "$@"

echo "== tier-2: GridPlan parity + cost-model planner on an 8-device (2x4) host mesh =="
# Grid-parity property suite and planner routing: the gridplan tests
# spawn 8-device (2x4 data x model) subprocesses themselves; the fast
# planner suite rides along so a planner regression fails this stage
# even when invoked with path args that skip the fast tiers.
python -m pytest -q -m "slow" tests/test_gridplan.py
python -m pytest -q tests/test_planner.py

echo "== tier-2: async pipelined dispatch parity + in-flight stress on the 8-device mesh =="
# Async-vs-sync box parity (GridPlan, 0.5-threshold guard) and the
# bounded in-flight stress run; the subprocess sets the 8-device
# (2x4 data x model) host platform itself.  The fast-tier async tests
# (dispatch/completion semantics, fake-clock harness, stats hammer)
# already ran in the tiers above.
python -m pytest -q -m "slow" tests/test_async_serving.py

echo "== tier-2: slow distributed/serving tests on a multi-device host mesh =="
# The pytest process itself sees 8 host CPU devices, activating any
# in-process multi-device tests; subprocess-based tests override
# XLA_FLAGS themselves before importing jax, so they are unaffected.
# exit 5 = nothing collected (e.g. a path argument with no slow tests)
# (test_gridplan.py / test_async_serving.py already ran in their stages)
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
python -m pytest -q -m "slow" --ignore=tests/test_gridplan.py \
  --ignore=tests/test_async_serving.py "$@" \
  || { rc=$?; [ "$rc" -eq 5 ] || exit "$rc"; }
