#!/usr/bin/env python
"""Regenerate tests/golden/microcode_<model>.txt.

Each golden file freezes one zoo model's full microcode disassembly
(the canonical tiny-vgg16 build from tests/test_model_zoo.py's
``golden_model``), so any assembler or head-spec edit that shifts an
address, channel count, or ext op fails the byte-stability test with a
diff naming the exact word.  When a shift is INTENTIONAL (a new layer,
an encoding change, an address-planner tweak), run this script — the
goldens update in the same commit that changes the lowering, never by
hand.

  PYTHONPATH=src python scripts/regen_golden_models.py [--check]

``--check`` recomputes without writing and exits 1 if any tracked
snapshot is stale (CI-friendly).
"""
from __future__ import annotations

import argparse
import importlib.util
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TEST_FILE = os.path.join(REPO, "tests", "test_model_zoo.py")


def _load_test_module():
    """tests/ is not a package; load the module straight off its file
    so we reuse its golden_model build + paths verbatim."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    spec = importlib.util.spec_from_file_location("_golden_zoo", TEST_FILE)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any tracked snapshot is stale, "
                         "write nothing")
    args = ap.parse_args(argv)

    mod = _load_test_module()
    os.makedirs(mod.GOLDEN_DIR, exist_ok=True)
    stale = []
    artifacts = []
    for name in sorted(mod.MODEL_ZOO):
        # raw microcode disassembly + the memplan-annotated optimized
        # program (schedule, arena slots, free-after sets, fusion facts)
        artifacts.append((
            mod.golden_path(name),
            mod.golden_model(name).program.disassemble() + "\n",
        ))
        artifacts.append((
            mod.golden_memplan_path(name),
            mod.golden_memplan_text(name),
        ))
    for path, text in artifacts:
        old = None
        if os.path.exists(path):
            with open(path) as f:
                old = f.read()
        if old == text:
            print(f"{os.path.relpath(path, REPO)}: up to date")
            continue
        stale.append(path)
        if not args.check:
            with open(path, "w") as f:
                f.write(text)
            print(f"{os.path.relpath(path, REPO)}: "
                  f"{'rewrote' if old is not None else 'created'} "
                  f"({len(text.splitlines())} lines)")
    if args.check and stale:
        print("stale golden microcode snapshots — run "
              "scripts/regen_golden_models.py:", file=sys.stderr)
        for p in stale:
            print(f"  {os.path.relpath(p, REPO)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
