#!/usr/bin/env python
"""Regenerate tests/test_planner.py::TestGoldenRouting.GOLDEN.

The golden table freezes Planner routing decisions over a canonical
grid of (bucket, batch, mesh-shape) inputs so any cost-model edit that
silently flips a route fails with the exact input named.  When a flip
is INTENTIONAL (a CostParams change, a new step-cost term, an
eligibility tweak), run this script: it recomputes every row with the
test module's own ``tall_features`` + ``TEST_PARAMS`` through
``runtime/planner.choose_kind`` and rewrites the block between the
``# GOLDEN-BEGIN`` / ``# GOLDEN-END`` markers in place — so the golden
updates in the same commit that changes the model, never by hand.

  PYTHONPATH=src python scripts/regen_golden_routing.py [--check]

``--check`` recomputes without writing and exits 1 if the tracked
table is stale (CI-friendly).
"""
from __future__ import annotations

import argparse
import importlib.util
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TEST_FILE = os.path.join(REPO, "tests", "test_planner.py")
BEGIN = "# GOLDEN-BEGIN"
END = "# GOLDEN-END"

# The canonical grid: per mesh shape, a comment line and the
# (hw, batch) rows frozen for it.  Editing THIS list (not the test
# file) is how the canonical coverage grows; test_golden_covers_every_
# kind keeps it honest about exercising all four plan kinds.
CANONICAL = [
    ((1, 1), "unit mesh: nothing to shard over", [
        ((64, 64), 1), ((512, 64), 8), ((2048, 64), 8),
    ]),
    ((4, 1), "data-only mesh: batch depth decides, height never bands", [
        ((64, 64), 1), ((64, 64), 4), ((64, 64), 8),
        ((256, 64), 1), ((256, 64), 4),
        ((512, 64), 1), ((512, 64), 8),
        ((1024, 128), 1), ((1024, 128), 4),
        ((2048, 64), 1), ((2048, 64), 8),
    ]),
    ((1, 4), "model-only mesh: the height crossover (64 -> 128 at "
             "W=64/128\n        # with TEST_PARAMS), band-height "
             "invariant already satisfied", [
        ((64, 64), 1), ((64, 64), 8),
        ((128, 128), 1), ((128, 128), 8),
        ((256, 64), 1), ((512, 64), 4), ((1024, 128), 8),
        ((2048, 64), 1),
    ]),
    ((2, 4), "2x4 grid mesh: small planes stay single/data-parallel "
             "by\n        # batch depth; tall planes band at batch 1 "
             "and take the\n        # composed grid once the batch is "
             "deep enough to split too", [
        ((64, 64), 1), ((64, 64), 4), ((64, 64), 8),
        ((128, 128), 1), ((128, 128), 4),
        ((256, 64), 1), ((256, 64), 8),
        ((512, 64), 1), ((512, 64), 4),
        ((1024, 128), 1), ((1024, 128), 8),
        ((2048, 64), 1), ((2048, 64), 8),
    ]),
]


def _load_test_module():
    """tests/ is not a package; load the module straight off its file
    so we reuse its tall_features + TEST_PARAMS verbatim."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    spec = importlib.util.spec_from_file_location("_golden_src", TEST_FILE)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def render_block(mod) -> str:
    from repro.runtime.planner import choose_kind

    lines = [f"    {BEGIN} (generated: scripts/regen_golden_routing.py)",
             "    GOLDEN = {"]
    for (dn, mn), comment, rows in CANONICAL:
        lines.append(f"        # {comment}")
        for hw, batch in rows:
            kind = choose_kind(mod.tall_features(*hw), hw, batch,
                               data_n=dn, model_n=mn,
                               params=mod.TEST_PARAMS)
            lines.append(
                f"        ({hw}, {batch}, ({dn}, {mn})): \"{kind}\",")
    lines += ["    }", f"    {END}"]
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if the tracked table is stale, "
                         "write nothing")
    args = ap.parse_args(argv)

    mod = _load_test_module()
    block = render_block(mod)
    with open(TEST_FILE) as f:
        src = f.read()
    pat = re.compile(
        rf"^    {re.escape(BEGIN)}.*?^    {re.escape(END)}$",
        re.DOTALL | re.MULTILINE,
    )
    if not pat.search(src):
        print(f"markers {BEGIN}/{END} not found in {TEST_FILE}",
              file=sys.stderr)
        return 2
    new = pat.sub(lambda _: block, src, count=1)
    if new == src:
        print("golden routing table up to date")
        return 0
    if args.check:
        print("golden routing table is STALE — run "
              "scripts/regen_golden_routing.py", file=sys.stderr)
        return 1
    with open(TEST_FILE, "w") as f:
        f.write(new)
    print(f"rewrote GOLDEN block in {TEST_FILE}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
