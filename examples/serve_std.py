"""Serve scene-text detection with batched random-size requests — the
paper's deployment scenario (Fig. 2), including the §IV.B random-size
path (bucketing + transpose trick) and C4 module-level pipelining.

Run:  PYTHONPATH=src python examples/serve_std.py --requests 12
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main()
