"""Serve scene-text detection with batched random-size requests — the
paper's deployment scenario (Fig. 2), including the §IV.B random-size
path (bucketing + transpose trick), C4 module-level pipelining, and the
dynamic micro-batching scheduler.

Run:  PYTHONPATH=src python examples/serve_std.py --requests 12
      PYTHONPATH=src python examples/serve_std.py --requests 12 --batched \
          --max-batch 8 --max-wait-ms 10

``--batched`` routes the same request stream through the async
micro-batching scheduler (resolution-bucketed batches, timeout flush)
and checks box-level parity against the pipelined path.  For the full
TPS/latency comparison see ``benchmarks/serve_bench.py``.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main()
