"""Quickstart: the paper's full loop in miniature, on CPU, in ~a minute.

1. Assemble a PixelLink STD model (VGG backbone) to MICROCODE — the
   paper's Fig. 4 auto-configuration flow — and disassemble it.
2. Normalize weights (BN fold + BFP, Fig. 4 right branch).
3. Run inference in reference and optimized (Winograd + fused-upsample)
   modes and check they agree.
4. Decode text boxes via connected components (no box regression).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BFPConfig
from repro.data.images import SyntheticSTDData
from repro.models.fcn import PixelLinkModel, postprocess
from repro.models.fcn.pixellink import STDConfig


def main():
    cfg = STDConfig(
        backbone="vgg16", width=0.25, image_size=(96, 96),
        merge_ch=(16, 16, 8), mode="optimized",
        bfp=BFPConfig(mantissa_bits=10), storage_fp16=False,
    )
    model = PixelLinkModel(cfg)
    print("=== microcode program (first 12 words) ===")
    print("\n".join(model.program.disassemble().splitlines()[:12]))
    print(f"... {len(model.program.words)} words total, "
          f"{model.microcode_bytes().nbytes} bytes of config RAM, "
          f"arena {model.program.arena_bytes/1024:.0f} KiB")

    params = model.init_params(jax.random.PRNGKey(0))
    params_n = model.normalize_weights(params)     # BN fold + BFP normalize

    data = SyntheticSTDData((96, 96), seed=42).sample(0, 1)
    x = jnp.asarray(data["images"])
    out = model.apply(params_n, x)
    print(f"score map {out['score'].shape}, links {out['links'].shape}")

    ref = PixelLinkModel(STDConfig(
        backbone="vgg16", width=0.25, image_size=(96, 96),
        merge_ch=(16, 16, 8), mode="reference", storage_fp16=False,
    ))
    out_ref = ref.apply(params, x)
    diff = float(jnp.max(jnp.abs(out["score"] - out_ref["score"])))
    print(f"optimized+BFP vs reference score max diff: {diff:.4f}")

    labels = postprocess.cc_label(out["score"][0], out["links"][0],
                                  score_thr=0.6)
    boxes = postprocess.boxes_from_labels(np.asarray(labels), min_area=2)
    print(f"{len(boxes)} text boxes detected (untrained net — structure "
          f"only): {[b['box'] for b in boxes][:4]}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
