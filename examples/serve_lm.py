"""LM serving example: prefill + batched greedy decode with the KV cache,
optionally with BFP-stored weights (paper C2 as the serving-bandwidth
feature — DESIGN.md §2).

Run:  PYTHONPATH=src python examples/serve_lm.py --tokens 24 --bfp-weights
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.lm import LMModel
from repro.models.lm import params as params_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--bfp-weights", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    model = LMModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    if args.bfp_weights:
        params_lib._BFP_MIN_SIZE = 1          # smoke weights are tiny
        params = params_lib.quantize_weights(params, model.param_meta())
        print("[serve_lm] weights quantized to int8 BFP mantissa streams")

    max_len = args.prompt_len + args.tokens
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )

    @jax.jit
    def prefill(params, toks):
        logits, cache = model.forward(params, toks, cache_out=True,
                                      max_len=max_len)
        return jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32), cache

    @jax.jit
    def step(params, tok, cache, pos):
        logits, cache = model.decode_step(params, tok[:, None], cache, pos)
        return jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32), cache

    t0 = time.perf_counter()
    tok, cache = prefill(params, prompts)
    jax.block_until_ready(tok)
    t_pre = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    pos = args.prompt_len
    for _ in range(args.tokens - 1):
        tok, cache = step(params, tok, cache, pos)
        pos += 1
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    gen = jnp.stack(out, 1)
    tps = args.batch * (args.tokens - 1) / max(t_dec, 1e-9)
    print(f"[serve_lm] {args.arch}: prefill({args.prompt_len}) "
          f"{t_pre*1e3:.0f}ms; decode {args.tokens-1} steps, "
          f"{tps:.0f} tok/s (incl 1st-step compile); sample: "
          f"{gen[0, :8].tolist()}")
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab)))
    print("serve_lm OK")


if __name__ == "__main__":
    main()
