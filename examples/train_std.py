"""End-to-end STD training: train a reduced PixelLink model on synthetic
scene-text images until the f-measure on held-out images is non-trivial.

This is the paper's task end-to-end: U-FCN -> score/link maps -> CC
decoding -> box f-measure, with BN folding at deploy time.

Run:  PYTHONPATH=src python examples/train_std.py --steps 120
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.images import SyntheticSTDData
from repro.models.fcn import PixelLinkModel, STDLoss, postprocess
from repro.models.fcn.pixellink import STDConfig
from repro.optim import adamw, cosine_with_warmup


def evaluate(model, params, data, n=4, score_thr=0.6):
    fms = []
    for i in range(n):
        s = data.sample(1000 + i, 1)
        out = model.apply(params, jnp.asarray(s["images"]))
        labels = postprocess.cc_label(out["score"][0], out["links"][0],
                                      score_thr=score_thr)
        boxes = postprocess.boxes_from_labels(np.asarray(labels), min_area=4)
        fm = postprocess.f_measure(boxes, s["boxes"][0], iou_thr=0.3)
        fms.append(fm["f_measure"])
    return float(np.mean(fms))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--size", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = STDConfig(backbone="vgg16", width=0.25,
                    image_size=(args.size, args.size), merge_ch=(16, 16, 8),
                    mode="reference", storage_fp16=False)
    model = PixelLinkModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    data = SyntheticSTDData((args.size, args.size), max_instances=3, seed=0)
    loss_fn = STDLoss(neg_ratio=3.0)
    opt_init, opt_update = adamw(
        cosine_with_warmup(3e-3, 10, args.steps), weight_decay=1e-4
    )
    opt = opt_init(params)

    @jax.jit
    def step(params, opt, images, score_gt, link_gt):
        def L(p):
            out = model.apply(p, images)
            d = loss_fn(out, score_gt, link_gt)
            return d["loss"], d

        (_, d), g = jax.value_and_grad(L, has_aux=True)(params)
        params, opt = opt_update(g, opt, params)
        return params, opt, d

    f0 = evaluate(model, params, data)
    print(f"[train_std] before training: f-measure {f0:.3f}")
    t0 = time.time()
    for i in range(args.steps):
        b = data.sample(i, args.batch)
        params, opt, d = step(
            params, opt, jnp.asarray(b["images"]), jnp.asarray(b["score"]),
            jnp.asarray(b["links"]),
        )
        if i % 20 == 0 or i == args.steps - 1:
            print(f"[train_std] step {i:4d} loss {float(d['loss']):.4f} "
                  f"(score {float(d['score_loss']):.4f} "
                  f"link {float(d['link_loss']):.4f})")
    f1 = evaluate(model, params, data)
    print(f"[train_std] after {args.steps} steps ({time.time()-t0:.0f}s): "
          f"f-measure {f0:.3f} -> {f1:.3f}")
    assert f1 > f0, "training must improve f-measure"
    print("train_std OK")


if __name__ == "__main__":
    main()
