"""End-to-end LM pretraining driver: a ~100M-param llama-family model for
a few hundred steps on the synthetic repeat-copy stream, with the full
production substrate: deterministic step-indexed data, AdamW (+optional
BFP8 first moments), grad clipping + accumulation, async checkpointing,
watchdog, and bit-exact mid-run crash-resume (exercised live).

Run:  PYTHONPATH=src python examples/train_lm_100m.py --steps 200
"""
import argparse
import os
import shutil
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data import TokenDataset
from repro.models.lm import LMModel, cross_entropy
from repro.optim import adamw, clip_by_global_norm, cosine_with_warmup
from repro.runtime.fault_tolerance import TrainRunner, Watchdog

# ~100M params: 12L x 768 (GPT-2-small class), llama-style blocks
CFG_100M = ArchConfig(
    name="llama-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=2048, vocab=4096,
    param_dtype="float32", compute_dtype="float32", remat=False,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    ap.add_argument("--crash-at", type=int, default=0,
                    help="inject a crash at this step to demo resume")
    args = ap.parse_args(argv)

    model = LMModel(CFG_100M)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        model.init_params(jax.random.PRNGKey(0))))
    print(f"[lm100m] {n_params/1e6:.1f}M params")

    ds = TokenDataset(CFG_100M.vocab, args.seq, args.batch, seed=0)
    opt_init, opt_update = adamw(
        cosine_with_warmup(args.lr, 20, args.steps),
        moment_dtype=args.moment_dtype, weight_decay=0.01,
    )

    @jax.jit
    def step_fn(state, batch):
        params, opt = state
        def L(p):
            logits = model.forward(p, batch["tokens"], mode="train")
            return cross_entropy(logits, batch["labels"])
        loss, g = jax.value_and_grad(L)(params)
        g, gnorm = clip_by_global_norm(g, 1.0)
        params, opt = opt_update(g, opt, params)
        return (params, opt), {"loss": loss, "grad_norm": gnorm}

    params = model.init_params(jax.random.PRNGKey(0))
    state = (params, opt_init(params))
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    cm = CheckpointManager(args.ckpt_dir, keep=2)
    runner = TrainRunner(
        step_fn, lambda s: jax.tree_util.tree_map(jnp.asarray, ds.batch(s)),
        cm, ckpt_every=50, watchdog=Watchdog(),
    )

    t0 = time.time()
    try:
        step, state, status = runner.run(
            state, 0, args.steps,
            fail_at=args.crash_at or None,
        )
    except RuntimeError as e:
        print(f"[lm100m] {e} — resuming from latest checkpoint")
        runner2 = TrainRunner(
            step_fn,
            lambda s: jax.tree_util.tree_map(jnp.asarray, ds.batch(s)),
            CheckpointManager(args.ckpt_dir, keep=2), ckpt_every=50,
        )
        start, state = runner2.resume_or_init(state)
        step, state, status = runner2.run(state, start, args.steps - start)
        runner.metrics_log += runner2.metrics_log

    logs = runner.metrics_log
    first = np.mean([m["loss"] for m in logs[:10]])
    last = np.mean([m["loss"] for m in logs[-10:]])
    for m in logs[:: max(len(logs) // 10, 1)]:
        print(f"[lm100m] step {int(m['step']):4d} loss {m['loss']:.4f}")
    print(f"[lm100m] loss {first:.3f} -> {last:.3f} in {time.time()-t0:.0f}s "
          f"({status})")
    assert last < first - 0.5, "model must learn the repeat-copy structure"
    print("train_lm_100m OK")


if __name__ == "__main__":
    main()
