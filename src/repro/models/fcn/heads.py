"""Detection-head zoo through the microcode seam (paper §II/Fig. 4).

The paper's headline claim is versatility: *different FCN models run on
one fixed datapath, reconfigured by microcodes*.  This module is that
claim's software seam — a :class:`DetectionHead` describes everything
model-specific about a scene-text detector:

  * the head's LayerSpecs appended after the shared backbone + U-merge
    (the general model description the Assembler resolves to microcode —
    Fig. 4 left branch),
  * how raw engine outputs become named probability/geometry maps,
  * the on-device serving tail (CC labeling for segmentation heads,
    valid-region masking for regression heads),
  * the per-image host decode and an independent NumPy reference decode
    the serve_bench parity gates compare against.

Three heads ship:

  * :class:`PixelLinkHead` — the paper's own model: 1 score + 8 link
    channels, connected components over positive links (PixelLink [6]).
  * :class:`EASTHead` — direct geometry regression (EAST, arXiv
    1704.03155): 1 score + 4 axis-aligned edge distances per pixel,
    decoded host-side with greedy NMS.  No CC tail at all — which is
    exactly why the engine payload had to stop being hardcoded to
    ``(labels, converged)``.
  * :class:`DBHead` — a DB/FAST-style minimalist shrink-mask head
    (FaSTExt, arXiv 1908.08994): a residual 3x3/1x1 merge through the
    binary ``add`` microcode op, one sigmoid mask channel, plain
    8-connected CC, and the DB unclip expansion at decode time.

:class:`DetectionModel` composes backbone + U-merge + head into ONE
assembled program; ``MODEL_ZOO``/:func:`build_head` are the registry the
engine factory, the serving layer, and serve_bench route by.  The N-th
model is a head subclass: specs + decode, ~50 lines.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Assembler, FCNEngine, LayerSpec
from repro.core.assembler import Program

from . import backbones as bb
from . import fusion

F32 = jnp.float32

#: the model axis every engine/param cache and telemetry series keys on
DEFAULT_MODEL = "pixellink"


def _valid_mask(score: jax.Array, valid_q: jax.Array) -> jax.Array:
    """(N, h, w) bool mask of the per-image valid region (quarter-res
    heights/widths in ``valid_q`` (N, 2)) — the same arithmetic the CC
    tail uses, shared so regression heads mask identically."""
    h, w = score.shape[1:]
    return (
        (jnp.arange(h)[None, :, None] < valid_q[:, 0, None, None])
        & (jnp.arange(w)[None, None, :] < valid_q[:, 1, None, None])
    )


def _iou(a: Tuple[int, int, int, int], b: Tuple[int, int, int, int]) -> float:
    """Inclusive-pixel IoU of two (x0, y0, x1, y1) boxes."""
    ix = min(a[2], b[2]) - max(a[0], b[0]) + 1
    iy = min(a[3], b[3]) - max(a[1], b[1]) + 1
    if ix <= 0 or iy <= 0:
        return 0.0
    inter = ix * iy
    aa = (a[2] - a[0] + 1) * (a[3] - a[1] + 1)
    bb = (b[2] - b[0] + 1) * (b[3] - b[1] + 1)
    return inter / float(aa + bb - inter)


def db_unclip_box(box: Dict, valid_hw_q: Tuple[int, int],
                  ratio: float) -> Dict:
    """DB's unclip expansion on one tight component box: the shrink-mask
    training target contracts text regions, so detection grows each box
    back by ``delta = area * ratio / perimeter`` (the polygon offset
    formula specialized to axis-aligned rectangles), clipped to the
    valid quarter-res plane."""
    x0, y0, x1, y1 = box["box"]
    w, h = x1 - x0 + 1, y1 - y0 + 1
    d = int(round(w * h * ratio / (2.0 * (w + h))))
    vh, vw = valid_hw_q
    out = dict(box)
    out["box"] = (max(0, x0 - d), max(0, y0 - d),
                  min(vw - 1, x1 + d), min(vh - 1, y1 + d))
    return out


class DetectionHead:
    """One detection model's head: specs, maps, tail, decode.

    Class attributes every subclass pins down:

    ``maps``
        ``((name, rank), ...)`` — the named maps :meth:`model_outputs`
        produces (rank includes the batch dim; 3 = per-pixel scalar,
        4 = per-pixel vector).  The row-banded engines shard exactly
        these maps out of the shard body.
    ``payload_ranks``
        Ranks of the device arrays :meth:`tail` returns before the
        trailing ``converged`` flag — the data-parallel engines build
        their out_specs from this.
    ``n_payload``
        ``len(payload_ranks)`` — how many payload arrays precede
        ``converged`` in an engine's return tuple.
    ``supports_device_postprocess``
        Whether the label-map → compact-boxes device tail applies
        (only single-label-map payloads can ride it).
    """

    name: str = "base"
    maps: Tuple[Tuple[str, int], ...] = ()
    payload_ranks: Tuple[int, ...] = (3,)
    n_payload: int = 1
    supports_device_postprocess: bool = False

    def __init__(self, score_thr: float = 0.5, link_thr: float = 0.5):
        self.score_thr = float(score_thr)
        self.link_thr = float(link_thr)

    # -- graph side -----------------------------------------------------------
    def head_specs(self, feat: str) -> Tuple[List[LayerSpec], List[str]]:
        """LayerSpecs appended after the fusion output ``feat`` plus the
        program output names (Fig. 4: the model-specific tail of the
        general model description)."""
        raise NotImplementedError

    def model_outputs(self, raw: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        """Raw engine outputs -> ``{"logits", <named maps...>}``."""
        raise NotImplementedError

    # -- device tail ----------------------------------------------------------
    def tail(self, factory, out: Dict[str, jax.Array],
             valid_q: jax.Array) -> Tuple[jax.Array, ...]:
        """Named maps -> ``(*payload, converged)`` on device.  Runs
        inside the compiled engine; ``factory`` supplies the shared CC
        machinery (EngineFactory.label_tail)."""
        raise NotImplementedError

    # -- host decode ----------------------------------------------------------
    def payload_plane(self, payload: Any) -> Optional[Tuple[int, int]]:
        """Quarter-res (h, w) plane of a per-image payload, or None when
        the payload carries no plane (device-compact rows)."""
        if isinstance(payload, tuple):
            return None
        return tuple(np.asarray(payload).shape[:2])

    def decode(self, payload: Any,
               valid_hw: Tuple[int, int]) -> Tuple[List[Dict], str]:
        """One image's materialized payload -> (boxes, kind) where kind
        labels the postprocess telemetry series ("host"/"device")."""
        raise NotImplementedError

    def reference_decode(self, out: Dict[str, np.ndarray],
                         valid_hw: Tuple[int, int]) -> List[Dict]:
        """Independent NumPy oracle: per-image maps (no batch dim) ->
        boxes.  serve_bench's per-model parity gate compares this
        against the serving tail + :meth:`decode` on the same maps."""
        raise NotImplementedError

    @staticmethod
    def _crop_q(arr: np.ndarray, valid_hw: Tuple[int, int]) -> np.ndarray:
        vh, vw = valid_hw[0] // 4, valid_hw[1] // 4
        return np.asarray(arr)[:vh, :vw]


class PixelLinkHead(DetectionHead):
    """The paper's model: 1 score + 8 neighbor-link channels, CC over
    positive links (PixelLink).  Supports the device-compact box tail."""

    name = "pixellink"
    maps = (("score", 3), ("links", 4))
    payload_ranks = (3,)
    n_payload = 1
    supports_device_postprocess = True

    def head_specs(self, feat):
        return fusion.pixellink_head(feat)

    def model_outputs(self, raw):
        prob = raw["head_prob"].astype(F32)
        return {
            "logits": raw["head_logits"].astype(F32),
            "score": prob[..., 0],
            "links": prob[..., 1:],
        }

    def tail(self, factory, out, valid_q):
        return factory.label_tail(out["score"], out["links"], valid_q)

    def decode(self, payload, valid_hw):
        from . import postprocess as pp

        if isinstance(payload, tuple):          # device-compact rows
            return pp.boxes_from_compact(payload[0]), "device"
        return pp.boxes_from_labels(self._crop_q(payload, valid_hw)), "host"

    def reference_decode(self, out, valid_hw):
        from . import postprocess as pp

        score = self._crop_q(out["score"], valid_hw)
        links = self._crop_q(out["links"], valid_hw)
        labels = pp.cc_label_numpy(score, links,
                                   self.score_thr, self.link_thr)
        return pp.boxes_from_labels_reference(labels)


class EASTHead(DetectionHead):
    """EAST-style direct regression: per-pixel score + 4 edge distances
    (top, right, bottom, left, in quarter-res pixels), decoded host-side
    with greedy NMS.  No CC tail — the engine payload is the masked
    score map plus the geometry map."""

    name = "east"
    maps = (("score", 3), ("geo", 4))
    payload_ranks = (3, 4)
    n_payload = 2
    supports_device_postprocess = False

    #: sigmoid output x scale = edge distance in quarter-res pixels (the
    #: regression range; EAST's text regions rarely exceed this radius
    #: at 1/4 scale for bucket-sized planes)
    GEO_SCALE = 8.0
    #: greedy-NMS suppression threshold
    NMS_IOU = 0.5

    def __init__(self, score_thr: float = 0.5, link_thr: float = 0.5, *,
                 geo_scale: float = GEO_SCALE, nms_iou: float = NMS_IOU):
        super().__init__(score_thr, link_thr)
        self.geo_scale = float(geo_scale)
        self.nms_iou = float(nms_iou)

    def head_specs(self, feat):
        specs = [
            LayerSpec("head_logits", "conv", [feat], out_ch=5, kernel=1),
            LayerSpec("head_prob", "sigmoid", ["head_logits"]),
        ]
        return specs, ["head_logits", "head_prob"]

    def model_outputs(self, raw):
        prob = raw["head_prob"].astype(F32)
        return {
            "logits": raw["head_logits"].astype(F32),
            "score": prob[..., 0],
            "geo": prob[..., 1:] * self.geo_scale,
        }

    def tail(self, factory, out, valid_q):
        score = out["score"]
        masked = jnp.where(_valid_mask(score, valid_q), score, 0.0)
        converged = jnp.ones((score.shape[0],), bool)
        return masked, out["geo"].astype(F32), converged

    def payload_plane(self, payload):
        return tuple(np.asarray(payload[0]).shape[:2])

    def _candidates(self, score: np.ndarray, geo: np.ndarray):
        """Thresholded pixels -> clipped integer candidate boxes, in
        (-score, y, x) order.  Vectorized; the reference decode redoes
        this per pixel in pure Python."""
        vh, vw = score.shape
        ys, xs = np.nonzero(score > self.score_thr)
        if ys.size == 0:
            return [], []
        d = geo[ys, xs]                      # (n, 4) order (t, r, b, l)
        x0 = np.clip(np.rint(xs - d[:, 3]), 0, vw - 1).astype(np.int64)
        y0 = np.clip(np.rint(ys - d[:, 0]), 0, vh - 1).astype(np.int64)
        x1 = np.clip(np.rint(xs + d[:, 1]), 0, vw - 1).astype(np.int64)
        y1 = np.clip(np.rint(ys + d[:, 2]), 0, vh - 1).astype(np.int64)
        sc = score[ys, xs]
        order = np.lexsort((xs, ys, -sc))    # primary -score, then y, x
        boxes = [(int(x0[i]), int(y0[i]), int(x1[i]), int(y1[i]))
                 for i in order]
        return boxes, [float(sc[i]) for i in order]

    @staticmethod
    def _nms(boxes, scores, iou_thr: float) -> List[Dict]:
        kept: List[Dict] = []
        for box, sc in zip(boxes, scores):
            if all(_iou(box, k["box"]) <= iou_thr for k in kept):
                kept.append({
                    "label": len(kept) + 1,
                    "box": box,
                    "area": (box[2] - box[0] + 1) * (box[3] - box[1] + 1),
                    "score": sc,
                })
        return kept

    def decode(self, payload, valid_hw):
        score, geo = payload
        vh, vw = valid_hw[0] // 4, valid_hw[1] // 4
        score = np.asarray(score)[:vh, :vw]
        geo = np.asarray(geo)[:vh, :vw]
        boxes, scores = self._candidates(score, geo)
        return self._nms(boxes, scores, self.nms_iou), "host"

    def reference_decode(self, out, valid_hw):
        score = self._crop_q(out["score"], valid_hw)
        geo = self._crop_q(out["geo"], valid_hw)
        vh, vw = score.shape
        cands = []
        for y in range(vh):                   # pure-Python oracle
            for x in range(vw):
                if not score[y, x] > self.score_thr:
                    continue
                t, r, b, l = (geo[y, x, 0], geo[y, x, 1],
                              geo[y, x, 2], geo[y, x, 3])
                box = (
                    int(min(max(np.rint(x - l), 0), vw - 1)),
                    int(min(max(np.rint(y - t), 0), vh - 1)),
                    int(min(max(np.rint(x + r), 0), vw - 1)),
                    int(min(max(np.rint(y + b), 0), vh - 1)),
                )
                cands.append((-float(score[y, x]), y, x, box))
        cands.sort(key=lambda c: c[:3])
        kept: List[Dict] = []
        for neg_sc, _, _, box in cands:
            if all(_iou(box, k["box"]) <= self.nms_iou for k in kept):
                kept.append({
                    "label": len(kept) + 1,
                    "box": box,
                    "area": (box[2] - box[0] + 1) * (box[3] - box[1] + 1),
                    "score": -neg_sc,
                })
        return kept


class DBHead(DetectionHead):
    """DB/FAST-style minimalist head: a residual 3x3/1x1 merge through
    the binary ``add`` microcode op (the residual read via ext_addr2 —
    the op the assembler's concat path used to double-count), ONE
    sigmoid shrink-mask channel, plain 8-connected CC over the mask, and
    the DB unclip expansion at decode time.  Supports the device-compact
    box tail (its payload is a single label map, like PixelLink's)."""

    name = "db"
    maps = (("score", 3),)
    payload_ranks = (3,)
    n_payload = 1
    supports_device_postprocess = True

    #: unclip growth factor (DB's r; the shrink target contracts text
    #: regions, decode grows them back)
    UNCLIP_RATIO = 1.5
    #: residual-merge width
    HEAD_CH = 16

    def __init__(self, score_thr: float = 0.5, link_thr: float = 0.5, *,
                 unclip_ratio: float = UNCLIP_RATIO, head_ch: int = HEAD_CH):
        super().__init__(score_thr, link_thr)
        self.unclip_ratio = float(unclip_ratio)
        self.head_ch = int(head_ch)

    def head_specs(self, feat):
        ch = self.head_ch
        specs = [
            LayerSpec("db_c3", "conv", [feat], out_ch=ch, kernel=3,
                      relu=True, bn=True, bias=False),
            LayerSpec("db_r1", "conv", ["db_c3"], out_ch=ch, kernel=1,
                      bn=True, bias=False),
            # the residual merge: reads db_r1 at in_addr and db_c3 via
            # ext_addr2 — channels must MATCH (never sum like a concat)
            LayerSpec("db_add", "add", ["db_r1", "db_c3"], relu=True),
            LayerSpec("head_logits", "conv", ["db_add"], out_ch=1,
                      kernel=1),
            LayerSpec("head_prob", "sigmoid", ["head_logits"]),
        ]
        return specs, ["head_logits", "head_prob"]

    def model_outputs(self, raw):
        prob = raw["head_prob"].astype(F32)
        return {
            "logits": raw["head_logits"].astype(F32),
            "score": prob[..., 0],
        }

    def tail(self, factory, out, valid_q):
        score = out["score"]
        # all-positive links turn the CC tail into plain 8-connected
        # labeling of the thresholded mask (link_thr < 1 always passes)
        links = jnp.ones(score.shape + (8,), score.dtype)
        return factory.label_tail(score, links, valid_q)

    def _unclip(self, boxes: List[Dict],
                valid_hw: Tuple[int, int]) -> List[Dict]:
        vq = (valid_hw[0] // 4, valid_hw[1] // 4)
        return [db_unclip_box(b, vq, self.unclip_ratio) for b in boxes]

    def decode(self, payload, valid_hw):
        from . import postprocess as pp

        if isinstance(payload, tuple):          # device-compact rows
            return self._unclip(pp.boxes_from_compact(payload[0]),
                                valid_hw), "device"
        boxes = pp.boxes_from_labels(self._crop_q(payload, valid_hw))
        return self._unclip(boxes, valid_hw), "host"

    def reference_decode(self, out, valid_hw):
        from . import postprocess as pp

        score = self._crop_q(out["score"], valid_hw)
        links = np.ones(score.shape + (8,), np.float32)
        labels = pp.cc_label_numpy(score, links,
                                   self.score_thr, self.link_thr)
        return self._unclip(pp.boxes_from_labels_reference(labels),
                            valid_hw)


#: name -> head class; the engine factory, serving layer, serve_bench
#: --model sweep, and the golden disassembly snapshots all route by it
MODEL_ZOO: Dict[str, type] = {
    "pixellink": PixelLinkHead,
    "east": EASTHead,
    "db": DBHead,
}


def check_model(model: str) -> str:
    if model not in MODEL_ZOO:
        raise ValueError(
            f"unknown model {model!r}; expected one of "
            f"{tuple(sorted(MODEL_ZOO))}"
        )
    return model


def build_head(model: str, *, score_thr: float = 0.5,
               link_thr: float = 0.5, **kw) -> DetectionHead:
    """One configured head instance from the zoo registry."""
    return MODEL_ZOO[check_model(model)](score_thr=score_thr,
                                         link_thr=link_thr, **kw)


class DetectionModel:
    """Backbone + EAST-style U-merge + one :class:`DetectionHead`,
    assembled to ONE microcode program and executed by FCNEngine — the
    generic model the whole zoo compiles through (PixelLinkModel is the
    ``head=PixelLinkHead()`` special case).

    ``cfg`` is duck-typed to the STDConfig fields (backbone, width,
    image_size, merge_ch, upsample_mode, mode, bfp, storage_fp16,
    use_pallas; ``memplan`` is optional and defaults True)."""

    def __init__(self, cfg, head: DetectionHead):
        self.cfg = cfg
        self.head = head
        h, w = cfg.image_size
        specs, taps = bb.BACKBONES[cfg.backbone](cfg.width)
        fspecs, fout = fusion.east_merge(
            taps, cfg.merge_ch, cfg.upsample_mode
        )
        hspecs, outs = head.head_specs(fout)
        self.program: Program = Assembler((h, w, 3)).assemble(
            specs + fspecs + hspecs, outputs=outs
        )
        self.engine = FCNEngine(
            self.program,
            mode=cfg.mode,
            bfp=cfg.bfp,
            storage_dtype=jnp.float16 if cfg.storage_fp16 else jnp.float32,
            use_pallas=cfg.use_pallas,
            memplan=getattr(cfg, "memplan", True),
        )

    def init_params(self, key):
        return self.engine.init_params(key)

    def for_plane(self, image_size: Tuple[int, int]) -> "DetectionModel":
        """The same architecture reassembled for another input plane
        (fully convolutional — parameters transfer 1:1; this is how the
        row-band ExecutionPlan builds its band-plane program)."""
        return DetectionModel(
            dataclasses.replace(self.cfg, image_size=tuple(image_size)),
            self.head,
        )

    def normalize_weights(self, params):
        """Paper Fig. 4 right branch (BN fold + BFP weight
        normalization)."""
        return self.engine.normalize_weights(params)

    def apply(self, params, images, *, transposed: bool = False,
              band_ctx=None) -> Dict[str, jax.Array]:
        """images (N, H, W, 3) -> the head's named maps + logits.

        Any leading batch size runs through ONE assembled program;
        ``transposed``/``band_ctx`` are the paper's §IV.B over-wide and
        row-band modes, threaded down to the engine unchanged."""
        if images.ndim != 4:
            raise ValueError(
                f"images must be (N, H, W, 3), got shape {images.shape}"
            )
        raw = self.engine(params, images, transposed=transposed,
                          band_ctx=band_ctx)
        return self.head.model_outputs(raw)

    def microcode_bytes(self):
        from repro.core.microcode import pack_program

        return pack_program(self.program.words)
