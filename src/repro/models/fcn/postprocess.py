"""Post-processing: positive pixels joined into Connected Components by
positive links; each CC is a detected text box (paper §III.A / PixelLink).

``cc_label`` is pure JAX (iterative max-label propagation in a while_loop
— TPU-friendly, no host sync).  Each label value encodes a linear pixel
index + 1, which buys two things:

  * **log-hop convergence** (``hop="log"``, the default): after the
    one-hop neighbor spread, a pointer-jumping step chases each label
    through the current label map (``labels <- max(labels,
    labels[labels - 1])``).  Because ``labels[p] - 1`` always indexes a
    pixel of p's own component (the spread only ever imports a linked
    neighbor's value, and values only grow toward the component max),
    the jump squares the reach per iteration — O(log diameter) rounds to
    the same fixpoint the one-hop path reaches in O(diameter).
  * **on-device box extraction** (``boxes_from_labels_jax``): converged
    label values are component ids, so a segment-reduce over pixel
    coordinates compacts a full (H, W) label map into a fixed-capacity
    ``(capacity + 1, 6)`` boxes tensor — the serving tail then
    materializes a few hundred bytes instead of the whole plane
    (docs/serving.md "Postprocess pipeline").

``cc_label_numpy`` is the union-find oracle used by the tests;
``boxes_from_labels`` extracts axis-aligned boxes on host for the
serving pipeline (single pass — scatter min/max + bincount);
``boxes_from_compact`` decodes the device-side compact rows into the
same box dicts.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# neighbor offsets, PixelLink's 8-connectivity, order: (dy, dx)
NEIGHBORS: Tuple[Tuple[int, int], ...] = (
    (-1, -1), (-1, 0), (-1, 1),
    (0, -1),           (0, 1),
    (1, -1), (1, 0), (1, 1),
)

#: label-propagation flavors: "log" = one-hop spread + pointer jumping
#: (O(log diameter) iterations), "one" = the plain one-hop spread
#: (O(diameter) — kept for the worst-case regression tests)
CC_HOPS = ("log", "one")


def link_symmetrize(links: jax.Array) -> jax.Array:
    """links (..., H, W, 8) -> OR with the reciprocal direction (PixelLink
    joins two pixels if EITHER direction predicts a positive link)."""
    rev = {0: 7, 1: 6, 2: 5, 3: 4, 4: 3, 5: 2, 6: 1, 7: 0}
    outs = []
    for d, (dy, dx) in enumerate(NEIGHBORS):
        rd = rev[d]
        nb = jnp.roll(links[..., rd], shift=(-dy, -dx), axis=(-2, -1))
        outs.append(jnp.maximum(links[..., d], nb))
    return jnp.stack(outs, axis=-1)


def cc_init_labels(pos: jax.Array) -> jax.Array:
    """Initial label map: each positive pixel holds its linear index + 1."""
    H, W = pos.shape
    return jnp.where(
        pos, jnp.arange(1, H * W + 1, dtype=jnp.int32).reshape(H, W), 0
    )


def cc_spread(labels: jax.Array, pos: jax.Array, lnk: jax.Array) -> jax.Array:
    """One hop of max-label propagation across positive links."""
    out = labels
    for d, (dy, dx) in enumerate(NEIGHBORS):
        # label of neighbor q = p + (dy, dx), viewed at p
        shifted = jnp.roll(labels, shift=(-dy, -dx), axis=(0, 1))
        # mask out wrap-around rows/cols
        if dy == 1:
            shifted = shifted.at[-1, :].set(0)
        elif dy == -1:
            shifted = shifted.at[0, :].set(0)
        if dx == 1:
            shifted = shifted.at[:, -1].set(0)
        elif dx == -1:
            shifted = shifted.at[:, 0].set(0)
        take = lnk[..., d] & pos
        out = jnp.where(take, jnp.maximum(out, shifted), out)
    return jnp.where(pos, out, 0)


def cc_pointer_jump(labels: jax.Array, pos: jax.Array) -> jax.Array:
    """Pointer jumping: ``labels <- max(labels, labels[labels - 1])``.

    Invariant: for a positive pixel p, ``labels[p] - 1`` is the linear
    index of a pixel in p's component (true at init, preserved by both
    the spread and the jump), so the hop stays inside the component and
    values stay bounded by the component max — same fixpoint as the
    one-hop spread, reached in O(log diameter) iterations."""
    H, W = labels.shape
    flat = labels.reshape(-1)
    ptr = jnp.take(flat, jnp.clip(flat - 1, 0, flat.shape[0] - 1))
    return jnp.where(pos, jnp.maximum(labels, ptr.reshape(H, W)), 0)


def check_hop(hop: str) -> str:
    if hop not in CC_HOPS:
        raise ValueError(f"unknown hop {hop!r}; expected one of {CC_HOPS}")
    return hop


def cc_label_stats(
    score: jax.Array,          # (H, W) probabilities
    links: jax.Array,          # (H, W, 8)
    score_thr: float = 0.5,
    link_thr: float = 0.5,
    max_iters: int = 256,
    hop: str = "log",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """``cc_label`` plus convergence diagnostics:
    ``(labels, iters, converged)``.

    ``iters`` is the number of propagation rounds actually run;
    ``converged`` is False iff the loop hit ``max_iters`` while labels
    were still changing — the silently-wrong case the serving path
    counts (CostBook ``pp_nonconverged``) instead of swallowing."""
    check_hop(hop)
    pos = score > score_thr
    lnk = link_symmetrize(links) > link_thr
    init = cc_init_labels(pos)

    def cond(state):
        labels, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        labels, _, it = state
        new = cc_spread(labels, pos, lnk)
        if hop == "log":
            new = cc_pointer_jump(new, pos)
        return new, jnp.any(new != labels), it + 1

    labels, changed, it = jax.lax.while_loop(
        cond, body, (init, jnp.bool_(True), jnp.int32(0))
    )
    return labels, it, ~changed


def cc_label(
    score: jax.Array,          # (H, W) probabilities
    links: jax.Array,          # (H, W, 8)
    score_thr: float = 0.5,
    link_thr: float = 0.5,
    max_iters: int = 256,
    hop: str = "log",
) -> jax.Array:
    """Label map (H, W) int32; 0 = background, labels = max linear index+1
    within the component.  ``hop="log"`` (default) converges in O(log
    diameter) rounds via pointer jumping; ``hop="one"`` is the legacy
    one-hop propagation."""
    return cc_label_stats(score, links, score_thr, link_thr, max_iters,
                          hop)[0]


def cc_label_batched(
    score: jax.Array,          # (N, H, W) probabilities
    links: jax.Array,          # (N, H, W, 8)
    score_thr: float = 0.5,
    link_thr: float = 0.5,
    max_iters: int = 256,
    valid_mask: Optional[jax.Array] = None,    # (N, H, W) bool
    hop: str = "log",
    return_stats: bool = False,
):
    """Vectorized ``cc_label`` over a leading batch axis -> (N, H, W) int32.

    The per-image propagation is a fixpoint, so the batched while_loop
    (which iterates until EVERY image converges) yields exactly the
    per-image result — and the vmapped loop state keeps exact per-image
    ``iters``/``converged`` (an element whose cond is False stops
    updating).  ``valid_mask`` zeroes scores outside each image's
    valid region so bucket padding can never grow or merge components —
    used by the serving path where images of different true sizes share
    one padded batch shape.  With ``return_stats`` the result is
    ``(labels, iters, converged)`` with (N,) diagnostics."""
    if valid_mask is not None:
        score = jnp.where(valid_mask, score, 0.0)
    f = lambda s, l: cc_label_stats(s, l, score_thr, link_thr, max_iters,
                                    hop)
    labels, iters, converged = jax.vmap(f)(score, links)
    if return_stats:
        return labels, iters, converged
    return labels


def cc_label_numpy(
    score: np.ndarray, links: np.ndarray,
    score_thr: float = 0.5, link_thr: float = 0.5,
) -> np.ndarray:
    """Union-find oracle with identical link semantics."""
    H, W = score.shape
    pos = score > score_thr
    lnk = np.asarray(link_symmetrize(jnp.asarray(links))) > link_thr
    parent = np.arange(H * W)

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)

    for y in range(H):
        for x in range(W):
            if not pos[y, x]:
                continue
            for d, (dy, dx) in enumerate(NEIGHBORS):
                ny, nx = y + dy, x + dx
                if 0 <= ny < H and 0 <= nx < W and pos[ny, nx] and lnk[y, x, d]:
                    union(y * W + x, ny * W + nx)
    out = np.zeros((H, W), np.int32)
    for y in range(H):
        for x in range(W):
            if pos[y, x]:
                out[y, x] = find(y * W + x) + 1
    return out


def boxes_from_labels(labels: np.ndarray, min_area: int = 1) -> List[Dict]:
    """Axis-aligned boxes per component (host-side, serving tail).

    Single pass over the positive pixels: compact the label values once
    (``np.unique(return_inverse=True)``), then scatter-reduce the
    coordinate extrema (``np.minimum.at`` / ``np.maximum.at``) and count
    areas with ``np.bincount`` — O(H*W + K) instead of the old
    O(K * H*W) full-plane scan per component.  Output order (ascending
    label value) and contents are identical to the reference
    implementation (parity-pinned in tests)."""
    labels = np.asarray(labels)
    ys, xs = np.nonzero(labels)
    if ys.size == 0:
        return []
    uniq, inv = np.unique(labels[ys, xs], return_inverse=True)
    k = uniq.size
    x0 = np.full(k, np.iinfo(np.int64).max)
    y0 = np.full(k, np.iinfo(np.int64).max)
    x1 = np.full(k, -1)
    y1 = np.full(k, -1)
    np.minimum.at(x0, inv, xs)
    np.minimum.at(y0, inv, ys)
    np.maximum.at(x1, inv, xs)
    np.maximum.at(y1, inv, ys)
    area = np.bincount(inv, minlength=k)
    return [
        {
            "label": int(uniq[i]),
            "box": (int(x0[i]), int(y0[i]), int(x1[i]), int(y1[i])),
            "area": int(area[i]),
        }
        for i in range(k)
        if area[i] >= min_area
    ]


def boxes_from_labels_reference(labels: np.ndarray,
                                min_area: int = 1) -> List[Dict]:
    """The original quadratic extraction (per-label full-plane scan) —
    kept as the parity oracle for :func:`boxes_from_labels`."""
    labels = np.asarray(labels)
    out = []
    for lab in np.unique(labels):
        if lab == 0:
            continue
        ys, xs = np.nonzero(labels == lab)
        if ys.size < min_area:
            continue
        out.append({
            "label": int(lab),
            "box": (int(xs.min()), int(ys.min()), int(xs.max()), int(ys.max())),
            "area": int(ys.size),
        })
    return out


#: fill value marking unused unique-label slots in the device extraction
#: (larger than any real label: labels are bounded by H*W + 1)
_BOX_FILL = np.iinfo(np.int32).max


def boxes_from_labels_jax(labels: jax.Array, capacity: int):
    """On-device box extraction: (H, W) int32 label map ->
    ``(rows, n_components)`` with ``rows`` a ``(capacity + 1, 6)`` int32
    tensor of ``(label, x0, y0, x1, y1, area)`` and ``n_components`` the
    EXACT component count.

    The label values are compacted with a fixed-size sorted
    ``jnp.unique`` (slot 0 absorbs the background 0 when present; unused
    slots carry the fill sentinel at the end), pixel coordinates are
    segment-min/max-reduced into their label's slot, and areas are
    segment-summed — all O(H*W), no host sync.  Rows are ordered by
    ascending label value, exactly matching the host
    :func:`boxes_from_labels` order; invalid slots are all-zero.

    ``n_components`` counts fixpoint representatives (pixels whose label
    is their own index + 1) — exact for converged label maps regardless
    of capacity, so ``n_components > capacity`` detects truncation (the
    serving path falls back to host extraction for that image; an
    unconverged map can only overcount, never hide an overflow)."""
    H, W = labels.shape
    npx = H * W
    flat = labels.reshape(-1).astype(jnp.int32)
    fill = jnp.int32(_BOX_FILL)
    uniq = jnp.unique(flat, size=capacity + 1, fill_value=fill)
    slot = jnp.clip(jnp.searchsorted(uniq, flat), 0, capacity)
    # a pixel contributes only when its label actually owns the slot
    # (overflowed labels miss — their rows are garbage anyway, and the
    # exact count below forces the fallback path)
    ok = (jnp.take(uniq, slot) == flat) & (flat > 0)
    idx = jnp.arange(npx, dtype=jnp.int32)
    ys, xs = idx // W, idx % W
    big = jnp.int32(max(H, W))
    seg = capacity + 1
    x0 = jax.ops.segment_min(jnp.where(ok, xs, big), slot, num_segments=seg)
    y0 = jax.ops.segment_min(jnp.where(ok, ys, big), slot, num_segments=seg)
    x1 = jax.ops.segment_max(jnp.where(ok, xs, -1), slot, num_segments=seg)
    y1 = jax.ops.segment_max(jnp.where(ok, ys, -1), slot, num_segments=seg)
    area = jax.ops.segment_sum(ok.astype(jnp.int32), slot, num_segments=seg)
    lab = jnp.where((uniq > 0) & (uniq < fill), uniq, 0)
    rows = jnp.stack([lab, x0, y0, x1, y1, area], axis=-1)
    rows = jnp.where(((lab > 0) & (area > 0))[:, None], rows, 0)
    n = jnp.sum((flat == idx + 1).astype(jnp.int32))
    return rows, n


def boxes_from_labels_batched_jax(labels: jax.Array, capacity: int):
    """Batched :func:`boxes_from_labels_jax`: (N, H, W) ->
    ``((N, capacity + 1, 6) rows, (N,) counts)``."""
    return jax.vmap(lambda l: boxes_from_labels_jax(l, capacity))(labels)


def boxes_from_compact(rows: np.ndarray, min_area: int = 1) -> List[Dict]:
    """Decode device-side compact box rows into the host box dicts —
    the trivial O(capacity) tail of the device postprocess path.
    Row order (ascending label) is preserved, so the output matches
    :func:`boxes_from_labels` on the same label map exactly."""
    rows = np.asarray(rows)
    keep = (rows[:, 0] > 0) & (rows[:, 5] >= min_area)
    return [
        {
            "label": int(lab),
            "box": (int(x0), int(y0), int(x1), int(y1)),
            "area": int(area),
        }
        for lab, x0, y0, x1, y1, area in rows[keep]
    ]


def f_measure(
    pred_boxes: List[Dict], gt_boxes: List[Tuple[int, int, int, int]],
    iou_thr: float = 0.5,
) -> Dict[str, float]:
    """IoU-matched precision/recall/F (the paper's Table VI metrics).

    Each prediction greedily matches the unmatched GT box with the
    HIGHEST IoU at or above the threshold (not the first one past it —
    first-past-threshold matching can burn a GT another prediction
    overlaps better, under-counting TPs on overlapping GTs)."""
    def iou(a, b):
        ax0, ay0, ax1, ay1 = a
        bx0, by0, bx1, by1 = b
        ix0, iy0 = max(ax0, bx0), max(ay0, by0)
        ix1, iy1 = min(ax1, bx1), min(ay1, by1)
        iw, ih = max(ix1 - ix0 + 1, 0), max(iy1 - iy0 + 1, 0)
        inter = iw * ih
        ua = (ax1 - ax0 + 1) * (ay1 - ay0 + 1)
        ub = (bx1 - bx0 + 1) * (by1 - by0 + 1)
        return inter / max(ua + ub - inter, 1)

    matched_gt = set()
    tp = 0
    for pb in pred_boxes:
        best_gi, best_iou = -1, 0.0
        for gi, gb in enumerate(gt_boxes):
            if gi in matched_gt:
                continue
            v = iou(pb["box"], gb)
            if v >= iou_thr and v > best_iou:
                best_gi, best_iou = gi, v
        if best_gi >= 0:
            matched_gt.add(best_gi)
            tp += 1
    prec = tp / max(len(pred_boxes), 1)
    rec = tp / max(len(gt_boxes), 1)
    f = 2 * prec * rec / max(prec + rec, 1e-9)
    return {"precision": prec, "recall": rec, "f_measure": f}
