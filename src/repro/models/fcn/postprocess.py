"""Post-processing: positive pixels joined into Connected Components by
positive links; each CC is a detected text box (paper §III.A / PixelLink).

``cc_label`` is pure JAX (iterative max-label propagation in a while_loop
— TPU-friendly, no host sync); ``cc_label_numpy`` is the union-find oracle
used by the tests; ``boxes_from_labels`` extracts axis-aligned boxes on
host for the serving pipeline.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# neighbor offsets, PixelLink's 8-connectivity, order: (dy, dx)
NEIGHBORS: Tuple[Tuple[int, int], ...] = (
    (-1, -1), (-1, 0), (-1, 1),
    (0, -1),           (0, 1),
    (1, -1), (1, 0), (1, 1),
)


def link_symmetrize(links: jax.Array) -> jax.Array:
    """links (..., H, W, 8) -> OR with the reciprocal direction (PixelLink
    joins two pixels if EITHER direction predicts a positive link)."""
    rev = {0: 7, 1: 6, 2: 5, 3: 4, 4: 3, 5: 2, 6: 1, 7: 0}
    outs = []
    for d, (dy, dx) in enumerate(NEIGHBORS):
        rd = rev[d]
        nb = jnp.roll(links[..., rd], shift=(-dy, -dx), axis=(-2, -1))
        outs.append(jnp.maximum(links[..., d], nb))
    return jnp.stack(outs, axis=-1)


def cc_label(
    score: jax.Array,          # (H, W) probabilities
    links: jax.Array,          # (H, W, 8)
    score_thr: float = 0.5,
    link_thr: float = 0.5,
    max_iters: int = 256,
) -> jax.Array:
    """Label map (H, W) int32; 0 = background, labels = max linear index+1
    within the component."""
    H, W = score.shape
    pos = score > score_thr
    lnk = link_symmetrize(links) > link_thr
    init = jnp.where(
        pos, jnp.arange(1, H * W + 1, dtype=jnp.int32).reshape(H, W), 0
    )

    def spread(labels):
        out = labels
        for d, (dy, dx) in enumerate(NEIGHBORS):
            # label of neighbor q = p + (dy, dx), viewed at p
            shifted = jnp.roll(labels, shift=(-dy, -dx), axis=(0, 1))
            # mask out wrap-around rows/cols
            if dy == 1:
                shifted = shifted.at[-1, :].set(0)
            elif dy == -1:
                shifted = shifted.at[0, :].set(0)
            if dx == 1:
                shifted = shifted.at[:, -1].set(0)
            elif dx == -1:
                shifted = shifted.at[:, 0].set(0)
            take = lnk[..., d] & pos
            out = jnp.where(take, jnp.maximum(out, shifted), out)
        return jnp.where(pos, out, 0)

    def cond(state):
        labels, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        labels, _, it = state
        new = spread(labels)
        return new, jnp.any(new != labels), it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (init, jnp.bool_(True), 0))
    return labels


def cc_label_batched(
    score: jax.Array,          # (N, H, W) probabilities
    links: jax.Array,          # (N, H, W, 8)
    score_thr: float = 0.5,
    link_thr: float = 0.5,
    max_iters: int = 256,
    valid_mask: Optional[jax.Array] = None,    # (N, H, W) bool
) -> jax.Array:
    """Vectorized ``cc_label`` over a leading batch axis -> (N, H, W) int32.

    The per-image propagation is a fixpoint, so the batched while_loop
    (which iterates until EVERY image converges) yields exactly the
    per-image result.  ``valid_mask`` zeroes scores outside each image's
    valid region so bucket padding can never grow or merge components —
    used by the serving path where images of different true sizes share
    one padded batch shape.
    """
    if valid_mask is not None:
        score = jnp.where(valid_mask, score, 0.0)
    f = lambda s, l: cc_label(s, l, score_thr, link_thr, max_iters)
    return jax.vmap(f)(score, links)


def cc_label_numpy(
    score: np.ndarray, links: np.ndarray,
    score_thr: float = 0.5, link_thr: float = 0.5,
) -> np.ndarray:
    """Union-find oracle with identical link semantics."""
    H, W = score.shape
    pos = score > score_thr
    lnk = np.asarray(link_symmetrize(jnp.asarray(links))) > link_thr
    parent = np.arange(H * W)

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)

    for y in range(H):
        for x in range(W):
            if not pos[y, x]:
                continue
            for d, (dy, dx) in enumerate(NEIGHBORS):
                ny, nx = y + dy, x + dx
                if 0 <= ny < H and 0 <= nx < W and pos[ny, nx] and lnk[y, x, d]:
                    union(y * W + x, ny * W + nx)
    out = np.zeros((H, W), np.int32)
    for y in range(H):
        for x in range(W):
            if pos[y, x]:
                out[y, x] = find(y * W + x) + 1
    return out


def boxes_from_labels(labels: np.ndarray, min_area: int = 1) -> List[Dict]:
    """Axis-aligned boxes per component (host-side, serving tail)."""
    labels = np.asarray(labels)
    out = []
    for lab in np.unique(labels):
        if lab == 0:
            continue
        ys, xs = np.nonzero(labels == lab)
        if ys.size < min_area:
            continue
        out.append({
            "label": int(lab),
            "box": (int(xs.min()), int(ys.min()), int(xs.max()), int(ys.max())),
            "area": int(ys.size),
        })
    return out


def f_measure(
    pred_boxes: List[Dict], gt_boxes: List[Tuple[int, int, int, int]],
    iou_thr: float = 0.5,
) -> Dict[str, float]:
    """IoU-matched precision/recall/F (the paper's Table VI metrics)."""
    def iou(a, b):
        ax0, ay0, ax1, ay1 = a
        bx0, by0, bx1, by1 = b
        ix0, iy0 = max(ax0, bx0), max(ay0, by0)
        ix1, iy1 = min(ax1, bx1), min(ay1, by1)
        iw, ih = max(ix1 - ix0 + 1, 0), max(iy1 - iy0 + 1, 0)
        inter = iw * ih
        ua = (ax1 - ax0 + 1) * (ay1 - ay0 + 1)
        ub = (bx1 - bx0 + 1) * (by1 - by0 + 1)
        return inter / max(ua + ub - inter, 1)

    matched_gt = set()
    tp = 0
    for pb in pred_boxes:
        for gi, gb in enumerate(gt_boxes):
            if gi in matched_gt:
                continue
            if iou(pb["box"], gb) >= iou_thr:
                matched_gt.add(gi)
                tp += 1
                break
    prec = tp / max(len(pred_boxes), 1)
    rec = tp / max(len(gt_boxes), 1)
    f = 2 * prec * rec / max(prec + rec, 1e-9)
    return {"precision": prec, "recall": rec, "f_measure": f}
