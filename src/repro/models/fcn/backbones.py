"""Feature-extraction backbones as LayerSpec emitters (paper §III.A: "The
feature extraction network has several candidates such as ResNet, VGG, and
MobileNet... the developer can modify the microcode to compute different
networks").

Each builder returns (specs, taps) where taps are the four feature levels
at 1/4, 1/8, 1/16, 1/32 of the input (paper Fig. 1).  Residual blocks use
the res_op cache/add mechanism exactly as the paper's Fig. 3; channel
widths may be scaled (``width``) for the reduced smoke configs.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core.assembler import LayerSpec


def _c(ch: int, width: float) -> int:
    return max(int(ch * width), 8)


# ---------------------------------------------------------------------------
# ResNet-50 (v1.5: stride on the 3x3)
# ---------------------------------------------------------------------------

def resnet50(width: float = 1.0, blocks=(3, 4, 6, 3)) -> Tuple[List[LayerSpec], List[str]]:
    specs: List[LayerSpec] = []
    add = specs.append
    add(LayerSpec("stem", "conv", ["input"], out_ch=_c(64, width), kernel=7,
                  stride=2, relu=True, bn=True, bias=False))
    add(LayerSpec("stem_pool", "pool", ["stem"], kernel=3, stride=2))

    taps: List[str] = []
    prev = "stem_pool"
    in_ch = _c(64, width)
    for si, (n, base) in enumerate(zip(blocks, (64, 128, 256, 512))):
        mid = _c(base, width)
        out = mid * 4
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            name = f"s{si+1}b{bi+1}"
            if bi == 0:
                # projection shortcut: result cached (paper Fig. 3 pattern)
                add(LayerSpec(f"{name}_proj", "conv", [prev], out_ch=out,
                              kernel=1, stride=stride, bn=True, bias=False,
                              res="cache"))
                first_in = prev
            else:
                # identity shortcut: cache the block input
                add(LayerSpec(f"{name}_id", "identity", [prev], res="cache"))
                first_in = prev
            add(LayerSpec(f"{name}_c1", "conv", [first_in], out_ch=mid,
                          kernel=1, relu=True, bn=True, bias=False))
            add(LayerSpec(f"{name}_c2", "conv", [f"{name}_c1"], out_ch=mid,
                          kernel=3, stride=stride, relu=True, bn=True,
                          bias=False))
            add(LayerSpec(f"{name}_c3", "conv", [f"{name}_c2"], out_ch=out,
                          kernel=1, bn=True, bias=False, res="add",
                          relu=True))
            prev = f"{name}_c3"
        taps.append(prev)
        in_ch = out
    return specs, taps


# ---------------------------------------------------------------------------
# VGG-16 (without FC layers, as in the paper's Fig. 8b)
# ---------------------------------------------------------------------------

def vgg16(width: float = 1.0) -> Tuple[List[LayerSpec], List[str]]:
    cfg = [
        (2, 64), (2, 128), (3, 256), (3, 512), (3, 512),
    ]
    specs: List[LayerSpec] = []
    prev = "input"
    taps: List[str] = []
    for si, (n, ch) in enumerate(cfg):
        for bi in range(n):
            name = f"conv{si+1}_{bi+1}"
            specs.append(LayerSpec(name, "conv", [prev], out_ch=_c(ch, width),
                                   kernel=3, relu=True, bn=True, bias=False))
            prev = name
        pool = f"pool{si+1}"
        specs.append(LayerSpec(pool, "pool", [prev], kernel=2, stride=2))
        prev = pool
        if si >= 1:
            taps.append(pool)     # pool2 1/4, pool3 1/8, pool4 1/16, pool5 1/32
    return specs, taps


# ---------------------------------------------------------------------------
# MobileNet-v1 style (depthwise separable; ext_flags bit 0 = depthwise)
# ---------------------------------------------------------------------------

def mobilenet(width: float = 1.0) -> Tuple[List[LayerSpec], List[str]]:
    specs: List[LayerSpec] = []
    prev = "input"
    specs.append(LayerSpec("stem", "conv", [prev], out_ch=_c(32, width),
                           kernel=3, stride=2, relu=True, bn=True,
                           bias=False))
    prev = "stem"
    plan = [  # (stride, out_ch)
        (1, 64), (2, 128), (1, 128), (2, 256), (1, 256),
        (2, 512), (1, 512), (1, 512), (1, 512), (1, 512), (1, 512),
        (2, 1024), (1, 1024),
    ]
    taps: List[str] = []
    cur_scale = 2
    tap_scales = {4, 8, 16, 32}
    in_ch = _c(32, width)
    for i, (s, ch) in enumerate(plan):
        if s == 2 and cur_scale in tap_scales:
            taps.append(prev)
        dw = f"dw{i+1}"
        pw = f"pw{i+1}"
        specs.append(LayerSpec(dw, "conv", [prev], out_ch=in_ch, kernel=3,
                               stride=s, relu=True, bn=True, bias=False,
                               table={"depthwise": True}))
        specs.append(LayerSpec(pw, "conv", [dw], out_ch=_c(ch, width),
                               kernel=1, relu=True, bn=True, bias=False))
        prev = pw
        in_ch = _c(ch, width)
        cur_scale *= s
    taps.append(prev)
    return specs, taps[-4:]


BACKBONES = {"resnet50": resnet50, "vgg16": vgg16, "mobilenet": mobilenet}
