"""PixelLink-style STD model: backbone + fusion assembled to ONE microcode
program (paper Fig. 1 + §III), plus the segmentation losses.

The model's outputs are pixel-wise at 1/4 input scale:
    score (1 ch)  — text / non-text probability
    links (8 ch)  — 8-neighbor same-instance probabilities
Connected components over positive links recover text boxes without any
box regression (postprocess.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import Assembler, BFPConfig, FCNEngine, LayerSpec
from repro.core.assembler import Program

from . import backbones as bb
from . import fusion

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class STDConfig:
    name: str = "pixellink_resnet50"
    backbone: str = "resnet50"
    width: float = 1.0
    image_size: Tuple[int, int] = (512, 512)     # (H, W); W <= 4096 (paper)
    merge_ch: Tuple[int, int, int] = (128, 64, 32)
    upsample_mode: str = "fused"
    mode: str = "optimized"                      # reference|optimized
    bfp: Optional[BFPConfig] = None
    storage_fp16: bool = True                    # paper's data-pool format
    use_pallas: bool = False                     # Pallas kernels in the
                                                 # optimized datapath


class PixelLinkModel:
    def __init__(self, cfg: STDConfig):
        self.cfg = cfg
        h, w = cfg.image_size
        specs, taps = bb.BACKBONES[cfg.backbone](cfg.width)
        fspecs, fout = fusion.east_merge(
            taps, cfg.merge_ch, cfg.upsample_mode
        )
        hspecs, outs = fusion.pixellink_head(fout)
        self.program: Program = Assembler((h, w, 3)).assemble(
            specs + fspecs + hspecs, outputs=outs
        )
        self.engine = FCNEngine(
            self.program,
            mode=cfg.mode,
            bfp=cfg.bfp,
            storage_dtype=jnp.float16 if cfg.storage_fp16 else jnp.float32,
            use_pallas=cfg.use_pallas,
        )

    def init_params(self, key):
        return self.engine.init_params(key)

    def for_plane(self, image_size: Tuple[int, int]) -> "PixelLinkModel":
        """The same architecture reassembled for another input plane.

        The model is fully convolutional, so parameters transfer 1:1 —
        this is how the row-band ExecutionPlan builds its per-band
        program (band + halo rows) while sharing the full-plane weights
        (runtime/executor.py)."""
        return PixelLinkModel(
            dataclasses.replace(self.cfg, image_size=tuple(image_size))
        )

    def normalize_weights(self, params):
        """Paper Fig. 4 right branch (BN fold + BFP weight normalization)."""
        return self.engine.normalize_weights(params)

    def apply(self, params, images, *, transposed: bool = False,
              band_ctx=None) -> Dict[str, jax.Array]:
        """images: (N, H, W, 3) -> {score (N,h,w), links (N,h,w,8), logits}.

        Any leading batch size runs through ONE assembled program — the
        serving scheduler compiles one engine per (bucket, batch) shape.
        ``transposed=True`` is the paper's §IV.B over-wide mode, threaded
        down to the engine (kernels transpose, datapath unchanged).
        ``band_ctx`` is the §IV.B row-band mode: ``images`` is one
        horizontal band of a taller plane and spatial layers
        halo-exchange boundary rows (see runtime/executor.py RowBand).
        """
        if images.ndim != 4:
            raise ValueError(
                f"images must be (N, H, W, 3), got shape {images.shape}"
            )
        out = self.engine(params, images, transposed=transposed,
                          band_ctx=band_ctx)
        prob = out["head_prob"].astype(F32)
        return {
            "logits": out["head_logits"].astype(F32),
            "score": prob[..., 0],
            "links": prob[..., 1:],
        }

    def microcode_bytes(self):
        from repro.core.microcode import pack_program

        return pack_program(self.program.words)


class STDLoss:
    """Class-balanced BCE on score + link BCE masked to positive pixels
    (PixelLink's loss structure, simplified: no instance-balancing)."""

    def __init__(self, neg_ratio: float = 3.0, link_weight: float = 1.0):
        self.neg_ratio = neg_ratio
        self.link_weight = link_weight

    def __call__(self, outputs, score_gt, link_gt) -> Dict[str, jax.Array]:
        logits = outputs["logits"]
        s_logit = logits[..., 0]
        l_logit = logits[..., 1:]
        pos = (score_gt > 0.5).astype(F32)
        neg = 1.0 - pos
        bce = lambda lg, y: jnp.maximum(lg, 0) - lg * y + jnp.log1p(
            jnp.exp(-jnp.abs(lg))
        )
        s_l = bce(s_logit, score_gt)
        n_pos = jnp.maximum(jnp.sum(pos), 1.0)
        # hard negative count = neg_ratio * n_pos (OHEM-lite: weight all
        # negatives by the ratio of the budget to the negative count)
        n_neg = jnp.minimum(self.neg_ratio * n_pos, jnp.sum(neg))
        w = pos + neg * (n_neg / jnp.maximum(jnp.sum(neg), 1.0))
        score_loss = jnp.sum(s_l * w) / jnp.maximum(jnp.sum(w), 1.0)

        l_l = bce(l_logit, link_gt)
        link_mask = pos[..., None]
        link_loss = jnp.sum(l_l * link_mask) / jnp.maximum(
            jnp.sum(link_mask) * l_logit.shape[-1] / link_gt.shape[-1], 1.0
        )
        total = score_loss + self.link_weight * link_loss
        return {"loss": total, "score_loss": score_loss,
                "link_loss": link_loss}
