"""PixelLink-style STD model: backbone + fusion assembled to ONE microcode
program (paper Fig. 1 + §III), plus the segmentation losses.

The model's outputs are pixel-wise at 1/4 input scale:
    score (1 ch)  — text / non-text probability
    links (8 ch)  — 8-neighbor same-instance probabilities
Connected components over positive links recover text boxes without any
box regression (postprocess.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import BFPConfig

from .heads import DetectionModel, PixelLinkHead

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class STDConfig:
    name: str = "pixellink_resnet50"
    backbone: str = "resnet50"
    width: float = 1.0
    image_size: Tuple[int, int] = (512, 512)     # (H, W); W <= 4096 (paper)
    merge_ch: Tuple[int, int, int] = (128, 64, 32)
    upsample_mode: str = "fused"
    mode: str = "optimized"                      # reference|optimized
    bfp: Optional[BFPConfig] = None
    storage_fp16: bool = True                    # paper's data-pool format
    use_pallas: bool = False                     # Pallas kernels in the
                                                 # optimized datapath
    memplan: bool = True                         # static memory plan
                                                 # (core.memplan): fusion
                                                 # facts + drop-at-last-use


class PixelLinkModel(DetectionModel):
    """The zoo's ``head=PixelLinkHead()`` special case, kept as a named
    class for back-compat: apply() returns {score (N,h,w), links
    (N,h,w,8), logits} exactly as before the DetectionHead refactor."""

    def __init__(self, cfg: STDConfig):
        super().__init__(cfg, PixelLinkHead())


class STDLoss:
    """Class-balanced BCE on score + link BCE masked to positive pixels
    (PixelLink's loss structure, simplified: no instance-balancing)."""

    def __init__(self, neg_ratio: float = 3.0, link_weight: float = 1.0):
        self.neg_ratio = neg_ratio
        self.link_weight = link_weight

    def __call__(self, outputs, score_gt, link_gt) -> Dict[str, jax.Array]:
        logits = outputs["logits"]
        s_logit = logits[..., 0]
        l_logit = logits[..., 1:]
        pos = (score_gt > 0.5).astype(F32)
        neg = 1.0 - pos
        bce = lambda lg, y: jnp.maximum(lg, 0) - lg * y + jnp.log1p(
            jnp.exp(-jnp.abs(lg))
        )
        s_l = bce(s_logit, score_gt)
        n_pos = jnp.maximum(jnp.sum(pos), 1.0)
        # hard negative count = neg_ratio * n_pos (OHEM-lite: weight all
        # negatives by the ratio of the budget to the negative count)
        n_neg = jnp.minimum(self.neg_ratio * n_pos, jnp.sum(neg))
        w = pos + neg * (n_neg / jnp.maximum(jnp.sum(neg), 1.0))
        score_loss = jnp.sum(s_l * w) / jnp.maximum(jnp.sum(w), 1.0)

        l_l = bce(l_logit, link_gt)
        link_mask = pos[..., None]
        # masked mean over ELEMENTS: the sum covers all n_links channels
        # of every positive pixel, so the denominator is positive pixels
        # x n_links (dividing by positive pixels alone inflates the link
        # term n_links-fold vs the documented BCE mean)
        link_loss = jnp.sum(l_l * link_mask) / jnp.maximum(
            jnp.sum(link_mask) * l_logit.shape[-1], 1.0
        )
        total = score_loss + self.link_weight * link_loss
        return {"loss": total, "score_loss": score_loss,
                "link_loss": link_loss}
