"""The paper's own model family: instance-segmentation STD (PixelLink [6]
+ EAST [24] style U-shape FCN) with configurable backbones, assembled to
microcode and executed by repro.core.FCNEngine."""
from . import backbones, fusion, pixellink, postprocess
from .pixellink import PixelLinkModel, STDLoss

__all__ = [
    "backbones", "fusion", "pixellink", "postprocess",
    "PixelLinkModel", "STDLoss",
]
