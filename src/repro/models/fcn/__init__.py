"""The paper's own model family: instance-segmentation STD (PixelLink [6]
+ EAST [24] style U-shape FCN) with configurable backbones, assembled to
microcode and executed by repro.core.FCNEngine.  heads.py is the model
zoo: every detection head compiles through the same assembler seam."""
from . import backbones, fusion, heads, pixellink, postprocess
from .heads import (
    DEFAULT_MODEL,
    MODEL_ZOO,
    DBHead,
    DetectionHead,
    DetectionModel,
    EASTHead,
    PixelLinkHead,
    build_head,
    check_model,
)
from .pixellink import PixelLinkModel, STDLoss

__all__ = [
    "backbones", "fusion", "heads", "pixellink", "postprocess",
    "DEFAULT_MODEL", "MODEL_ZOO", "DBHead", "DetectionHead",
    "DetectionModel", "EASTHead", "PixelLinkHead", "build_head",
    "check_model", "PixelLinkModel", "STDLoss",
]
