"""Feature-fusion network (paper Fig. 1): EAST-style U-merge of the four
backbone taps + PixelLink pixel-wise heads.

The merge path per level: upsample the deeper feature x2, *concat* with
the lateral tap (concat = adjacent-address allocation in the assembler —
the paper's §III.B mechanism), then conv1x1 (channel squeeze) + conv3x3.
The head emits 1 score channel + 8 link channels through the fusion
module's sigmoid unit (which replaces maxpool in the fusion datapath —
paper §III.D).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.core.assembler import LayerSpec

N_LINKS = 8
HEAD_CH = 1 + N_LINKS        # score + 8 neighbor links


def east_merge(
    taps: Sequence[str],
    merge_ch: Sequence[int] = (128, 64, 32),
    upsample_mode: str = "fused",
) -> Tuple[List[LayerSpec], str]:
    """taps: [1/4, 1/8, 1/16, 1/32] feature names.  Returns (specs, out)."""
    assert len(taps) == 4
    specs: List[LayerSpec] = []
    h = taps[-1]                       # deepest (1/32)
    for i, lateral in enumerate(reversed(taps[:-1])):   # 1/16, 1/8, 1/4
        ch = merge_ch[i]
        # squeeze channels BEFORE upsampling so the fused (learnable
        # phase-decomposed) upsample kernel stays ch x ch
        sq = f"merge{i+1}_sq"
        specs.append(LayerSpec(sq, "conv", [h], out_ch=ch, kernel=1,
                               relu=True, bn=True, bias=False))
        up = f"merge{i+1}_up"
        specs.append(LayerSpec(up, "upsample", [sq],
                               upsample_mode=upsample_mode))
        cc = f"merge{i+1}_c1"
        specs.append(LayerSpec(cc, "conv", [up, lateral], out_ch=ch,
                               kernel=1, relu=True, bn=True, bias=False))
        cv = f"merge{i+1}_c3"
        specs.append(LayerSpec(cv, "conv", [cc], out_ch=ch, kernel=3,
                               relu=True, bn=True, bias=False))
        h = cv
    specs.append(LayerSpec("fuse_out", "conv", [h], out_ch=merge_ch[-1],
                           kernel=3, relu=True, bn=True, bias=False))
    return specs, "fuse_out"


def pixellink_head(feat: str) -> Tuple[List[LayerSpec], List[str]]:
    """1 score + 8 link channels, sigmoid'd (fusion-module sigmoid unit)."""
    specs = [
        LayerSpec("head_logits", "conv", [feat], out_ch=HEAD_CH, kernel=1),
        LayerSpec("head_prob", "sigmoid", ["head_logits"]),
    ]
    return specs, ["head_logits", "head_prob"]
