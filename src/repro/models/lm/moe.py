"""Mixture-of-Experts datapath module (grok-1 8e/top-2, kimi-k2 384e/top-8).

Capacity-bounded dispatch with **sort-based ranking**: the usual one-hot
cumsum rank computation is O(T*k*E) memory — at kimi-k2 prefill scale
(1M tokens x 384 experts) that is terabytes.  Ranking via a stable argsort
of expert ids is O(T*k): at 8M (token,slot) pairs it is ~32 MB.  Dispatch/
combine are gathers/scatters, which the SPMD partitioner lowers to the
expert all-to-all when experts are sharded.

Compute scales with ``tokens * top_k * capacity_factor`` (active FLOPs),
never with n_experts.

Sharding: experts dim over "model" when divisible (kimi: 384 % 16 == 0 ->
true EP); otherwise d_ff picks up "model" (grok: 8 experts < 16 devices ->
expert-TP).  Declared in ParamMeta prefs, resolved per-mesh (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .layers import _maybe_bfp
from .params import ParamMeta

F32 = jnp.float32


def moe_meta(d: int, f: int, n_experts: int, dtype,
             fission: int = 1) -> Dict[str, ParamMeta]:
    """``fission`` r > 1 splits every expert's FFN into r slices along
    d_ff, giving E*r virtual experts of width f/r.  Mathematically
    identical (gate/up are elementwise per f-slice; down-proj partial sums
    add), but E*r can divide the "model" axis when E cannot — it turns
    grok's 8-expert expert-TP (layer-wise psum of activation-sized
    partials) into true EP (dispatch/combine only).  §Perf cell B."""
    E = n_experts * fission
    fs = f // fission
    assert f % fission == 0
    return {
        "router": ParamMeta((d, n_experts), dtype, init="scaled"),
        "wg": ParamMeta((E, d, fs), dtype, init="scaled",
                        prefs=((0, "model"), (2, "model"), (1, "data"))),
        "wu": ParamMeta((E, d, fs), dtype, init="scaled",
                        prefs=((0, "model"), (2, "model"), (1, "data"))),
        "wd": ParamMeta((E, fs, d), dtype, init="scaled",
                        prefs=((0, "model"), (1, "model"), (2, "data"))),
    }


def _ranks_by_sort(expert_of: jax.Array, n_experts: int) -> jax.Array:
    """rank of each element within its expert, via stable sort — O(T*k)."""
    n = expert_of.shape[0]
    order = jnp.argsort(expert_of, stable=True)
    sorted_e = expert_of[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[expert_of].add(1)
    starts = jnp.cumsum(counts) - counts               # exclusive cumsum
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_e]
    return jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)


def moe(p, x, *, mc=None, table=None, ctx=None):
    """x: (B, L, D).  table: n_experts, top_k, capacity_factor."""
    table = table or {}
    E = int(table["n_experts"])
    k = int(table["top_k"])
    cf = float(table.get("capacity_factor", 1.25))
    B, L, D = x.shape
    T = B * L
    xt = x.reshape(T, D)

    gates = jnp.einsum(
        "td,de->te", xt.astype(F32), p["router"].astype(F32)
    )                                                  # (T, E)
    probs = jax.nn.softmax(gates, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)               # (T, k)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    r = int(table.get("fission", 1))
    if r > 1:                # expert fission: slot per d_ff slice
        topi = (topi[..., None] * r
                + jnp.arange(r, dtype=topi.dtype)).reshape(T, k * r)
        topv = jnp.repeat(topv, r, axis=-1)            # same gate weight
        k = k * r
        E = E * r

    cap = max(int(T * k * cf) // E, 4)
    expert_of = topi.reshape(-1).astype(jnp.int32)     # (T*k,)
    pos = _ranks_by_sort(expert_of, E)                 # (T*k,)
    keep = pos < cap
    tok_of = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    slot = expert_of * cap + pos                       # in [0, E*cap)
    slot = jnp.where(keep, slot, E * cap)              # overflow cell

    # dispatch: gather tokens into (E, cap, D) expert buffers
    buf_tok = jnp.zeros((E * cap + 1,), jnp.int32).at[slot].set(tok_of)
    buf_valid = jnp.zeros((E * cap + 1,), jnp.bool_).at[slot].set(keep)
    xe = (
        jnp.take(xt, buf_tok[: E * cap], axis=0)
        * buf_valid[: E * cap, None].astype(x.dtype)
    ).reshape(E, cap, D)
    cstr = (ctx or {}).get("shard")
    if cstr is not None:
        xe = cstr(xe, "ecd")      # EP layout: experts over "model"

    # expert FFN (SwiGLU), batched over experts — the EP matmuls
    xq = _maybe_bfp(xe, table)
    g = jnp.einsum("ecd,edf->ecf", xq, p["wg"].astype(x.dtype),
                   preferred_element_type=F32)
    u = jnp.einsum("ecd,edf->ecf", xq, p["wu"].astype(x.dtype),
                   preferred_element_type=F32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", _maybe_bfp(h, table),
                    p["wd"].astype(x.dtype),
                    preferred_element_type=F32)        # (E, cap, D)

    # combine: each (token, slot) reads back its expert/cap cell
    ye_flat = ye.reshape(E * cap, D)
    back = jnp.take(ye_flat, jnp.minimum(slot, E * cap - 1), axis=0)
    back = back * keep[:, None].astype(back.dtype)
    back = back.reshape(T, k, D) * topv[..., None]
    out = jnp.sum(back, axis=1)
    return out.reshape(B, L, D).astype(x.dtype)


def aux_load_loss(p, x, *, table=None) -> jax.Array:
    """Switch-style load-balance auxiliary loss (importance * load)."""
    table = table or {}
    E = int(table["n_experts"])
    k = int(table["top_k"])
    B, L, D = x.shape
    xt = x.reshape(B * L, D)
    gates = jax.nn.softmax(
        jnp.einsum("td,de->te", xt.astype(F32), p["router"].astype(F32)),
        axis=-1,
    )
    _, topi = jax.lax.top_k(gates, k)
    load = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, E, dtype=F32), axis=1), axis=0
    )
    importance = jnp.mean(gates, axis=0)
    return jnp.sum(load * importance) * E
