"""LM model assembly — configs compile to microcode programs (paper C1),
executed by ``repro.core.interpreter.build_stream_fn`` over the datapath
module registry, scanned over layers.

One engine, ten architectures:
  dense   : [id.cache, norm, attn.add, id.cache, norm, glu_mlp.add] x L
  moe     : same with MOE in the MLP slot
  ssm     : [id.cache, norm, ssd.add] x L                  (mamba2)
  hybrid  : ssm blocks + a SHARED attention block every k layers —
            weight sharing is microcode address reuse: the shared block's
            words carry the same binding name at every call site (zamba2)
  audio   : encoder (non-causal) + decoder with cross-attn    (whisper)
  vlm     : vision-stub prefix embeddings + dense decoder   (internvl)

The transformer residual is literally the paper's Fig. 3 res_op
mechanism: IDENTITY(res=cache) ... BLOCK(res=add).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.interpreter import build_stream_fn
from repro.core.microcode import ExtOp, Microcode, ResOp

from . import layers as L
from . import moe as moe_mod
from . import ssm as ssm_mod
from .params import ParamMeta, abstract, is_meta, materialize, tree_map_meta

F32 = jnp.float32


# ---------------------------------------------------------------------------
# microcode emission helpers
# ---------------------------------------------------------------------------

def _word(op: ExtOp, *, res: ResOp = ResOp.NONE, tbl: int = 0,
          d_in: int = 0, d_out: int = 0, seq: int = 0) -> Microcode:
    return Microcode(
        layer_type=3,
        in_ch=min(d_in, (1 << 16) - 1),
        out_ch=min(d_out, (1 << 16) - 1),
        height=min(seq, (1 << 20) - 1),
        res_op=int(res),
        ext_opcode=int(op),
        ext_table_idx=tbl,
    )


@dataclasses.dataclass
class Stream:
    """A microcode segment + its tables and parameter bindings."""

    words: List[Microcode]
    tables: List[Dict[str, Any]]
    bindings: Dict[int, str]
    metas: Dict[str, Any]            # binding name -> ParamMeta tree

    def fn(self):
        return build_stream_fn(
            self.words, self.tables, L.registry(), self.bindings
        )


class StreamBuilder:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.words: List[Microcode] = []
        self.tables: List[Dict[str, Any]] = []
        self.bindings: Dict[int, str] = {}
        self.metas: Dict[str, Any] = {}

    def table(self, **kw) -> int:
        self.tables.append(kw)
        return len(self.tables)

    def emit(self, op: ExtOp, name: Optional[str] = None,
             meta: Optional[Any] = None, *, res: ResOp = ResOp.NONE,
             tbl: int = 0):
        idx = len(self.words)
        self.words.append(
            _word(op, res=res, tbl=tbl, d_in=self.cfg.d_model,
                  d_out=self.cfg.d_model)
        )
        if name is not None:
            self.bindings[idx] = name
            if meta is not None and name not in self.metas:
                self.metas[name] = meta

    def build(self) -> Stream:
        return Stream(self.words, self.tables, self.bindings, self.metas)


def _norm_parts(cfg: ArchConfig):
    if cfg.norm == "rmsnorm":
        return ExtOp.RMSNORM, L.rmsnorm_meta(cfg.d_model, cfg.param_dtype)
    return ExtOp.LAYERNORM, L.layernorm_meta(cfg.d_model, cfg.param_dtype)


def _common_tables(cfg: ArchConfig) -> Dict[str, Any]:
    t: Dict[str, Any] = {"compute_dtype": cfg.compute_dtype}
    if cfg.bfp_forward:
        t.update(bfp=True, bfp_block=cfg.bfp_block,
                 bfp_mantissa=cfg.bfp_mantissa)
    return t


def attn_block_stream(cfg: ArchConfig, *, causal=True, cross=False,
                      prefix="") -> Stream:
    """[id.cache, norm, attn.add] (+ optional cross-attn) + mlp sub-block."""
    b = StreamBuilder(cfg)
    nop, nmeta = _norm_parts(cfg)
    attn_tbl = b.table(
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
        rope_theta=cfg.rope_theta, causal=causal, rope=True,
        **_common_tables(cfg),
    )
    mlp_tbl = b.table(**_common_tables(cfg))
    amet = L.attention_meta(
        cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
        cfg.param_dtype, qkv_bias=cfg.qkv_bias,
    )
    b.emit(ExtOp.IDENTITY, res=ResOp.CACHE)
    b.emit(nop, f"{prefix}attn_norm", nmeta)
    b.emit(ExtOp.ATTN, f"{prefix}attn", amet, res=ResOp.ADD, tbl=attn_tbl)
    if cross:
        xmet = L.attention_meta(
            cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.param_dtype
        )
        b.emit(ExtOp.IDENTITY, res=ResOp.CACHE)
        b.emit(nop, f"{prefix}xattn_norm", nmeta)
        b.emit(ExtOp.CROSS_ATTN, f"{prefix}xattn", xmet, res=ResOp.ADD,
               tbl=attn_tbl)
    b.emit(ExtOp.IDENTITY, res=ResOp.CACHE)
    b.emit(nop, f"{prefix}mlp_norm", nmeta)
    if cfg.family == "moe" and not cross and not prefix:
        moe_tbl = b.table(
            n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, fission=cfg.moe_fission,
            **_common_tables(cfg),
        )
        b.emit(
            ExtOp.MOE, "moe",
            moe_mod.moe_meta(cfg.d_model, cfg.d_ff, cfg.n_experts,
                             cfg.param_dtype, fission=cfg.moe_fission),
            res=ResOp.ADD, tbl=moe_tbl,
        )
    elif cfg.act == "swiglu":
        b.emit(ExtOp.GLU_MLP, f"{prefix}mlp",
               L.glu_mlp_meta(cfg.d_model, cfg.d_ff, cfg.param_dtype),
               res=ResOp.ADD, tbl=mlp_tbl)
    else:
        b.emit(ExtOp.MLP, f"{prefix}mlp",
               L.mlp_meta(cfg.d_model, cfg.d_ff, cfg.param_dtype),
               res=ResOp.ADD, tbl=mlp_tbl)
    return b.build()


def ssm_block_stream(cfg: ArchConfig, prefix="") -> Stream:
    b = StreamBuilder(cfg)
    nop, nmeta = _norm_parts(cfg)
    tbl = b.table(
        d_inner=cfg.d_inner, n_heads=cfg.ssm_heads, n_groups=cfg.ssm_groups,
        d_state=cfg.ssm_state, headdim=cfg.ssm_headdim,
        conv_width=cfg.conv_width, chunk=cfg.ssm_chunk,
        **_common_tables(cfg),
    )
    met = ssm_mod.mamba2_meta(
        cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_groups,
        cfg.ssm_state, cfg.conv_width, cfg.param_dtype,
    )
    b.emit(ExtOp.IDENTITY, res=ResOp.CACHE)
    b.emit(nop, f"{prefix}ssm_norm", nmeta)
    b.emit(ExtOp.SSD, f"{prefix}ssm", met, res=ResOp.ADD, tbl=tbl)
    return b.build()


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

def _stack_meta(meta_tree, n: int):
    """Prepend a stacked layer dim to every ParamMeta (for lax.scan)."""
    def stack(m: ParamMeta) -> ParamMeta:
        prefs = tuple((d + 1, a) for d, a in m.prefs)
        return ParamMeta((n,) + m.shape, m.dtype, m.init, m.scale, prefs,
                         m.custom_init)
    return tree_map_meta(stack, meta_tree)


class LMModel:
    """Config-driven LM; all blocks execute through microcode streams."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        if cfg.family in ("dense", "moe", "vlm"):
            self.block = attn_block_stream(cfg)
            self.block_kind = "attn"
        elif cfg.family == "ssm":
            self.block = ssm_block_stream(cfg)
            self.block_kind = "ssm"
        elif cfg.family == "hybrid":
            self.block = ssm_block_stream(cfg)
            self.shared = attn_block_stream(cfg, prefix="shared_")
            self.block_kind = "hybrid"
        elif cfg.family == "audio":
            self.block = attn_block_stream(cfg, cross=True)
            self.enc_block = attn_block_stream(cfg, causal=False,
                                               prefix="enc_")
            self.block_kind = "encdec"
        else:
            raise ValueError(cfg.family)
        nop, nmeta = _norm_parts(cfg)
        self._final_norm_op = nop
        self._final_norm_meta = nmeta
        self._head_tbl = _common_tables(cfg)

    # -- parameter metadata -------------------------------------------------
    def param_meta(self) -> Dict[str, Any]:
        cfg = self.cfg
        p: Dict[str, Any] = {
            "embed": L.embed_meta(cfg.vocab, cfg.d_model, cfg.param_dtype),
            "final_norm": self._final_norm_meta,
        }
        if not cfg.tie_embeddings:
            p["head"] = L.lm_head_meta(cfg.d_model, cfg.vocab,
                                       cfg.param_dtype)
        if self.block_kind == "hybrid":
            n_groups = cfg.n_layers // cfg.attn_every
            p["layers"] = _stack_meta(self.block.metas, cfg.n_layers)
            p["shared_attn"] = self.shared.metas          # ONE copy, reused
        elif self.block_kind == "encdec":
            p["layers"] = _stack_meta(self.block.metas, cfg.n_layers)
            p["enc_layers"] = _stack_meta(self.enc_block.metas,
                                          cfg.encoder_layers)
        else:
            p["layers"] = _stack_meta(self.block.metas, cfg.n_layers)
        return p

    def abstract_params(self):
        return abstract(self.param_meta())

    def init_params(self, key):
        return materialize(self.param_meta(), key)

    # -- caches --------------------------------------------------------------
    def cache_meta(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        quant = cfg.kv_cache_dtype == "int8"
        kvdt = jnp.int8 if quant else dt

        def kv():
            m = {
                "k": ParamMeta((batch, max_len, cfg.n_kv_heads, cfg.hd),
                               kvdt, init="zeros",
                               prefs=((0, ("pod", "data")), (1, "model"))),
                "v": ParamMeta((batch, max_len, cfg.n_kv_heads, cfg.hd),
                               kvdt, init="zeros",
                               prefs=((0, ("pod", "data")), (1, "model"))),
            }
            if quant:   # per-vector scales (paper C2 on the KV stream)
                for s in ("k_scale", "v_scale"):
                    m[s] = ParamMeta(
                        (batch, max_len, cfg.n_kv_heads), jnp.float16,
                        init="zeros",
                        prefs=((0, ("pod", "data")), (1, "model")),
                    )
            return m
        d_conv = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        ssm = lambda: {
            "conv": ParamMeta((batch, cfg.conv_width - 1, d_conv), dt,
                              init="zeros", prefs=((0, ("pod", "data")),)),
            "ssm": ParamMeta(
                (batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                F32, init="zeros",
                prefs=((0, ("pod", "data")), (1, "model"))),
        }
        if self.block_kind == "attn":
            return {"layers": _stack_meta(kv(), cfg.n_layers)}
        if self.block_kind == "ssm":
            return {"layers": _stack_meta(ssm(), cfg.n_layers)}
        if self.block_kind == "hybrid":
            n_sites = cfg.n_layers // cfg.attn_every
            return {
                "layers": _stack_meta(ssm(), cfg.n_layers),
                "shared_attn": _stack_meta(kv(), n_sites),
            }
        if self.block_kind == "encdec":
            return {
                "layers": _stack_meta(kv(), cfg.n_layers),
                "memory": ParamMeta(
                    (batch, cfg.frontend_len, cfg.d_model), dt, init="zeros",
                    prefs=((0, ("pod", "data")),)),
            }
        raise ValueError(self.block_kind)

    def init_cache(self, batch: int, max_len: int):
        return materialize(self.cache_meta(batch, max_len), jax.random.PRNGKey(0))

    # -- forward -------------------------------------------------------------
    def _embed(self, params, tokens):
        tbl = {"compute_dtype": self.cfg.compute_dtype}
        return L.embed(params["embed"], tokens, table=tbl)

    def _head(self, params, x):
        from repro.core import bfp as bfp_lib

        xn = (L.rmsnorm if self.cfg.norm == "rmsnorm" else L.layernorm)(
            params["final_norm"], x
        )
        if self.cfg.tie_embeddings:
            return jnp.einsum(
                "bld,vd->blv", xn.astype(F32),
                params["embed"]["table"].astype(F32),
            )
        hp = params["head"]
        if isinstance(hp.get("w"), bfp_lib.BFPTensor):   # BFP weight storage
            hp = {"w": bfp_lib.dequantize(hp["w"]).astype(x.dtype)}
        return L.lm_head(hp, xn, table=self._head_tbl)

    def _scan_blocks(self, stream: Stream, stacked_params, x, ctx,
                     stacked_cache=None, remat: bool = False):
        fn = stream.fn()

        def body(carry, xs):
            h, cache_len = carry
            lp, lc = xs
            step_ctx = dict(ctx)
            step_ctx["cache_len"] = cache_len
            if lc is not None:
                step_ctx["cache"] = lc
            if step_ctx.get("shard") is not None and h.ndim == 3:
                # the remat-saved residual stream; seq-sharded under the
                # Megatron-SP option (runtime.sharding)
                h = step_ctx["shard"](h, "boundary")
            y, step_ctx = fn(lp, h, step_ctx)
            new_lc = step_ctx.get("cache") if lc is not None else None
            return (y, cache_len), new_lc

        if remat:
            body = jax.checkpoint(body)
        n = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
        xs = (stacked_params, stacked_cache)
        # scan_unroll=large is the dry-run ANALYSIS mode: XLA cost_analysis
        # counts while-loop bodies once, so the roofline pass compiles an
        # unrolled variant to get true per-step FLOPs/bytes/collectives.
        unroll = min(int(ctx.get("scan_unroll", 1)), n)
        (y, _), new_cache = jax.lax.scan(body, (x, ctx.get("cache_len", 0)),
                                         xs, length=n, unroll=unroll)
        return y, new_cache

    # full-sequence forward (train / prefill)
    def forward(self, params, tokens, *, prefix_embed=None, positions=None,
                mode="train", cache_out: bool = False, max_len: int = 0,
                ctx_extra: Optional[Dict[str, Any]] = None):
        cfg = self.cfg
        x = self._embed(params, tokens)
        if cfg.family == "vlm" and prefix_embed is not None:
            x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
        B, Lseq, _ = x.shape
        if positions is None:
            positions = jnp.arange(Lseq, dtype=jnp.int32)[None, :]
        ctx: Dict[str, Any] = {
            "positions": positions, "mode": "full",
            "interpret": True,
            "compute_dtype": jnp.dtype(cfg.compute_dtype),
        }
        if ctx_extra:
            ctx.update(ctx_extra)
        if ctx.get("shard") is not None:
            x = ctx["shard"](x, "bld")
        remat = cfg.remat and mode == "train"

        cache = None
        if cache_out:
            cache = self.init_cache(B, max_len or Lseq)

        if self.block_kind == "encdec":
            enc = prefix_embed.astype(x.dtype)
            enc_ctx = {
                "positions": jnp.arange(enc.shape[1])[None, :],
                "mode": "full",
            }
            enc, _ = self._scan_blocks(self.enc_block, params["enc_layers"],
                                       enc, enc_ctx, remat=remat)
            ctx["memory"] = enc
            if cache_out:
                cache["memory"] = enc
        if self.block_kind == "hybrid":
            y = x
            n_sites = cfg.n_layers // cfg.attn_every
            per = cfg.attn_every
            lp = jax.tree_util.tree_map(
                lambda a: a.reshape((n_sites, per) + a.shape[1:]),
                params["layers"],
            )
            shared_fn = self.shared.fn()
            sc_list = []
            for g in range(n_sites):
                gp = jax.tree_util.tree_map(lambda a: a[g], lp)
                gc = None
                if cache_out:
                    gc = jax.tree_util.tree_map(
                        lambda a: a[g * per:(g + 1) * per], cache["layers"]
                    )
                y, gc_new = self._scan_blocks(self.block, gp, y, ctx, gc,
                                              remat=remat)
                sctx = dict(ctx)
                if cache_out:
                    sctx["cache"] = jax.tree_util.tree_map(
                        lambda a: a[g], cache["shared_attn"]
                    )
                    sctx["cache_len"] = 0
                y, sctx = shared_fn(params["shared_attn"], y, sctx)
                if cache_out:
                    sc_list.append(sctx["cache"])
                    cache["layers"] = jax.tree_util.tree_map(
                        lambda full, part: jax.lax.dynamic_update_slice_in_dim(
                            full, part, g * per, axis=0
                        ),
                        cache["layers"], gc_new,
                    )
            if cache_out and sc_list:
                cache["shared_attn"] = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *sc_list
                )
        else:
            lc = cache["layers"] if cache_out else None
            if cache_out:
                ctx["cache_len"] = 0
            y, new_cache = self._scan_blocks(
                self.block, params["layers"], x, ctx, lc, remat=remat
            )
            if cache_out:
                cache["layers"] = new_cache
        logits = self._head(params, y)
        if cfg.family == "vlm" and prefix_embed is not None:
            logits = logits[:, prefix_embed.shape[1]:, :]
        if cache_out:
            return logits, cache
        return logits

    # single-token decode against a cache
    def decode_step(self, params, tokens, cache, cache_len,
                    ctx_extra: Optional[Dict[str, Any]] = None):
        cfg = self.cfg
        x = self._embed(params, tokens)             # (B, 1, D)
        positions = jnp.full((x.shape[0], 1), cache_len, jnp.int32)
        ctx: Dict[str, Any] = {
            "positions": positions, "mode": "decode",
            "cache_len": cache_len,
            "compute_dtype": jnp.dtype(cfg.compute_dtype),
        }
        if ctx_extra:
            ctx.update(ctx_extra)
        if self.block_kind == "encdec":
            ctx["memory"] = cache["memory"]
        if self.block_kind == "hybrid":
            n_sites = cfg.n_layers // cfg.attn_every
            per = cfg.attn_every
            lp = jax.tree_util.tree_map(
                lambda a: a.reshape((n_sites, per) + a.shape[1:]),
                params["layers"],
            )
            lc = jax.tree_util.tree_map(
                lambda a: a.reshape((n_sites, per) + a.shape[1:]),
                cache["layers"],
            )
            shared_fn = self.shared.fn()
            y = x
            new_lc = []
            new_sc = []
            for g in range(n_sites):
                gp = jax.tree_util.tree_map(lambda a: a[g], lp)
                gc = jax.tree_util.tree_map(lambda a: a[g], lc)
                y, gc2 = self._scan_blocks(self.block, gp, y, ctx, gc)
                new_lc.append(gc2)
                sctx = dict(ctx)
                sctx["cache"] = jax.tree_util.tree_map(
                    lambda a: a[g], cache["shared_attn"]
                )
                y, sctx = shared_fn(params["shared_attn"], y, sctx)
                new_sc.append(sctx["cache"])
            cache = dict(cache)
            cache["layers"] = jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate([a for a in xs], 0), *new_lc
            )
            cache["shared_attn"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_sc
            )
        else:
            y, new_cache = self._scan_blocks(
                self.block, params["layers"], x, ctx, cache["layers"]
            )
            cache = dict(cache)
            cache["layers"] = new_cache
        logits = self._head(params, y)
        return logits, cache


# ---------------------------------------------------------------------------
# losses / step functions
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over valid (label >= 0) positions; logits (B, L, V) f32."""
    valid = (labels >= 0).astype(F32)
    lab = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
    ll = jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    model = LMModel(cfg)
    tree = model.param_meta()
    total = 0
    for path, m in jax.tree_util.tree_leaves_with_path(
        tree, is_leaf=is_meta
    ):
        n = int(np.prod(m.shape))
        if active_only and cfg.n_experts:
            keys = jax.tree_util.keystr(path)
            if any(k in keys for k in ("wg", "wu", "wd")) and "moe" in keys:
                n = n * cfg.top_k // cfg.n_experts
        total += n
    return total
