"""LM datapath modules — the transformer analogue of the paper's fixed
compute units (conv / pool / upsample), dispatched by microcode ExtOps.

Every module is ``fn(params, x, *, mc, table, ctx) -> y``:
  * hyperparameters come from the microcode side-table (paper C1: models
    are configured, not coded),
  * ``ctx`` carries step state (positions, KV cache, prefix memory),
  * all matmuls run ``preferred_element_type=f32`` — the §IV.C wide-
    accumulator discipline — with optional BFP input quantization (C2).

Shapes are (B, L, D) throughout; decode is the L=1 case with a cache.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bfp as bfp_lib
from repro.core.microcode import ExtOp

from .params import ParamMeta

F32 = jnp.float32


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _maybe_bfp(x: jax.Array, table: Dict[str, Any], axis: int = -1):
    """Paper C2: quantize matmul inputs to shared-exponent blocks."""
    if table.get("bfp"):
        return bfp_lib.roundtrip(
            x.astype(F32),
            block_size=table.get("bfp_block", 32),
            mantissa_bits=table.get("bfp_mantissa", 10),
            axis=axis,
        )
    return x


def dot(x, w, table: Optional[Dict[str, Any]] = None):
    """x @ w with f32 accumulation (+ optional BFP input quantization)."""
    table = table or {}
    x = _maybe_bfp(x, table)
    return jax.lax.dot_general(
        x, w.astype(x.dtype),
        ((((x.ndim - 1),), (0,)), ((), ())),
        preferred_element_type=F32,
    )


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x: (B, L, H, hd), positions: (B, L)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=F32) / half
    )                                            # (half,)
    ang = positions.astype(F32)[..., None] * freqs   # (B, L, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_meta(d: int, dtype) -> Dict[str, ParamMeta]:
    return {"scale": ParamMeta((d,), dtype, init="ones")}


def rmsnorm(p, x, *, mc=None, table=None, ctx=None):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + 1e-6)
    return (y * p["scale"].astype(F32)).astype(x.dtype)


def layernorm_meta(d: int, dtype) -> Dict[str, ParamMeta]:
    return {
        "scale": ParamMeta((d,), dtype, init="ones"),
        "bias": ParamMeta((d,), dtype, init="zeros"),
    }


def layernorm(p, x, *, mc=None, table=None, ctx=None):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    return (y * p["scale"].astype(F32) + p["bias"].astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def embed_meta(vocab: int, d: int, dtype) -> Dict[str, ParamMeta]:
    return {
        "table": ParamMeta(
            (vocab, d), dtype, init="normal", scale=0.02,
            prefs=((0, "model"), (1, "data")),
        )
    }


def embed(p, tokens, *, mc=None, table=None, ctx=None):
    dtype = jnp.dtype(table.get("compute_dtype", "bfloat16")) if table else jnp.bfloat16
    return jnp.take(p["table"], tokens, axis=0).astype(dtype)


def lm_head_meta(d: int, vocab: int, dtype) -> Dict[str, ParamMeta]:
    return {
        "w": ParamMeta(
            (d, vocab), dtype, init="scaled",
            prefs=((1, "model"), (0, "data")),
        )
    }


def lm_head(p, x, *, mc=None, table=None, ctx=None):
    return dot(x, p["w"], table)       # f32 logits


# ---------------------------------------------------------------------------
# attention (GQA + RoPE; self or cross; full / decode-with-cache)
# ---------------------------------------------------------------------------

def attention_meta(
    d_model: int, n_heads: int, n_kv: int, head_dim: int, dtype,
    qkv_bias: bool = False,
) -> Dict[str, ParamMeta]:
    m = {
        "wq": ParamMeta(
            (d_model, n_heads, head_dim), dtype, init="scaled",
            prefs=((1, "model"), (0, "data")),
        ),
        "wk": ParamMeta(
            (d_model, n_kv, head_dim), dtype, init="scaled",
            prefs=((1, "model"), (0, "data")),
        ),
        "wv": ParamMeta(
            (d_model, n_kv, head_dim), dtype, init="scaled",
            prefs=((1, "model"), (0, "data")),
        ),
        "wo": ParamMeta(
            (n_heads, head_dim, d_model), dtype, init="scaled",
            prefs=((0, "model"), (2, "data")),
        ),
    }
    if qkv_bias:
        m["bq"] = ParamMeta((n_heads, head_dim), dtype, init="zeros")
        m["bk"] = ParamMeta((n_kv, head_dim), dtype, init="zeros")
        m["bv"] = ParamMeta((n_kv, head_dim), dtype, init="zeros")
    return m


def _proj_qkv(p, x, table):
    q = jnp.einsum(
        "bld,dhk->blhk", _maybe_bfp(x, table), p["wq"].astype(x.dtype),
        preferred_element_type=F32,
    )
    k = jnp.einsum(
        "bld,dhk->blhk", _maybe_bfp(x, table), p["wk"].astype(x.dtype),
        preferred_element_type=F32,
    )
    v = jnp.einsum(
        "bld,dhk->blhk", _maybe_bfp(x, table), p["wv"].astype(x.dtype),
        preferred_element_type=F32,
    )
    if "bq" in p:
        q = q + p["bq"].astype(F32)
        k = k + p["bk"].astype(F32)
        v = v + p["bv"].astype(F32)
    return q, k, v


def _sdpa_full(q, k, v, *, causal: bool, ctx) -> jax.Array:
    """(B, L, H, hd) x (B, S, K, hd) dense attention with GQA broadcast.

    Two memory disciplines (found via the dry-run §Perf loop):
      * KV heads are repeated to H (not q reshaped to (K, g)) so the head
        dim stays shardable over "model" — the (K, g) reshape silently
        replicated the score tensor across the TP axis;
      * queries are processed in chunks via lax.scan (flash-lite): only
        one (B, H, chunk, S) score block is ever live, bounding the
        activation peak at any sequence length.
    """
    B, L, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    g = H // K
    kf = jnp.repeat(k, g, axis=2) if g > 1 else k     # (B, S, H, hd)
    vf = jnp.repeat(v, g, axis=2) if g > 1 else v
    cstr = (ctx or {}).get("shard")
    if cstr is not None:
        q = cstr(q, "blhd")
        kf = cstr(kf, "blhd")
        vf = cstr(vf, "blhd")
    scale = hd ** -0.5
    chunk = int((ctx or {}).get("q_chunk", 1024))
    chunk = min(chunk, L)

    def attend(qc, row0):
        s = jnp.einsum("blhd,bshd->bhls", qc, kf,
                       preferred_element_type=F32) * scale
        if causal:
            rows = row0 + jnp.arange(qc.shape[1])[:, None]
            cols = jnp.arange(S)[None, :]
            s = jnp.where((cols <= rows + (S - L))[None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1).astype(qc.dtype)
        return jnp.einsum("bhls,bshd->blhd", pr, vf,
                          preferred_element_type=F32).astype(qc.dtype)

    if L <= chunk:
        return attend(q, 0)
    pad = (-L) % chunk
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    nch = qp.shape[1] // chunk
    qs = jnp.moveaxis(qp.reshape(B, nch, chunk, H, hd), 1, 0)

    def body(_, inp):
        qc, i = inp
        return None, attend(qc, i * chunk)

    # analysis mode (scan_unroll > 1) unrolls so cost_analysis sees every
    # chunk (while bodies are otherwise counted once)
    unroll = nch if int((ctx or {}).get("scan_unroll", 1)) > 1 else 1
    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(nch)), unroll=unroll)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nch * chunk, H, hd)
    return out[:, :L]


def _kv_write(cache, k, v, pos):
    """Write K/V at pos; quantizes to int8 + per-vector scale when the
    cache is int8 (paper C2 on the *decode-dominant* stream: the KV cache
    — the §Perf cell-C finding that weights are not the decode bottleneck
    at high sharding degrees)."""
    if cache["k"].dtype == jnp.int8:
        def q(t):
            s = jnp.max(jnp.abs(t.astype(F32)), -1, keepdims=True) / 127.0
            s = jnp.maximum(s, 1e-8)
            return jnp.round(t.astype(F32) / s).astype(jnp.int8), \
                s[..., 0].astype(jnp.float16)
        kq, ks = q(k)
        vq, vs = q(v)
        return {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, pos, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, pos, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(
                cache["k_scale"], ks, (0, pos, 0)),
            "v_scale": jax.lax.dynamic_update_slice(
                cache["v_scale"], vs, (0, pos, 0)),
        }
    return {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0)),
    }


def _kv_read(cache, dtype):
    if cache["k"].dtype == jnp.int8:
        k = cache["k"].astype(F32) * cache["k_scale"].astype(F32)[..., None]
        v = cache["v"].astype(F32) * cache["v_scale"].astype(F32)[..., None]
        return k.astype(dtype), v.astype(dtype)
    return cache["k"].astype(dtype), cache["v"].astype(dtype)


def attention(p, x, *, mc=None, table=None, ctx=None):
    """Self-attention.  table: n_heads, n_kv, head_dim, rope_theta, causal.
    ctx: positions (B, L); mode 'full' | 'decode'; cache {k, v} (B, S, K, hd);
    cache_len scalar; use_flash bool."""
    table = table or {}
    ctx = ctx or {}
    theta = table.get("rope_theta", 10000.0)
    q, k, v = _proj_qkv(p, x, table)
    positions = ctx.get("positions")
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    if table.get("rope", True):
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    q = q.astype(x.dtype)
    k = k.astype(x.dtype)
    v = v.astype(x.dtype)

    mode = ctx.get("mode", "full")
    if mode == "decode":
        cache = ctx["cache"]
        pos = ctx["cache_len"]                    # scalar int32
        ctx["cache"] = _kv_write(cache, k, v, pos)
        kc, vc = _kv_read(ctx["cache"], q.dtype)
        from repro.kernels.flash_attention.ops import decode_attention

        o = decode_attention(
            jnp.swapaxes(q, 1, 2),                # (B, H, 1, hd)
            jnp.swapaxes(kc, 1, 2),
            jnp.swapaxes(vc, 1, 2),
            pos + 1,
        )
        o = jnp.swapaxes(o, 1, 2)                 # (B, 1, H, hd)
    else:
        if "cache" in ctx:
            # prefill: write the full-sequence K/V into the cache so decode
            # can continue from here
            ctx["cache"] = _kv_write(ctx["cache"], k, v,
                                     ctx.get("cache_len", 0))
        if ctx.get("use_flash"):
            from repro.kernels.flash_attention.ops import flash_attention

            o = flash_attention(
                jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                jnp.swapaxes(v, 1, 2),
                causal=table.get("causal", True),
                interpret=bool(ctx.get("interpret", True)),
            )
            o = jnp.swapaxes(o, 1, 2)
        else:
            o = _sdpa_full(q, k, v, causal=table.get("causal", True), ctx=ctx)
    out = jnp.einsum(
        "blhd,hdm->blm", o.astype(x.dtype), p["wo"].astype(x.dtype),
        preferred_element_type=F32,
    ).astype(x.dtype)
    if ctx.get("shard") is not None:
        out = ctx["shard"](out, "bld")
    return out


def cross_attention(p, x, *, mc=None, table=None, ctx=None):
    """Cross-attention against ctx['memory'] (B, S, D_mem->proj'd)."""
    table = dict(table or {})
    table["rope"] = False
    table["causal"] = False
    ctx = ctx or {}
    mem = ctx["memory"]
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"].astype(x.dtype),
                   preferred_element_type=F32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", mem.astype(x.dtype),
                   p["wk"].astype(x.dtype),
                   preferred_element_type=F32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", mem.astype(x.dtype),
                   p["wv"].astype(x.dtype),
                   preferred_element_type=F32).astype(x.dtype)
    o = _sdpa_full(q, k, v, causal=False, ctx=ctx)
    return jnp.einsum(
        "blhd,hdm->blm", o.astype(x.dtype), p["wo"].astype(x.dtype),
        preferred_element_type=F32,
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def glu_mlp_meta(d: int, f: int, dtype) -> Dict[str, ParamMeta]:
    return {
        "wg": ParamMeta((d, f), dtype, init="scaled",
                        prefs=((1, "model"), (0, "data"))),
        "wu": ParamMeta((d, f), dtype, init="scaled",
                        prefs=((1, "model"), (0, "data"))),
        "wd": ParamMeta((f, d), dtype, init="scaled",
                        prefs=((0, "model"), (1, "data"))),
    }


def glu_mlp(p, x, *, mc=None, table=None, ctx=None):
    g = dot(x, p["wg"], table)
    u = dot(x, p["wu"], table)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return dot(h, p["wd"], table).astype(x.dtype)


def mlp_meta(d: int, f: int, dtype) -> Dict[str, ParamMeta]:
    return {
        "w1": ParamMeta((d, f), dtype, init="scaled",
                        prefs=((1, "model"), (0, "data"))),
        "b1": ParamMeta((f,), dtype, init="zeros"),
        "w2": ParamMeta((f, d), dtype, init="scaled",
                        prefs=((0, "model"), (1, "data"))),
        "b2": ParamMeta((d,), dtype, init="zeros"),
    }


def mlp(p, x, *, mc=None, table=None, ctx=None):
    h = jax.nn.gelu(dot(x, p["w1"], table) + p["b1"].astype(F32))
    return (
        dot(h.astype(x.dtype), p["w2"], table) + p["b2"].astype(F32)
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# registry — the interpreter's dispatch table
# ---------------------------------------------------------------------------

def registry() -> Dict[ExtOp, Any]:
    from . import moe as moe_mod
    from . import ssm as ssm_mod

    return {
        ExtOp.RMSNORM: rmsnorm,
        ExtOp.LAYERNORM: layernorm,
        ExtOp.ATTN: attention,
        ExtOp.CROSS_ATTN: cross_attention,
        ExtOp.GLU_MLP: glu_mlp,
        ExtOp.MLP: mlp,
        ExtOp.MOE: moe_mod.moe,
        ExtOp.SSD: ssm_mod.mamba2_block,
        ExtOp.EMBED: embed,
        ExtOp.LM_HEAD: lm_head,
    }
