"""Parameter metadata system — shape/dtype/init/sharding declared together.

Big-model hygiene: modules declare :class:`ParamMeta` trees; the dry-run
lowers against ``abstract()`` ShapeDtypeStructs (no 1T-parameter
allocation ever happens on the host), smoke tests ``materialize()`` the
reduced configs, and the launcher derives NamedShardings from the same
tree so init/restore/train all agree on layout.

Sharding is declared as *axis preferences* and resolved against the mesh
with divisibility checks (``best_spec``): e.g. a weight (d_model, d_ff)
prefers d_ff on "model" (TP) and d_model on "data" (FSDP); if a dim does
not divide the mesh axis, the preference is dropped rather than padding
silently — the roofline table then shows the replication cost honestly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    init: str = "normal"          # normal|zeros|ones|scaled|custom
    scale: float = 0.02
    # axis preferences: tuple of (dim, mesh_axis or tuple of axes) tried in
    # order; each mesh axis used at most once per param.
    prefs: Tuple[Tuple[int, Any], ...] = ()
    custom_init: Optional[Callable[[jax.Array], jax.Array]] = None

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def tree_map_meta(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_meta)


def abstract(tree):
    return tree_map_meta(lambda m: m.abstract(), tree)


def materialize(tree, key: jax.Array):
    """Instantiate real arrays (reduced/smoke configs only)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_meta)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for m, k in zip(leaves, keys):
        if m.init == "zeros":
            v = jnp.zeros(m.shape, m.dtype)
        elif m.init == "ones":
            v = jnp.ones(m.shape, m.dtype)
        elif m.init == "normal":
            v = (jax.random.normal(k, m.shape, jnp.float32) * m.scale).astype(m.dtype)
        elif m.init == "scaled":  # fan-in scaled
            fan_in = m.shape[-2] if len(m.shape) >= 2 else m.shape[-1]
            v = (
                jax.random.normal(k, m.shape, jnp.float32)
                * (1.0 / math.sqrt(max(fan_in, 1)))
            ).astype(m.dtype)
        elif m.init == "custom":
            v = m.custom_init(k).astype(m.dtype)
        else:
            raise ValueError(m.init)
        out.append(v)
    return jax.tree_util.tree_unflatten(treedef, out)


def best_spec(meta: ParamMeta, mesh_shape: Dict[str, int]) -> P:
    """Resolve axis preferences to a valid PartitionSpec for this mesh."""
    assign: Dict[int, Any] = {}
    used: set = set()
    for dim, axes in meta.prefs:
        if dim in assign or dim >= len(meta.shape):
            continue
        axes_t = axes if isinstance(axes, tuple) else (axes,)
        # try the full tuple first, then single axes
        candidates = [axes_t] + [(a,) for a in axes_t if len(axes_t) > 1]
        for cand in candidates:
            if any(a in used or a not in mesh_shape for a in cand):
                continue
            total = int(np.prod([mesh_shape[a] for a in cand]))
            if meta.shape[dim] % total == 0 and meta.shape[dim] >= total:
                assign[dim] = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
    if not assign:
        return P()
    ndim = max(assign) + 1
    return P(*[assign.get(d) for d in range(ndim)])


def shardings(tree, mesh: Mesh):
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return tree_map_meta(
        lambda m: NamedSharding(mesh, best_spec(m, shape)), tree
    )


def specs(tree, mesh: Mesh):
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return tree_map_meta(lambda m: best_spec(m, shape), tree)


# ---------------------------------------------------------------------------
# BFP weight storage (paper C2 as a serving-bandwidth feature): big matmul
# weights live in HBM as int8 shared-exponent mantissas (+1 exponent / 32
# values) and are dequantized in VMEM at use.  ~2x less HBM traffic and
# ~2x smaller FSDP all-gathers than bf16 — measured in EXPERIMENTS §Perf.
# ---------------------------------------------------------------------------

BFP_WEIGHT_BITS = 7
BFP_WEIGHT_BLOCK = 32
_BFP_MIN_SIZE = 1 << 20       # only quantize big matmul weights


def _bfp_eligible(path, meta: ParamMeta) -> bool:
    keys = jax.tree_util.keystr(path)
    if "embed" in keys:        # gather path — dequant-after-gather only
        return False
    return len(meta.shape) >= 2 and int(np.prod(meta.shape)) >= _BFP_MIN_SIZE


def bfp_abstract(tree):
    """Abstract params with eligible leaves replaced by BFPTensor SDS."""
    from repro.core import bfp as bfp_lib

    def one(path, m: ParamMeta):
        if not _bfp_eligible(path, m):
            return m.abstract()
        nb = -(-m.shape[-1] // BFP_WEIGHT_BLOCK)
        return bfp_lib.BFPTensor(
            jax.ShapeDtypeStruct(m.shape, jnp.int8),
            jax.ShapeDtypeStruct(m.shape[:-1] + (nb,), jnp.int32),
            BFP_WEIGHT_BITS, BFP_WEIGHT_BLOCK, -1,
        )

    return jax.tree_util.tree_map_with_path(one, tree, is_leaf=is_meta)


def bfp_shardings(tree, mesh: Mesh):
    """Shardings matching bfp_abstract: mantissa inherits the param spec;
    the exponent keeps axes that still divide its blocked last dim."""
    import dataclasses as _dc

    from repro.core import bfp as bfp_lib

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, m: ParamMeta):
        spec = best_spec(m, sizes)
        if not _bfp_eligible(path, m):
            return NamedSharding(mesh, spec)
        parts = list(spec) + [None] * (len(m.shape) - len(spec))
        eparts = list(parts)
        nb = -(-m.shape[-1] // BFP_WEIGHT_BLOCK)
        last = eparts[-1]
        if last is not None:
            ax = last if isinstance(last, tuple) else (last,)
            total = int(np.prod([sizes[a] for a in ax]))
            if nb % total != 0:
                eparts[-1] = None
        return bfp_lib.BFPTensor(
            NamedSharding(mesh, P(*parts)),
            NamedSharding(mesh, P(*eparts)),
            BFP_WEIGHT_BITS, BFP_WEIGHT_BLOCK, -1,
        )

    return jax.tree_util.tree_map_with_path(one, tree, is_leaf=is_meta)


def quantize_weights(params, meta_tree):
    """Materialized params -> BFP storage (the Fig. 4 weight-normalization
    branch, serving flavour)."""
    from repro.core import bfp as bfp_lib

    def one(path, m, p):
        if not _bfp_eligible(path, m):
            return p
        q = bfp_lib.quantize(
            p.astype(jnp.float32), block_size=BFP_WEIGHT_BLOCK,
            mantissa_bits=BFP_WEIGHT_BITS, axis=-1, rounding="nearest",
        )
        import dataclasses as _dc
        return _dc.replace(q, mantissa=q.mantissa.astype(jnp.int8))

    return jax.tree_util.tree_map_with_path(
        lambda path, m, p: one(path, m, p), meta_tree, params,
        is_leaf=is_meta,
    )


def count_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_meta)
    return sum(int(np.prod(m.shape)) for m in leaves)


def param_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_meta)
    return sum(
        int(np.prod(m.shape)) * jnp.dtype(m.dtype).itemsize for m in leaves
    )
