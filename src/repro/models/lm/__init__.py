"""LM model substrate: datapath modules (layers/moe/ssm) + microcode-driven
stacks (transformer) for the ten assigned architectures."""
from . import layers, moe, params, ssm, transformer
from .transformer import LMModel, cross_entropy

__all__ = [
    "layers", "moe", "params", "ssm", "transformer", "LMModel",
    "cross_entropy",
]
