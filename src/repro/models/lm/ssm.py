"""Mamba2 (SSD) datapath module — zamba2-2.7b and mamba2-370m.

Block layout follows arXiv:2405.21060:
    in_proj -> [z | x | B | C | dt]
    causal conv1d (width 4) over [x | B | C], silu
    dt = softplus(dt + dt_bias);  A = -exp(A_log)
    y  = SSD(x, dt, A, B, C, D)          (kernels/ssd_scan)
    y  = RMSNorm(y * silu(z)) -> out_proj

Decode carries (conv_state, ssm_state) in the cache — O(1) per token,
which is what makes the long_500k shape runnable for the SSM/hybrid archs
(DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ops import ssd_decode_step, ssd_scan

from .layers import _maybe_bfp, rmsnorm
from .params import ParamMeta

F32 = jnp.float32


def mamba2_meta(
    d_model: int, d_inner: int, n_heads: int, n_groups: int, d_state: int,
    conv_width: int, dtype,
) -> Dict[str, ParamMeta]:
    d_proj = 2 * d_inner + 2 * n_groups * d_state + n_heads
    d_conv = d_inner + 2 * n_groups * d_state
    return {
        "in_proj": ParamMeta(
            (d_model, d_proj), dtype, init="scaled",
            prefs=((1, "model"), (0, "data")),
        ),
        "conv_w": ParamMeta((conv_width, d_conv), dtype, init="scaled"),
        "conv_b": ParamMeta((d_conv,), dtype, init="zeros"),
        "dt_bias": ParamMeta((n_heads,), F32, init="zeros"),
        "A_log": ParamMeta((n_heads,), F32, init="zeros"),
        "D": ParamMeta((n_heads,), F32, init="ones"),
        "norm_scale": ParamMeta((d_inner,), dtype, init="ones"),
        "out_proj": ParamMeta(
            (d_inner, d_model), dtype, init="scaled",
            prefs=((0, "model"), (1, "data")),
        ),
    }


def _split_proj(zxbcdt, d_inner, n_groups, d_state, n_heads):
    gs = n_groups * d_state
    z = zxbcdt[..., :d_inner]
    xc = zxbcdt[..., d_inner: 2 * d_inner + 2 * gs]   # conv'd chunk [x|B|C]
    dt = zxbcdt[..., 2 * d_inner + 2 * gs:]
    return z, xc, dt


def _causal_conv(xc, w, b):
    """Depthwise causal conv1d; xc: (B, L, C), w: (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xc, dtype=F32)
    for i in range(W):
        out = out + pad[:, i: i + xc.shape[1], :].astype(F32) * w[i].astype(F32)
    return jax.nn.silu(out + b.astype(F32)).astype(xc.dtype)


def mamba2_block(p, x, *, mc=None, table=None, ctx=None):
    """x: (B, L, D).  table: d_inner, n_heads, n_groups, d_state, headdim,
    conv_width, chunk.  ctx mode 'full' | 'decode' (cache: conv_state
    (B, W-1, d_conv), ssm_state (B, H, P, N))."""
    table = table or {}
    ctx = ctx or {}
    d_inner = int(table["d_inner"])
    H = int(table["n_heads"])
    G = int(table["n_groups"])
    N = int(table["d_state"])
    P = int(table["headdim"])
    Wd = int(table.get("conv_width", 4))
    chunk = int(table.get("chunk", 128))
    Bsz, L, Dm = x.shape
    gs = G * N

    zxbcdt = jnp.einsum(
        "bld,dp->blp", _maybe_bfp(x, table), p["in_proj"].astype(x.dtype),
        preferred_element_type=F32,
    ).astype(x.dtype)
    z, xc, dt_raw = _split_proj(zxbcdt, d_inner, G, N, H)

    mode = ctx.get("mode", "full")
    if mode == "decode":
        # conv state: (B, W-1, d_conv) of previous raw xc inputs
        conv_state = ctx["cache"]["conv"]
        hist = jnp.concatenate([conv_state, xc], axis=1)  # (B, W, d_conv)
        ctx_new_conv = hist[:, 1:, :]
        acc = jnp.zeros(xc.shape, F32)
        for i in range(Wd):
            acc = acc + hist[:, i: i + 1, :].astype(F32) * p["conv_w"][i].astype(F32)
        xc = jax.nn.silu(acc + p["conv_b"].astype(F32)).astype(x.dtype)
    else:
        xc = _causal_conv(xc, p["conv_w"], p["conv_b"])

    xs = xc[..., :d_inner]
    Bm = xc[..., d_inner: d_inner + gs].reshape(Bsz, L, G, N)
    Cm = xc[..., d_inner + gs:].reshape(Bsz, L, G, N)
    dt = jax.nn.softplus(dt_raw.astype(F32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(F32))
    xh = xs.reshape(Bsz, L, H, P)

    if mode == "decode":
        h = ctx["cache"]["ssm"]                        # (B, H, P, N)
        h_new, y = ssd_decode_step(
            h, xh[:, 0].astype(F32), dt[:, 0], A,
            Bm[:, 0].astype(F32), Cm[:, 0].astype(F32), p["D"],
        )
        ctx["cache"] = {"conv": ctx_new_conv, "ssm": h_new}
        y = y[:, None, :, :]                           # (B, 1, H, P)
    else:
        want_state = "cache" in ctx
        if ctx.get("use_kernel") and not want_state:
            y = ssd_scan(
                xh, dt, A, Bm, Cm, p["D"],
                chunk=min(chunk, L),
                interpret=bool(ctx.get("interpret", True)),
            )
        else:
            y, h_last = _ssd_xla(
                xh, dt, A, Bm, Cm, p["D"], chunk=min(chunk, L),
                return_state=True,
            )
            if want_state:
                # prefill: stash conv tail (pre-activation inputs) + final
                # SSM state so decode can continue
                conv_tail = zxbcdt[..., d_inner: 2 * d_inner + 2 * gs][
                    :, L - (Wd - 1):, :
                ]
                ctx["cache"] = {
                    "conv": conv_tail.astype(ctx["cache"]["conv"].dtype),
                    "ssm": h_last,
                }

    y = y.reshape(Bsz, L, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(F32)).astype(x.dtype)
    y = rmsnorm({"scale": p["norm_scale"]}, y)
    return jnp.einsum(
        "bli,id->bld", _maybe_bfp(y, table), p["out_proj"].astype(x.dtype),
        preferred_element_type=F32,
    ).astype(x.dtype)


def _ssd_xla(x, dt, A, Bm, Cm, D, *, chunk: int, return_state: bool = False):
    """Pure-XLA chunked SSD (same math as the Pallas kernel, for paths
    where interpret-mode would be too slow or the dry-run lowers for a
    non-TPU backend)."""
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    nc = L // chunk
    xf = x.astype(F32)
    dtf = dt.astype(F32)
    la = (dtf * A[None, None, :]).reshape(Bsz, nc, chunk, H)
    scum = jnp.cumsum(la, axis=2)
    xdt = (xf * dtf[..., None]).reshape(Bsz, nc, chunk, H, P)
    Bc = jnp.repeat(
        Bm.reshape(Bsz, nc, chunk, G, N).astype(F32), hpg, axis=3
    )
    Cc = jnp.repeat(
        Cm.reshape(Bsz, nc, chunk, G, N).astype(F32), hpg, axis=3
    )
    cb = jnp.einsum("bcthn,bcshn->bchts", Cc, Bc)
    sc_h = scum.transpose(0, 1, 3, 2)                  # (B, nc, H, T)
    arg = sc_h[:, :, :, :, None] - sc_h[:, :, :, None, :]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask the exponent (not the product): t<s entries are exp(+large) and
    # would overflow to inf before a post-hoc where
    dec = jnp.exp(jnp.where(tri[None, None, None], arg, -jnp.inf))
    w = cb * dec
    y_intra = jnp.einsum("bchts,bcshp->bcthp", w, xdt)

    s_last = scum[:, :, -1, :]                         # (B, nc, H)
    bw = Bc * jnp.exp(s_last[:, :, None, :] - scum)[..., None]
    st = jnp.einsum("bcthp,bcthn->bchpn", xdt, bw)

    def carry(h, inp):
        st_c, dec_c = inp
        h_out = h
        h = h * dec_c[..., None, None] + st_c
        return h, h_out

    h0 = jnp.zeros((Bsz, H, P, N), F32)
    h_final, h_in = jax.lax.scan(
        carry, h0,
        (jnp.moveaxis(st, 1, 0), jnp.moveaxis(jnp.exp(s_last), 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)
    y_inter = jnp.einsum(
        "bcthn,bchpn->bcthp", Cc * jnp.exp(scum)[..., None], h_in
    )
    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    y = y + xf * D[None, None, :, None]
    if return_state:
        return y, h_final
    return y


def init_ssm_cache(batch: int, table: Dict[str, Any], dtype) -> Dict[str, Any]:
    d_conv = int(table["d_inner"]) + 2 * int(table["n_groups"]) * int(table["d_state"])
    return {
        "conv": jnp.zeros(
            (batch, int(table.get("conv_width", 4)) - 1, d_conv), dtype
        ),
        "ssm": jnp.zeros(
            (
                batch,
                int(table["n_heads"]),
                int(table["headdim"]),
                int(table["d_state"]),
            ),
            F32,
        ),
    }
