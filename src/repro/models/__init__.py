"""Model substrate: the paper's own FCN/STD family (models.fcn) and the
ten assigned LM architectures (models.lm), all executed through the
repro.core microcode engine."""
