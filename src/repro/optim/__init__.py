from .optimizers import adamw, sgd_momentum, OptState
from .schedules import constant, cosine_with_warmup, linear_warmup
from .grad_utils import (
    clip_by_global_norm,
    global_norm,
    GradAccumulator,
    error_feedback_compress,
)

__all__ = [
    "adamw", "sgd_momentum", "OptState", "constant", "cosine_with_warmup",
    "linear_warmup", "clip_by_global_norm", "global_norm",
    "GradAccumulator", "error_feedback_compress",
]
