"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def constant(lr: float):
    return lambda step: jnp.asarray(lr, F32)


def linear_warmup(lr: float, warmup_steps: int):
    def f(step):
        s = step.astype(F32)
        return lr * jnp.minimum(s / max(warmup_steps, 1), 1.0)
    return f


def cosine_with_warmup(
    lr: float, warmup_steps: int, total_steps: int, final_ratio: float = 0.1
):
    def f(step):
        s = step.astype(F32)
        warm = lr * jnp.minimum(s / max(warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = final_ratio + (1 - final_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog)
        )
        return jnp.where(s < warmup_steps, warm, lr * cos)
    return f
