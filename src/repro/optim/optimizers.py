"""Optimizers from scratch (no optax in this environment).

AdamW with configurable **moment storage**:
    moment_dtype = "float32" | "bfloat16" | "bfp8"
"bfp8" stores the FIRST moment as 7-bit-mantissa shared-exponent blocks —
the paper's C2 block floating-point applied beyond the paper, to optimizer
state (DESIGN.md §2).  At kimi-k2 scale this is the difference between
needing 8 TB and ~3 TB for moments (§6).

Measured negative result (EXPERIMENTS.md §Perf, lesson log): BFP8 on the
SECOND moment diverges — nu's intra-block dynamic range exceeds what any
linear 7-bit mantissa can hold (ratios > 10^3 within a 32-block), small
nu crush to exactly 0 and 1/(sqrt(0)+eps) explodes the step.  Sqrt-domain
storage fails the same way.  This is the paper's §IV.C lesson in reverse:
never narrow the quantity whose reciprocal you take.  So "bfp8" = BFP8 mu
+ bf16 nu (bf16 has a per-VALUE exponent, no crush); the update math is
always f32 (wide-accumulator discipline).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bfp as bfp_lib

F32 = jnp.float32


class OptState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment  (pytree, storage repr)
    nu: Any          # second moment (pytree, storage repr)
    extra: Any = None


def _store(x: jax.Array, dtype: str, *, second_moment: bool = False) -> Any:
    if dtype == "float32":
        return x.astype(F32)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    if dtype == "bfp8":
        if second_moment:
            return x.astype(jnp.bfloat16)   # see module docstring
        # int8 mantissa (7 bits + sign), one exponent per 32 values
        return bfp_lib.quantize(
            x, block_size=32, mantissa_bits=7, axis=-1, rounding="nearest"
        )
    raise ValueError(dtype)


def _load(x: Any) -> jax.Array:
    if isinstance(x, bfp_lib.BFPTensor):
        return bfp_lib.dequantize(x)
    return x.astype(F32)


def adamw(
    lr: Callable[[jax.Array], jax.Array] | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    moment_dtype: str = "float32",
):
    """Returns (init_fn, update_fn) — the minimal optax-style pair."""

    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, F32))

    def init(params) -> OptState:
        zeros = jax.tree_util.tree_map(
            lambda p: _store(jnp.zeros(p.shape, F32), moment_dtype), params
        )
        zeros2 = jax.tree_util.tree_map(
            lambda p: _store(jnp.zeros(p.shape, F32), moment_dtype,
                             second_moment=True),
            params,
        )
        return OptState(jnp.zeros((), jnp.int32), zeros, zeros2)

    def update(grads, state: OptState, params) -> Tuple[Any, OptState]:
        step = state.step + 1
        t = step.astype(F32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        lr_t = lr_fn(step)

        is_bfp = lambda x: isinstance(x, bfp_lib.BFPTensor)

        def upd(g, mu_s, nu_s, p):
            g = g.astype(F32)
            mu = b1 * _load(mu_s) + (1 - b1) * g
            nu = b2 * _load(nu_s) + (1 - b2) * g * g
            mhat = mu / bc1
            nhat = jnp.maximum(nu / bc2, 0.0)   # quantized nu may dip < 0
            delta = mhat / (jnp.sqrt(nhat) + eps)
            delta = delta + weight_decay * p.astype(F32)
            new_p = (p.astype(F32) - lr_t * delta).astype(p.dtype)
            return (
                new_p,
                _store(mu, moment_dtype),
                _store(nu, moment_dtype, second_moment=True),
            )

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        # moments tree has the same *structure* as params (BFPTensor is a
        # registered pytree node, so flatten with explicit leaf test):
        mu_leaves = jax.tree_util.tree_leaves(state.mu, is_leaf=is_bfp)
        nu_leaves = jax.tree_util.tree_leaves(state.nu, is_leaf=is_bfp)
        p_leaves = jax.tree_util.tree_leaves(params)
        outs = [
            upd(g, m, n, p)
            for g, m, n, p in zip(flat_g, mu_leaves, nu_leaves, p_leaves)
        ]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
        return new_p, OptState(step, new_mu, new_nu)

    return init, update


def sgd_momentum(
    lr: Callable[[jax.Array], jax.Array] | float,
    *,
    momentum: float = 0.9,
    weight_decay: float = 0.0,
):
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, F32))

    def init(params) -> OptState:
        z = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, F32), params)
        return OptState(jnp.zeros((), jnp.int32), z, None)

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, p):
            g = g.astype(F32) + weight_decay * p.astype(F32)
            m = momentum * m + g
            return (p.astype(F32) - lr_t * m).astype(p.dtype), m

        pairs = jax.tree_util.tree_map(upd, grads, state.mu, params)
        new_p = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step, new_m, None)

    return init, update
