"""Gradient utilities: clipping, accumulation, and compressed all-reduce
with error feedback (distributed-optimization tricks, DESIGN.md §5).

``error_feedback_compress`` applies the paper's C2 block quantizer to
gradients before they cross the interconnect: the residual (what the
quantizer dropped) is added back into the next step's gradient, so the
*sequence* of updates is unbiased even at 8-bit mantissas.  On a real
mesh, pairing this with ``runtime.collectives.compressed_psum`` cuts DP
gradient traffic ~4x versus f32 (measured in the dry-run collective
bytes, EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import bfp as bfp_lib

F32 = jnp.float32


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(F32))) for x in leaves)
    )


def clip_by_global_norm(tree, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(F32) * scale).astype(x.dtype), tree
    ), norm


class GradAccumulator:
    """Microbatch gradient accumulation as a lax.scan over the batch axis.

    ``accumulate(loss_fn, params, batch, n_micro)`` splits every leaf of
    ``batch`` into n_micro slices along axis 0 and averages grads — the
    memory/throughput knob used by the perf iterations.
    """

    def __init__(self, n_micro: int):
        assert n_micro >= 1
        self.n_micro = n_micro

    def __call__(self, loss_fn, params, batch):
        n = self.n_micro
        if n == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def reshape(x):
            b = x.shape[0]
            assert b % n == 0, f"batch {b} % n_micro {n} != 0"
            return x.reshape(n, b // n, *x.shape[1:])

        micro = jax.tree_util.tree_map(reshape, batch)

        def body(carry, mb):
            acc_loss, acc_g = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            acc_g = jax.tree_util.tree_map(
                lambda a, b_: a + b_.astype(F32), acc_g, g
            )
            return (acc_loss + l, acc_g), None

        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, F32), params
        )
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), F32), zero_g),
                                        micro)
        inv = 1.0 / n
        return loss * inv, jax.tree_util.tree_map(lambda g: g * inv, grads)


def error_feedback_compress(
    grads, residual, *, mantissa_bits: int = 7, block_size: int = 32
) -> Tuple[Any, Any]:
    """(compressed_grads, new_residual) — EF-style unbiased-in-the-limit
    quantization.  g' = Q(g + r);  r' = (g + r) - g'."""

    def one(g, r):
        gf = g.astype(F32) + r
        q = bfp_lib.roundtrip(
            gf, block_size=block_size, mantissa_bits=mantissa_bits,
            axis=-1, rounding="nearest",
        )
        return q.astype(g.dtype), gf - q

    pairs = jax.tree_util.tree_map(one, grads, residual)
    comp = jax.tree_util.tree_map(
        lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_r = jax.tree_util.tree_map(
        lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    return comp, new_r


def init_residual(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, F32), params
    )
