"""Architecture config schema + the four assigned input shapes.

Every assigned architecture is a module ``configs/<id>.py`` exporting
``CONFIG`` (the exact published hyperparameters) and ``SMOKE`` (a reduced
same-family config for CPU smoke tests).  ``input_specs`` builds the
ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"          # rmsnorm|layernorm
    act: str = "swiglu"            # swiglu|gelu
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_fission: int = 1       # split experts into d_ff slices (EP trick)
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 128
    # --- hybrid (zamba2-style shared attention blocks) ---
    attn_every: int = 0            # 0 = pure family; k = shared attn block
                                   # after every k SSM layers
    # --- enc-dec / prefix frontends (whisper / internvl stubs) ---
    encoder_layers: int = 0
    cross_attn: bool = False
    frontend: str = "none"         # none|audio_stub|vision_stub
    frontend_len: int = 0          # frames / patches fed by the stub
    # --- numerics / memory policy ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # BFP (paper C2) quantized matmul mode for forward compute
    bfp_forward: bool = False
    kv_cache_dtype: str = "compute"   # compute|int8 (C2 on the KV stream)
    bfp_block: int = 32
    bfp_mantissa: int = 10

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic token mixing -> long_500k is runnable."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        from repro.models.lm import transformer

        return transformer.count_params(self)

    def active_param_count(self) -> int:
        from repro.models.lm import transformer

        return transformer.count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train|prefill|decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-skip) — the DESIGN.md §Arch-applicability rules."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "full O(L^2) attention at 524288 ctx is infeasible; arch has no "
            "sub-quadratic path (skip noted in DESIGN.md)"
        )
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeConfig, *, batch: Optional[int] = None):
    """ShapeDtypeStruct stand-ins for every model input (dry-run pattern:
    weak-type-correct, shardable, no device allocation)."""
    b = batch if batch is not None else shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.frontend != "none":
            specs["prefix_embed"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.compute_dtype)
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.frontend != "none":
            specs["prefix_embed"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.compute_dtype)
            )
        return specs
    # decode: one new token against a seq_len-deep cache
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "cache_len": jax.ShapeDtypeStruct((), i32),
    }
