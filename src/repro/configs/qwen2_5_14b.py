"""qwen2.5-14b — dense GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B; hf].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
TP note: 40 heads do not divide the 16-way model axis; the sharding
resolver falls back to d_ff TP + FSDP attention (no silent padding).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab=152064, qkv_bias=True, rope_theta=1000000.0,
)

SMOKE = ArchConfig(
    name="qwen2.5-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab=256, qkv_bias=True,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
