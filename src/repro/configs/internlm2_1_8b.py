"""internlm2-1.8b — dense GQA [arXiv:2403.17297; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92544, rope_theta=1000000.0,
)

SMOKE = ArchConfig(
    name="internlm2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
