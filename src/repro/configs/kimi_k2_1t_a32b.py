"""kimi-k2-1t-a32b — trillion-param MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per-expert) vocab=163840,
MoE 384e top-8.  head_dim 7168//64 = 112.
Memory note (DESIGN.md §6): single-pod train_4k cannot hold f32 Adam
moments; the launcher defaults this arch to BFP8 moments + bf16 params.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, n_experts=384, top_k=8,
    rope_theta=50000.0,
)

SMOKE = ArchConfig(
    name="kimi-k2-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab=256, n_experts=8, top_k=2,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
