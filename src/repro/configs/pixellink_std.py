"""The paper's own models: PixelLink/EAST STD with ResNet-50 (the deployed
configuration, §V.B) and VGG-16 (the Fig. 8b comparison point).
"""
from repro.core.interpreter import BFPConfig
from repro.models.fcn.pixellink import STDConfig

# The configuration the paper deploys: ResNet-50 extractor, BFP numerics
# (FP16 storage, 10-bit mantissa blocks, wide accumulation).
RESNET50 = STDConfig(
    name="pixellink_resnet50",
    backbone="resnet50",
    image_size=(512, 512),
    mode="optimized",
    bfp=BFPConfig(block_size=32, mantissa_bits=10, wide_accum=True),
    storage_fp16=True,
)

VGG16 = STDConfig(
    name="pixellink_vgg16",
    backbone="vgg16",
    image_size=(512, 512),
    mode="optimized",
    bfp=BFPConfig(block_size=32, mantissa_bits=10, wide_accum=True),
    storage_fp16=True,
)

SMOKE = STDConfig(
    name="pixellink_smoke",
    backbone="vgg16",
    width=0.125,
    image_size=(64, 64),
    merge_ch=(16, 16, 8),
    mode="reference",
    storage_fp16=False,
)
