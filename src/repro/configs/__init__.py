"""Config registry: ``--arch <id>`` resolution for launchers/benchmarks."""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import SHAPES, ArchConfig, ShapeConfig, input_specs, shape_applicable

# arch id -> module name
ARCH_MODULES: Dict[str, str] = {
    "grok-1-314b": "grok_1_314b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen2.5-14b": "qwen2_5_14b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "internlm2-1.8b": "internlm2_1_8b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "whisper-tiny": "whisper_tiny",
    "internvl2-76b": "internvl2_76b",
    "zamba2-2.7b": "zamba2_2_7b",
    "mamba2-370m": "mamba2_370m",
}

ARCH_IDS: List[str] = list(ARCH_MODULES)


def _mod(arch: str):
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")


def get_config(arch: str) -> ArchConfig:
    return _mod(arch).CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    return _mod(arch).SMOKE


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS", "ARCH_MODULES", "SHAPES", "ArchConfig", "ShapeConfig",
    "all_configs", "get_config", "get_smoke_config", "input_specs",
    "shape_applicable",
]
