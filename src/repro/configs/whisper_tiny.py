"""whisper-tiny — enc-dec audio backbone [arXiv:2212.04356; unverified].

4L (encoder) + 4L (decoder), d_model=384 6H (MHA kv=6) d_ff=1536
vocab=51865.  The conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, 1500, d_model) — DESIGN.md §4.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, norm="layernorm", act="gelu",
    encoder_layers=4, cross_attn=True,
    frontend="audio_stub", frontend_len=1500,
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, norm="layernorm", act="gelu",
    encoder_layers=2, cross_attn=True,
    frontend="audio_stub", frontend_len=12,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
