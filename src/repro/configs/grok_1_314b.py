"""grok-1-314b — MoE 8e top-2 [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.
EP note (DESIGN.md §5): 8 experts < 16-way model axis -> expert-TP
(d_ff sharded over "model"), resolved automatically by ParamMeta prefs.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072, n_experts=8, top_k=2,
    rope_theta=10000.0,
)

SMOKE = ArchConfig(
    name="grok-1-314b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, n_experts=4, top_k=2,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
