"""mistral-nemo-12b — dense GQA, 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407; hf].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072; head_dim=128
(explicit: q-proj dim 4096 != d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128, rope_theta=1000000.0,
)

SMOKE = ArchConfig(
    name="mistral-nemo-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab=256, head_dim=24,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
