"""zamba2-2.7b — Mamba2 + shared attention blocks [arXiv:2411.15242; hf].

54L d_model=2560 32H (GQA kv=32 = MHA) d_ff=10240, ssm_state=64.
Weight sharing of the attention block across its 9 call sites is
microcode address reuse (same binding name at every site) — DESIGN.md §4.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, ssm_state=64, ssm_headdim=64,
    ssm_expand=2, ssm_groups=1, attn_every=6, ssm_chunk=128,
)

SMOKE = ArchConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, ssm_state=16, ssm_headdim=16,
    attn_every=2, ssm_chunk=8,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
