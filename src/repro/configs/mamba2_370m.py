"""mamba2-370m — SSD, attention-free [arXiv:2405.21060; unverified].

48L d_model=1024, ssm_state=128, d_inner=2048, headdim=64 (-> 32 ssm
heads), vocab=50280.  Attention-sharding features are inapplicable
(attn-free) — noted in DESIGN.md §4; arch fully supported.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280, ssm_state=128, ssm_headdim=64,
    ssm_expand=2, ssm_groups=1, ssm_chunk=128,
)

SMOKE = ArchConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=3, d_model=64, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=256, ssm_state=16, ssm_headdim=16, ssm_chunk=8,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
