"""internvl2-76b — InternViT + LM backbone [arXiv:2404.16821; unverified].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  The InternViT
frontend is a STUB: input_specs() provides patch embeddings
(B, 256, d_model) prepended to the token sequence — DESIGN.md §4.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, rope_theta=500000.0,
    frontend="vision_stub", frontend_len=256,
)

SMOKE = ArchConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, frontend="vision_stub", frontend_len=8,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
