"""Sharded, async, atomic checkpointing (no orbax in this environment).

Layout:  <dir>/step_<N>/
             manifest.json        tree structure, shapes, dtypes, step
             <leaf-key>.npy       one file per pytree leaf

Properties the fault-tolerance layer depends on:
  * ATOMIC   — written to step_<N>.tmp, fsync'd, then os.rename: a crash
               mid-save never corrupts the latest checkpoint.
  * ASYNC    — ``save_checkpoint(..., blocking=False)`` snapshots to host
               RAM (device_get) synchronously and writes on a worker
               thread; training continues during the write.
  * ELASTIC  — restore() takes an optional shardings tree; arrays are
               device_put with the *new* mesh layout, so a job can restart
               on a different device count (elastic re-mesh, DESIGN.md §5).
  * EXACT    — round-trips bit-identically (tests assert bitwise equality
               of a resumed training run).

BFPTensor optimizer moments are pytree nodes, so they serialize through
the same path (mantissa + exponent leaves).
"""
from __future__ import annotations

import itertools
import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")
# unique tmp suffixes: two writers of the same step (e.g. an orphaned async
# write racing a post-restart save) must never share a staging directory
_TMP_SEQ = itertools.count()


def _leaf_key(path) -> str:
    return (
        jax.tree_util.keystr(path)
        .replace("[", "_").replace("]", "_").replace("'", "")
        .replace(".", "_").replace("/", "_").strip("_")
    ) or "leaf"


def _flatten_with_keys(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = []
    seen: Dict[str, int] = {}
    for path, _ in flat:
        k = _leaf_key(path)
        if k in seen:
            seen[k] += 1
            k = f"{k}__{seen[k]}"
        else:
            seen[k] = 0
        keys.append(k)
    return [(k, v) for k, (_, v) in zip(keys, flat)], treedef


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    *,
    blocking: bool = True,
    extra_meta: Optional[Dict[str, Any]] = None,
) -> threading.Thread | None:
    """Write ``tree`` at ``directory/step_<step>`` (atomic; async option)."""
    os.makedirs(directory, exist_ok=True)
    # snapshot to host synchronously (cheap vs the disk write); training may
    # then mutate device buffers freely
    leaves, treedef = _flatten_with_keys(tree)
    host = [(k, np.asarray(jax.device_get(v))) for k, v in leaves]
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "leaves": [
            {"key": k, "shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in host
        ],
        "meta": extra_meta or {},
    }

    tmp_suffix = f".tmp-{os.getpid()}-{next(_TMP_SEQ)}"

    def write():
        final = os.path.join(directory, f"step_{step}")
        tmp = final + tmp_suffix
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for k, v in host:
            # raw bytes + manifest dtype — np.save cannot round-trip
            # bfloat16 (ml_dtypes) arrays
            with open(os.path.join(tmp, f"{k}.bin"), "wb") as f:
                f.write(np.ascontiguousarray(v).tobytes())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def restore_checkpoint(
    directory: str,
    step: int,
    like: Any,
    *,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes verified).

    ``shardings``: optional matching pytree of jax.sharding.Sharding — the
    elastic-reshard path: arrays land directly in the new layout.
    """
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten_with_keys(like)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"model expects {len(leaves)}"
        )
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(shardings)
    out = []
    for i, ((k, ref), rec) in enumerate(zip(leaves, manifest["leaves"])):
        if k != rec["key"]:
            raise ValueError(f"leaf order mismatch: {k} != {rec['key']}")
        dtype = jnp.dtype(rec["dtype"])
        with open(os.path.join(d, f"{k}.bin"), "rb") as f:
            arr = np.frombuffer(f.read(), dtype=dtype).reshape(rec["shape"])
        if list(arr.shape) != list(np.shape(ref)):
            raise ValueError(
                f"{k}: checkpoint shape {arr.shape} != model {np.shape(ref)}"
            )
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Retention + latest-step discovery + auto-resume + async handle."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        # reclaim staging dirs orphaned by a crashed writer: tmp suffixes
        # are unique per save, so a dead process's dir is never reused and
        # would otherwise live forever (single-writer-per-dir assumption)
        for name in os.listdir(directory):
            if ".tmp-" in name:
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(
                os.path.join(self.directory, name, "manifest.json")
            ):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def save(self, step: int, tree: Any, *, blocking: bool = False,
             extra_meta=None):
        self.wait()                      # one in-flight write at a time
        self._pending = save_checkpoint(
            self.directory, step, tree, blocking=blocking,
            extra_meta=extra_meta,
        )
        if blocking:
            self._pending = None
        self._gc()

    def restore_latest(self, like, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, restore_checkpoint(
            self.directory, step, like, shardings=shardings
        )

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)
