"""Telemetry + calibration: measured cost flowing from engines to the
planner (ROADMAP "calibrated cost model" / "plan-aware autoscaling
signals").

The paper's §V efficiency claim rests on the mapping being tuned to
*measured* behavior, not nominal FLOPs.  This module is the measurement
half of that loop:

  * :class:`CostBook` — the lock-guarded measurement store every layer
    writes into.  Engine step times are keyed by
    ``(bucket_hw, batch, plan_kind)`` (plus a ``stage`` dimension:
    ``"dispatch"`` = the engine-call wall recorded by
    runtime/executor.EngineFactory, ``"step"`` = dispatch through
    materialization recorded by launch/serve.STDService — and a
    ``precision`` dimension, ``"f32"``/``"bfp"``, so per-precision
    walls never mix and a measured-cost planner can route each bucket
    to its faster numerics); scheduler
    stage timings / queue gauges / shed counters from
    launch/batching.MicroBatcher land as named series in the same book.
    Every series keeps a count, an EWMA, and a bounded window of recent
    samples for p50/p99 — all mutations hold one lock, the same
    stats-locking contract the PR 4 hammer tests pin on MicroBatcher.
  * :func:`snapshot` / :func:`prometheus_text` — flat scrapeable
    ``{metric_name: value}`` export (labels are embedded in the metric
    name, Prometheus-style), surfaced by
    ``STDService.metrics_snapshot()`` for autoscalers.
  * :func:`fit_cost_params` — least-squares calibration: the analytic
    step-cost model (runtime/planner.step_cost) is LINEAR in the five
    :class:`~repro.runtime.planner.CostParams` constants, so a sweep of
    measured (features, kind, batch, mesh) -> seconds rows determines
    them directly.  ``benchmarks/serve_bench.py --calibrate out.json``
    runs the sweep and saves the fit; ``--cost-params out.json``
    reloads it (:func:`save_cost_params` / :func:`load_cost_params`
    round-trip through JSON exactly).

The planner side of the loop lives in runtime/planner.py:
``MeasuredCost(book)`` overlays the analytic model once a combo has
enough observations.  This module never imports the planner at the top
level's hot path beyond CostParams, and the planner does not import
this module at all (the book is duck-typed), so the layering stays
one-directional.
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

StepKey = Tuple[Tuple[int, int], int, str]


class _Series:
    """Count + EWMA + bounded recent-sample window for one metric.

    The window is a deterministic sliding reservoir (last ``maxlen``
    samples), so percentile queries need no randomness and tests can
    pin exact values."""

    __slots__ = ("count", "ewma", "total", "window")

    def __init__(self, window: int):
        self.count = 0
        self.ewma: Optional[float] = None
        self.total = 0.0
        self.window: deque = deque(maxlen=window)

    def add(self, value: float, alpha: float) -> None:
        self.count += 1
        self.total += value
        self.ewma = (value if self.ewma is None
                     else alpha * value + (1.0 - alpha) * self.ewma)
        self.window.append(value)

    def percentile(self, q: float) -> Optional[float]:
        if not self.window:
            return None
        xs = sorted(self.window)
        i = min(len(xs) - 1, max(0, math.ceil(q / 100.0 * len(xs)) - 1))
        return xs[i]


class CostBook:
    """Lock-guarded measurement store: engine step times keyed by
    ``(bucket_hw, batch, plan_kind)`` and named scheduler/service
    series, each with count / EWMA / p50 / p99.

    Writers (engine wrappers, scheduler stages, service completion)
    call :meth:`record_step`, :meth:`observe`, :meth:`incr`,
    :meth:`set_gauge` from their own threads; every mutation and every
    read holds ``_lock`` — the counters are read-modify-write, so the
    GIL alone would lose updates (tests/test_telemetry.py hammers
    this, the PR 4 lost-update pattern)."""

    def __init__(self, *, ewma_alpha: float = 0.25, window: int = 256,
                 warmup: int = 1,
                 labels: Optional[Dict[str, str]] = None):
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if warmup < 0:
            raise ValueError("warmup must be >= 0")
        self.ewma_alpha = ewma_alpha
        self.window = window
        # constant label set (e.g. {"replica": "r0"}) embedded in every
        # snapshot metric name, so N per-replica books aggregate into
        # one scrape without the named counters/gauges clobbering each
        # other (launch/router.py gives each replica's book its name)
        self.labels: Dict[str, str] = dict(labels or {})
        # the first call of a compiled engine traces + XLA-compiles
        # INSIDE the call (jit is lazy), a multi-second one-off that
        # would poison a millisecond-scale EWMA — skip the first
        # ``warmup`` samples per (combo, stage)
        self.warmup = warmup
        self._lock = threading.Lock()
        # step series key: (StepKey, stage, precision, model)
        self._steps: Dict[Tuple[StepKey, str, str, str], _Series] = {}
        self._warm: Dict[Tuple[StepKey, str, str, str], int] = {}
        self._series: Dict[str, _Series] = {}
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}

    @staticmethod
    def _step_key(hw, batch, kind) -> StepKey:
        return ((int(hw[0]), int(hw[1])), int(batch), str(kind))

    # -- writers ---------------------------------------------------------------
    def record_step(self, hw: Tuple[int, int], batch: int, kind: str,
                    seconds: float, *, stage: str = "step",
                    precision: str = "f32",
                    model: str = "pixellink") -> None:
        """One engine step's wall time for a (bucket, batch, plan_kind)
        combo.  ``stage="dispatch"`` is the non-blocking engine-call
        wall (executor); ``stage="step"`` is dispatch through
        materialization (the routing-relevant one — MeasuredCost reads
        it).  ``precision`` keeps f32 and bfp walls in separate series
        (per-precision engines compile separately and run different
        kernels); ``model`` does the same across the detection zoo (the
        heads have very different FLOP profiles)."""
        key = (self._step_key(hw, batch, kind), stage, str(precision),
               str(model))
        with self._lock:
            warm = self._warm.get(key, 0)
            if warm < self.warmup:
                self._warm[key] = warm + 1
                return
            s = self._steps.get(key)
            if s is None:
                s = self._steps[key] = _Series(self.window)
            s.add(float(seconds), self.ewma_alpha)

    def observe(self, name: str, value: float) -> None:
        """One sample of a named series (stage timings, occupancy...)."""
        with self._lock:
            s = self._series.get(name)
            if s is None:
                s = self._series[name] = _Series(self.window)
            s.add(float(value), self.ewma_alpha)

    def incr(self, name: str, n: float = 1.0) -> None:
        """Monotonic counter (sheds, submissions...)."""
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + n

    def set_gauge(self, name: str, value: float) -> None:
        """Point-in-time gauge (queue depth, in-flight batches...)."""
        with self._lock:
            self._gauges[name] = float(value)

    # -- readers ---------------------------------------------------------------
    def step_count(self, hw, batch, kind, *, stage: str = "step",
                   precision: str = "f32",
                   model: str = "pixellink") -> int:
        key = (self._step_key(hw, batch, kind), stage, str(precision),
               str(model))
        with self._lock:
            s = self._steps.get(key)
            return s.count if s is not None else 0

    def step_ewma(self, hw, batch, kind, *, stage: str = "step",
                  precision: str = "f32",
                  model: str = "pixellink") -> Optional[float]:
        key = (self._step_key(hw, batch, kind), stage, str(precision),
               str(model))
        with self._lock:
            s = self._steps.get(key)
            return s.ewma if s is not None else None

    def step_percentile(self, hw, batch, kind, q: float, *,
                        stage: str = "step",
                        precision: str = "f32",
                        model: str = "pixellink") -> Optional[float]:
        key = (self._step_key(hw, batch, kind), stage, str(precision),
               str(model))
        with self._lock:
            s = self._steps.get(key)
            return s.percentile(q) if s is not None else None

    def step_total(self, hw, batch, kind, *, stage: str = "step",
                   precision: str = "f32",
                   model: str = "pixellink") -> float:
        """Cumulative wall seconds for one combo — the busy-time view
        (e.g. summing ``stage="postprocess"`` walls across buckets gives
        each postprocess mode's total tail cost in an A/B)."""
        key = (self._step_key(hw, batch, kind), stage, str(precision),
               str(model))
        with self._lock:
            s = self._steps.get(key)
            return s.total if s is not None else 0.0

    def step_keys(self, *, stage: str = "step",
                  precision: str = "f32",
                  model: str = "pixellink") -> List[StepKey]:
        """Every (hw, batch, kind) combo with at least one sample at
        this (stage, precision, model)."""
        with self._lock:
            return sorted(k for k, st, pr, md in self._steps
                          if st == stage and pr == precision
                          and md == model)

    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    def gauge(self, name: str) -> Optional[float]:
        with self._lock:
            return self._gauges.get(name)

    def snapshot(self, prefix: str = "std_") -> Dict[str, float]:
        """Flat scrapeable ``{metric_name: value}`` view of everything
        in the book.  Labels are embedded Prometheus-style in the name,
        so the dict stays flat: e.g.
        ``std_step_ewma_s{bucket="128x64",batch="4",plan="row_band",
        stage="step"}``.  A book constructed with ``labels=`` gets them
        merged into every name (see :func:`relabel`), so per-replica
        books stay disjoint when a router aggregates N snapshots."""
        out: Dict[str, float] = {}
        with self._lock:
            for ((hw, batch, kind), stage, precision, model), s in sorted(
                    self._steps.items()):
                # the f32/pixellink defaults keep the historical label
                # shape; other precisions/models append their own labels
                # so scrapers can tell them apart
                prec = ("" if precision == "f32"
                        else f',precision="{precision}"')
                mdl = ("" if model == "pixellink"
                       else f',model="{model}"')
                lbl = (f'{{bucket="{hw[0]}x{hw[1]}",batch="{batch}",'
                       f'plan="{kind}",stage="{stage}"{prec}{mdl}}}')
                out[f"{prefix}step_count{lbl}"] = float(s.count)
                if s.ewma is not None:
                    out[f"{prefix}step_ewma_s{lbl}"] = s.ewma
                p50, p99 = s.percentile(50), s.percentile(99)
                if p50 is not None:
                    out[f"{prefix}step_p50_s{lbl}"] = p50
                    out[f"{prefix}step_p99_s{lbl}"] = p99
            for name, s in sorted(self._series.items()):
                out[f"{prefix}{name}_count"] = float(s.count)
                if s.ewma is not None:
                    out[f"{prefix}{name}_ewma"] = s.ewma
                p50, p99 = s.percentile(50), s.percentile(99)
                if p50 is not None:
                    out[f"{prefix}{name}_p50"] = p50
                    out[f"{prefix}{name}_p99"] = p99
            for name, v in sorted(self._counters.items()):
                out[f"{prefix}{name}_total"] = v
            for name, v in sorted(self._gauges.items()):
                out[f"{prefix}{name}"] = v
        if self.labels:
            out = relabel(out, **self.labels)
        return out


def _merge_labels(name: str, suffix: str) -> str:
    """Insert a rendered ``k="v",...`` label suffix into a metric name,
    merging into an existing ``{...}`` group or appending a new one."""
    if not suffix:
        return name
    if name.endswith("}"):
        return f"{name[:-1]},{suffix}}}"
    return f"{name}{{{suffix}}}"


def relabel(metrics: Dict[str, float], **labels: str) -> Dict[str, float]:
    """Embed constant labels into every metric name of a flat snapshot
    (names already carrying one of the label keys keep their value).
    This is the per-replica aggregation seam: N replica snapshots
    relabel to disjoint name sets and merge into one scrape without
    gauge clobbering."""
    out: Dict[str, float] = {}
    for name, v in metrics.items():
        missing = {k: val for k, val in labels.items()
                   if f'{k}="' not in name}
        suffix = ",".join(f'{k}="{val}"'
                          for k, val in sorted(missing.items()))
        out[_merge_labels(name, suffix)] = v
    return out


def prometheus_text(metrics: Dict[str, float]) -> str:
    """Render a flat ``{metric_name: value}`` dict (labels already
    embedded in names) as Prometheus text-exposition lines."""
    lines = []
    for name in sorted(metrics):
        v = metrics[name]
        lines.append(f"{name} {float(v):.9g}")
    return "\n".join(lines) + ("\n" if lines else "")


# -- calibration ---------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StepMeasurement:
    """One calibration row: the cost-model inputs of a measured step.

    ``flops``/``halo_bytes``/``halo_layers`` come from the bucket's
    PlanFeatures, ``kind``/``batch``/``data_n``/``model_n`` describe
    how it ran, ``seconds`` is the measured (blocked-until-ready) step
    wall time."""

    flops: float
    halo_bytes: float
    halo_layers: int
    kind: str
    batch: int
    data_n: int
    model_n: int
    seconds: float


def _design_row(m: StepMeasurement) -> List[float]:
    """The analytic step cost is linear in
    ``x = (1/peak_flops, 1/ici_bw, dispatch_overhead_s,
    collective_overhead_s, halo_launch_s)``; this is one row of the
    design matrix, mirroring runtime/planner.step_cost term for term."""
    from repro.runtime.planner import PLAN_KINDS, _BANDED, padded_batch

    if m.kind not in PLAN_KINDS:
        raise ValueError(f"unknown plan kind {m.kind!r}")
    dn = m.data_n if m.kind in ("data_parallel", "grid") else 1
    mn = m.model_n if m.kind in _BANDED else 1
    local_b = padded_batch(m.batch, dn) // dn
    return [
        m.flops * local_b / mn,                       # 1/peak_flops
        m.halo_bytes * local_b if mn > 1 else 0.0,    # 1/ici_bw
        1.0,                                          # dispatch_overhead_s
        float((dn > 1) + (mn > 1)),                   # collective_overhead_s
        float(m.halo_layers) if mn > 1 else 0.0,      # halo_launch_s
    ]


def fit_cost_params(measurements: Iterable[StepMeasurement], *,
                    base: Optional[Any] = None):
    """Least-squares fit of the CostParams constants from measured step
    times.  Columns the sweep never exercised (e.g. no banded combos on
    a unit mesh leave every halo entry zero) are unidentifiable and
    keep ``base``'s value (default: the napkin CostParams()); fitted
    rate constants are clamped positive so 1/x stays finite."""
    import numpy as np

    from repro.runtime.planner import CostParams

    base = base if base is not None else CostParams()
    measurements = list(measurements)      # may be a single-pass iterable
    rows = [_design_row(m) for m in measurements]
    if not rows:
        return base
    y = np.asarray([m.seconds for m in measurements], dtype=np.float64)
    A = np.asarray(rows, dtype=np.float64)
    identifiable = np.any(A != 0.0, axis=0)
    x = np.zeros(A.shape[1])
    if identifiable.any():
        sol, *_ = np.linalg.lstsq(A[:, identifiable], y, rcond=None)
        x[identifiable] = sol
    base_x = np.asarray([
        1.0 / base.peak_flops, 1.0 / base.ici_bw,
        base.dispatch_overhead_s, base.collective_overhead_s,
        base.halo_launch_s,
    ])
    # unidentifiable -> base; identifiable but non-positive (noise drove
    # the fit through zero) -> base as well, never a negative rate
    for i in range(5):
        if not identifiable[i] or x[i] <= 0.0:
            x[i] = base_x[i]
    return CostParams(
        peak_flops=float(1.0 / x[0]),
        ici_bw=float(1.0 / x[1]),
        dispatch_overhead_s=float(x[2]),
        collective_overhead_s=float(x[3]),
        halo_launch_s=float(x[4]),
    )


def cost_params_to_dict(params) -> Dict[str, float]:
    return {k: float(v) for k, v in dataclasses.asdict(params).items()}


def cost_params_from_dict(d: Dict[str, float]):
    from repro.runtime.planner import CostParams

    fields = {f.name for f in dataclasses.fields(CostParams)}
    unknown = set(d) - fields
    if unknown:
        raise ValueError(f"unknown CostParams fields {sorted(unknown)}")
    return CostParams(**{k: float(v) for k, v in d.items()})


def save_cost_params(params, path: str, *,
                     measurements: Sequence[StepMeasurement] = (),
                     meta: Optional[Dict[str, Any]] = None) -> None:
    """Fitted params (+ provenance: the measurement rows and free-form
    meta) to JSON; :func:`load_cost_params` round-trips exactly."""
    doc = {
        "cost_params": cost_params_to_dict(params),
        "measurements": [dataclasses.asdict(m) for m in measurements],
        "meta": dict(meta or {}),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def load_cost_params(path: str):
    """CostParams back from a ``save_cost_params`` JSON file (also
    accepts a bare ``{field: value}`` dict for hand-written files)."""
    with open(path) as f:
        doc = json.load(f)
    d = doc.get("cost_params", doc) if isinstance(doc, dict) else doc
    return cost_params_from_dict(d)
