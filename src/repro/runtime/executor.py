"""ExecutionPlan layer: one seam from assembled microcode to multi-device
serving.

The paper stacks three levels of parallelism over one fixed FCN datapath;
each level is an :class:`ExecutionPlan` target here, and every compiled
serving engine flows through :class:`EngineFactory` — so the scheduler
(launch/serve.py, launch/batching.py) never touches jit/shard_map
directly and later scaling work (multi-pod meshes, heterogeneous buckets,
async dispatch) only has to add plan types:

  * :class:`SingleDevice` — the baseline engine: the paper's batch-level
    parallelism only (one chip runs a (bucket, batch) shape end to end).
  * :class:`DataParallel` — the paper's batch level spread over a device
    mesh: shard_map splits the micro-batch over the mesh's "data" axis,
    each shard runs the full microcode program plus the CC-labeling tail
    on its slice (per-image ops, so per-shard == global).
  * :class:`RowBand` — the paper's §IV.B row-wise segmentation across
    devices: the image plane is split into horizontal bands over the
    "model" axis and each device runs the SAME program assembled at the
    band plane.  Every spatial layer halo-exchanges its own boundary
    rows (runtime/collectives.halo_exchange driven by
    FCNEngine._spatial_banded) — the multi-device generalization of
    core/rowband.conv2d_banded, layer by layer.  Band outputs equal the
    full plane mathematically; in "reference" mode (and wherever band
    offsets are Winograd-tile-aligned) they are bit-identical, while
    misaligned offsets in "optimized" mode regroup Winograd tiles and
    can shift scores by float-reassociation noise (~1e-6) — far inside
    the margin of any realistic 0.5-threshold decision.  This is the
    route for over-tall images that exceed the largest resolution
    bucket.

  * :class:`GridPlan` — the paper's two levels stacked in ONE compiled
    engine (§IV batch-level x row-wise segmentation): shard_map over a
    2-D mesh splits the micro-batch over the "data" axis *and* the image
    rows over the "model" axis simultaneously, so each model-row of
    devices runs the band-plane program on its batch shard with
    per-layer halo exchange along "model" only (halo_exchange never
    crosses the "data" axis — see runtime/collectives).  Activations
    follow the composed 2-D specs from runtime.sharding
    (fcn_activation_specs with both axes set).  This is the full-pod
    shape: a (data=N, model=M) mesh serves N batch shards of M-banded
    planes per step.

    Module-level pipelining (paper C4) stays host-side — HostPipeline /
    MicroBatcher overlap preprocess, device compute, and postprocess
    around whichever plan is active.

Plans are frozen, hashable dataclasses: the serving engine LRU keys on
``(bucket_hw, batch, plan, precision, model)`` and a mesh, precision, or
model change is a new compiled engine, never silent reuse.  ``model`` is
the paper's versatility axis (models/fcn/heads.MODEL_ZOO): every
detection head compiles through the same assembler -> microcode path,
and the factory's ``make_model(hw, precision, model)`` builds whichever
head a request routes to.  ``precision`` is the
paper's numerics axis (docs/plans.md "Precision modes"): ``"f32"`` runs
plain float convs, ``"bfp"`` runs BFP-quantized convs with FP16
data-pool storage and the Pallas kernels where the backend compiles
them — the factory's ``make_model(hw, precision)`` builds the matching
model, and the bfp parameter cache holds the f32 parameters run through
the paper's Fig. 4 normalization (BN fold + BFP weight roundtrip), so
both precisions share one underlying weight set and accuracy-parity
gates compare like with like.

Compiled engines are ASYNC: calling one returns un-materialized device
arrays (JAX async dispatch), so the serving dispatch stage can submit
the next batch while this one's H2D/compute/D2H run; materialization
(``np.asarray``) is the completion stage's job (launch/batching.py).
On accelerator backends the padded input stack's buffer is donated back
to XLA (:func:`_donate_argnums`).
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
import threading
import time
from typing import Any, Callable, Dict, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.batching import LRUCache
from repro.models.fcn.heads import DEFAULT_MODEL, check_model
from repro.runtime.collectives import halo_exchange
from repro.runtime.sharding import (
    fcn_activation_specs,
    mesh_axis_sizes,
    shard_map_compat,
)


@dataclasses.dataclass(frozen=True)
class SingleDevice:
    """Run the whole (bucket, batch) shape on the default device."""


@dataclasses.dataclass(frozen=True)
class DataParallel:
    """Split the batch over ``mesh`` axis ``axis`` (paper batch level)."""

    mesh: Mesh
    axis: str = "data"


@dataclasses.dataclass(frozen=True)
class RowBand:
    """Split image rows into bands over ``mesh`` axis ``axis`` (paper
    §IV.B).  ``bands`` must equal the axis size (0 = take it from the
    mesh); per-layer halo widths are derived from each layer's kernel."""

    mesh: Mesh
    axis: str = "model"
    bands: int = 0


@dataclasses.dataclass(frozen=True)
class GridPlan:
    """Batch over ``data_axis`` x rows over ``model_axis`` in one
    shard_map (paper §IV batch level + row-wise segmentation stacked).
    ``bands`` must equal the model-axis size (0 = take it from the
    mesh); batch sizes must be a multiple of the data-axis size."""

    mesh: Mesh
    data_axis: str = "data"
    model_axis: str = "model"
    bands: int = 0


ExecutionPlan = Union[SingleDevice, DataParallel, RowBand, GridPlan]

#: execution precisions the engine LRU keys on: plain float vs the
#: paper's BFP-quantized datapath with FP16 data-pool storage
PRECISIONS = ("f32", "bfp")


def check_precision(precision: str) -> str:
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; expected one of {PRECISIONS}"
        )
    return precision


class _BandCtx:
    """Halo-exchange hook handed to FCNEngine for row-banded execution
    (keeps core/ free of collective imports)."""

    def __init__(self, axis_name: str, n_bands: int):
        self.axis_name = axis_name
        self.n_bands = n_bands

    def exchange(self, x, halo: int):
        return halo_exchange(
            x, self.axis_name, halo, axis=1, axis_size=self.n_bands
        )


def _donate_argnums() -> Tuple[int, ...]:
    """Donation slots for compiled engines: the padded input stack
    (arg 1) is built fresh per batch and never reused by the scheduler,
    so on accelerator backends XLA may overwrite its buffer in place —
    with async pipelined dispatch each in-flight batch owns its own
    donated slot, so overlap never aliases live data.  CPU XLA cannot
    donate and would warn on every call, so donation is gated off
    there."""
    return (1,) if jax.default_backend() in ("gpu", "tpu") else ()


def plan_batch_multiple(plan: ExecutionPlan) -> int:
    """Batch sizes compiled for ``plan`` must be a multiple of this."""
    if isinstance(plan, DataParallel):
        return mesh_axis_sizes(plan.mesh).get(plan.axis, 1)
    if isinstance(plan, GridPlan):
        return mesh_axis_sizes(plan.mesh).get(plan.data_axis, 1)
    return 1


def plan_bands(plan: ExecutionPlan) -> int:
    """Number of row bands a plan splits the image plane into (1 for
    non-banded plans)."""
    if isinstance(plan, RowBand):
        return plan.bands or mesh_axis_sizes(plan.mesh).get(plan.axis, 1)
    if isinstance(plan, GridPlan):
        return plan.bands or mesh_axis_sizes(plan.mesh).get(
            plan.model_axis, 1
        )
    return 1


def band_height_unit(plan: ExecutionPlan, deepest_stride: int) -> int:
    """Heights compiled for a row-banded plan (RowBand or GridPlan) must
    be a multiple of this: every band must divide evenly through the
    whole stride pyramid (``H % (bands * deepest_stride) == 0``)."""
    return plan_bands(plan) * deepest_stride


def row_band_height_unit(plan: RowBand, deepest_stride: int) -> int:
    """Back-compat alias for :func:`band_height_unit`."""
    return band_height_unit(plan, deepest_stride)


def plan_kind(plan: ExecutionPlan) -> str:
    """The planner-side kind string for a plan instance — the key the
    telemetry CostBook and runtime/planner.PLAN_KINDS share."""
    if isinstance(plan, DataParallel):
        return "data_parallel"
    if isinstance(plan, RowBand):
        return "row_band"
    if isinstance(plan, GridPlan):
        return "grid"
    return "single_device"


def describe_plan(plan: ExecutionPlan) -> str:
    if isinstance(plan, DataParallel):
        n = mesh_axis_sizes(plan.mesh).get(plan.axis, 1)
        return f"data_parallel[{plan.axis}={n}]"
    if isinstance(plan, RowBand):
        n = plan.bands or mesh_axis_sizes(plan.mesh).get(plan.axis, 1)
        return f"row_band[{plan.axis}={n}]"
    if isinstance(plan, GridPlan):
        sizes = mesh_axis_sizes(plan.mesh)
        dn = sizes.get(plan.data_axis, 1)
        mn = plan.bands or sizes.get(plan.model_axis, 1)
        return f"grid[{plan.data_axis}={dn},{plan.model_axis}={mn}]"
    return "single_device"


class EngineFactory:
    """Compiles (bucket_hw, batch, plan, precision) -> engine callable,
    with the model/param caches and the compiled-engine LRU behind one
    lock.

    ``make_model(hw, precision)`` builds the STD model for one input
    plane at one execution precision (its parameters must be
    plane-invariant — fully convolutional — so one per-bucket param set
    serves every band plane derived from it).  Legacy single-argument
    ``make_model(hw)`` callables still work but pin the factory to
    ``"f32"``.  The compiled callable is ``fn(params, x, valid_q) ->
    (labels, converged)``: FCN forward, per-image valid-region masking,
    batched CC labeling (log-hop pointer jumping), and the per-image
    convergence flag the serving layer counts instead of swallowing.
    On TPU the CC tail routes through the Pallas tile-local kernel
    (``cc_pallas=None`` derives from the backend; force with
    True/False), and :meth:`boxes_fn` compiles the on-device compact
    box extraction the device postprocess path rides
    (docs/serving.md "Postprocess pipeline").

    Parameters are per-precision without being independent: the f32
    cache holds the deterministic PRNGKey(0) initialization, and the
    bfp cache holds those SAME parameters run through the paper's
    Fig. 4 normalization (BN fold + BFP weight roundtrip via the bfp
    model's ``normalize_weights``) — so f32-vs-bfp accuracy parity
    compares one weight set under two numerics, never two inits.

    With a telemetry ``book`` (runtime/telemetry.CostBook) every
    compiled engine is wrapped once, at compile time, to record its
    per-call wall keyed by (bucket_hw, batch, plan_kind) under
    ``stage="dispatch"`` — the non-blocking engine-call side of the
    measured-cost loop (engines return un-materialized arrays; the
    serving layer records the dispatch-through-materialization
    ``stage="step"`` wall the planner's MeasuredCost overlay reads).
    The wrapper lives inside the LRU, so cache hits return the identical
    callable.
    """

    def __init__(
        self,
        make_model: Callable[..., Any],
        *,
        score_thr: float = 0.5,
        link_thr: float = 0.5,
        capacity: int = 16,
        book: Any = None,
        cc_pallas: Any = None,
        engine_bytes_budget: int = 0,
    ):
        self.make_model = make_model
        # make_model generations: legacy (hw), precision-aware
        # (hw, precision), model-aware (hw, precision, model).
        # Unintrospectable callables are treated as model-aware (they
        # can ignore the extras).
        try:
            n_params = len([
                p for p in inspect.signature(make_model).parameters.values()
                if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
                or p.kind == p.VAR_POSITIONAL
            ])
        except (TypeError, ValueError):
            n_params = 3
        self._make_model_arity = min(n_params, 3)
        self.score_thr = score_thr
        self.link_thr = link_thr
        self.book = book
        # Pallas tile-local CC kernel only beats the jnp while_loop where
        # it actually compiles (TPU Mosaic); elsewhere the interpreter
        # would be orders of magnitude slower than XLA
        self.cc_pallas = (jax.default_backend() == "tpu"
                          if cc_pallas is None else bool(cc_pallas))
        # model/param caches are LRU-bounded like the engines: oversize
        # inputs clamp to an open-ended set of padded shapes (bucket_hw),
        # so unbounded dicts would leak a parameter tree per shape
        self._models = LRUCache(capacity)
        self._params = LRUCache(capacity)
        # the engine LRU can evict by planned activation bytes instead of
        # (only) entry count: plan_fn puts each engine with
        # weight = memplan peak bytes x batch, so a byte budget keeps the
        # RESIDENT FOOTPRINT bounded rather than the engine count —
        # engine_bytes_budget=0 keeps the pure count rule
        self._engines = LRUCache(capacity, byte_budget=engine_bytes_budget)
        self._memplans = LRUCache(capacity)
        self._lock = threading.Lock()
        self.stats: Dict[str, Any] = {"compiled": [], "engine_memory": []}
        self._mem_measured: Dict[Any, Dict[str, Any]] = {}

    def _build_model(self, hw: Tuple[int, int], precision: str, model: str):
        if self._make_model_arity < 3 and model != DEFAULT_MODEL:
            raise ValueError(
                f"make_model {self.make_model!r} is not model-aware; a "
                f"model-zoo factory needs make_model(hw, precision, "
                f"model) to build {model!r} engines"
            )
        if self._make_model_arity < 2:
            if precision != "f32":
                raise ValueError(
                    f"make_model {self.make_model!r} takes only (hw); a "
                    f"precision-aware factory needs make_model(hw, "
                    f"precision) to build {precision!r} engines"
                )
            return self.make_model(hw)
        if self._make_model_arity < 3:
            return self.make_model(hw, precision)
        return self.make_model(hw, precision, model)

    # -- model / param caches --------------------------------------------------
    def model(self, hw: Tuple[int, int], precision: str = "f32",
              model: str = DEFAULT_MODEL):
        hw = tuple(hw)
        check_precision(precision)
        check_model(model)
        with self._lock:
            m = self._models.get((hw, precision, model))
            if m is None:
                m = self._build_model(hw, precision, model)
                self._models.put((hw, precision, model), m)
            return m

    def params(self, hw: Tuple[int, int], precision: str = "f32",
               model: str = DEFAULT_MODEL):
        """Parameters for one plane — deterministic (PRNGKey(0)), so an
        LRU-evicted entry rebuilds identically.  The bfp entry is the
        f32 entry run through the bfp model's ``normalize_weights``
        (paper Fig. 4: BN fold + BFP weight normalization) — one weight
        set under both numerics.  Per model: heads differ in parameter
        trees, so the cache keys on (hw, precision, model)."""
        hw = tuple(hw)
        check_precision(precision)
        check_model(model)
        model_obj = self.model(hw, precision, model)
        raw = self.params(hw, "f32", model) if precision != "f32" else None
        with self._lock:
            p = self._params.get((hw, precision, model))
            if p is None:
                p = (model_obj.init_params(jax.random.PRNGKey(0))
                     if precision == "f32"
                     else model_obj.normalize_weights(raw))
                self._params.put((hw, precision, model), p)
            return p

    def memplan(self, hw: Tuple[int, int], precision: str = "f32",
                model: str = DEFAULT_MODEL):
        """The static memory plan (core.memplan.MemPlan) of the program
        assembled at ``hw`` — cached per (hw, precision, model).  Byte
        accounting follows the precision's compute dtype: f32 activations
        are 4 bytes, bfp serving stores fp16 between layers (2)."""
        from repro.core.memplan import plan_program

        hw = tuple(hw)
        check_precision(precision)
        check_model(model)
        key = (hw, precision, model)
        plan = self._memplans.get(key)
        if plan is None:
            prog = self.model(hw, precision, model).program
            plan = plan_program(
                prog, dtype_bytes=2 if precision == "bfp" else 4
            )
            self._memplans.put(key, plan)
        return plan

    def engine_weight_bytes(self, hw: Tuple[int, int], batch: int,
                            precision: str = "f32",
                            model: str = DEFAULT_MODEL) -> int:
        """Planned activation footprint of one compiled engine — the
        byte weight its LRU entry carries."""
        return int(self.memplan(hw, precision, model).peak_bytes) * int(batch)

    def measure_engine_memory(self, hw: Tuple[int, int], batch: int,
                              plan: "ExecutionPlan", precision: str = "f32",
                              model: str = DEFAULT_MODEL) -> Dict[str, Any]:
        """AOT-compile one engine shape and read the backend's buffer
        assignment (launch/hlo_analysis.lowered_memory): temp / argument
        / output bytes.  Explicit opt-in — it compiles outside the
        serving engine cache, so a bench calling it pays one extra
        compile per shape.  Results are memoized and appended to
        ``stats["engine_memory"]`` (the metrics_snapshot gauge source)."""
        from repro.launch.hlo_analysis import lowered_memory

        hw = tuple(hw)
        key = (hw, int(batch), plan, precision, model)
        got = self._mem_measured.get(key)
        if got is not None:
            return got
        model_obj = self.model(hw, precision, model)
        params = self.params(hw, precision, model)
        c0 = model_obj.program.input_shape_chw[0]
        x_sds = jax.ShapeDtypeStruct((int(batch), hw[0], hw[1], c0),
                                     jnp.float32)
        vq_sds = jax.ShapeDtypeStruct((int(batch), 2), jnp.int32)
        raw = self._compile(hw, int(batch), plan, precision, model)
        stats = lowered_memory(raw, params, x_sds, vq_sds)
        row = {
            "hw": hw, "batch": int(batch), "plan": describe_plan(plan),
            "precision": precision, "model": model,
            "planned_peak_bytes": self.engine_weight_bytes(
                hw, batch, precision, model),
            **(stats or {}),
        }
        self._mem_measured[key] = row
        self.stats.setdefault("engine_memory", []).append(row)
        return row

    def deepest_stride(self, hw: Tuple[int, int], precision: str = "f32",
                       model: str = DEFAULT_MODEL) -> int:
        """Deepest cumulative stride of the program assembled at ``hw``
        (architecture property — plane-independent for divisible planes)."""
        prog = self.model(tuple(hw), precision, model).program
        return max(hw[0] // max(h, 1) for h, _, _ in prog.addr_shapes.values())

    # -- engines ---------------------------------------------------------------
    def plan_fn(self, hw: Tuple[int, int], batch: int,
                plan: ExecutionPlan, precision: str = "f32",
                model: str = DEFAULT_MODEL) -> Callable:
        """The compiled engine for one (bucket, batch, plan, precision,
        model) key — a precision or model change is a different engine,
        never a cache hit on the other numerics or head."""
        check_precision(precision)
        check_model(model)
        key = (tuple(hw), int(batch), plan, precision, model)
        fn = self._engines.get(key)
        if fn is not None:
            return fn
        fn = self._compile(tuple(hw), int(batch), plan, precision, model)
        if self.book is not None:
            fn = self._timed(fn, tuple(hw), int(batch), plan_kind(plan),
                             precision, model)
        self.stats["compiled"].append(
            {"hw": tuple(hw), "batch": int(batch),
             "plan": describe_plan(plan), "precision": precision,
             "model": model}
        )
        try:
            weight = self.engine_weight_bytes(hw, batch, precision, model)
        except Exception:
            weight = 0          # planning must never block serving
        self._engines.put(key, fn, weight=weight)
        return fn

    def _timed(self, fn: Callable, hw, batch: int, kind: str,
               precision: str = "f32",
               model: str = DEFAULT_MODEL) -> Callable:
        """Record each engine call's wall into the telemetry book.
        This measures the DISPATCH side only — engines return pending
        arrays, so blocking here would serialize the async pipeline."""
        def timed(params, x, valid_q):
            t0 = time.perf_counter()
            out = fn(params, x, valid_q)
            self.book.record_step(hw, batch, kind,
                                  time.perf_counter() - t0,
                                  stage="dispatch", precision=precision,
                                  model=model)
            return out

        return timed

    def _tail(self, model_obj, out, valid_q):
        """The model's serving tail: named maps -> (*payload, converged).
        Zoo models carry a DetectionHead that owns the tail (CC labeling
        for segmentation heads, valid-region masking for regression
        heads); headless legacy models get the PixelLink CC tail."""
        head = getattr(model_obj, "head", None)
        if head is not None:
            return head.tail(self, out, valid_q)
        return self._label_tail(out["score"], out["links"], valid_q)

    def label_tail(self, score, links, valid_q):
        """Public CC-tail entry point for DetectionHead.tail
        implementations (the shared log-hop labeling machinery)."""
        return self._label_tail(score, links, valid_q)

    def _label_tail(self, score, links, valid_q):
        """Batched CC labeling tail -> (labels, converged) with the
        per-image (N,) convergence flag (iters stay internal)."""
        from repro.models.fcn import postprocess as pp

        h, w = score.shape[1:]
        mask = (
            (jnp.arange(h)[None, :, None] < valid_q[:, 0, None, None])
            & (jnp.arange(w)[None, None, :] < valid_q[:, 1, None, None])
        )
        if self.cc_pallas:
            from repro.kernels.cc_label import cc_label_pallas

            labels, _, converged = cc_label_pallas(
                score, links, self.score_thr, self.link_thr,
                valid_mask=mask, return_stats=True,
            )
        else:
            labels, _, converged = pp.cc_label_batched(
                score, links, self.score_thr, self.link_thr,
                valid_mask=mask, return_stats=True,
            )
        return labels, converged

    def boxes_fn(self, hw: Tuple[int, int], batch: int,
                 capacity: int) -> Callable:
        """Compiled on-device box extraction for one (bucket, batch)
        shape: ``fn(labels (N, h, w) int32) -> (rows, counts)`` with
        ``rows`` (N, capacity + 1, 6) and ``counts`` (N,) — the compact
        D2H payload of the device postprocess path (postprocess
        ``boxes_from_labels_batched_jax``).  Cached in the engine LRU
        alongside the plan fns (distinct key namespace)."""
        from repro.models.fcn import postprocess as pp

        key = ("boxes", tuple(hw), int(batch), int(capacity))
        fn = self._engines.get(key)
        if fn is not None:
            return fn
        fn = jax.jit(functools.partial(
            pp.boxes_from_labels_batched_jax, capacity=int(capacity)
        ))
        self.stats.setdefault("boxes_compiled", []).append(
            {"hw": tuple(hw), "batch": int(batch),
             "capacity": int(capacity)}
        )
        self._engines.put(key, fn)
        return fn

    def _compile(self, hw, batch, plan, precision: str = "f32",
                 model: str = DEFAULT_MODEL) -> Callable:
        if isinstance(plan, SingleDevice):
            return self._compile_single(hw, precision, model)
        if isinstance(plan, DataParallel):
            return self._compile_data_parallel(hw, batch, plan, precision,
                                               model)
        if isinstance(plan, RowBand):
            return self._compile_row_band(hw, plan, precision, model)
        if isinstance(plan, GridPlan):
            return self._compile_grid(hw, batch, plan, precision, model)
        raise TypeError(f"unknown execution plan {plan!r}")

    def _compile_single(self, hw, precision: str = "f32",
                        model: str = DEFAULT_MODEL) -> Callable:
        model_obj = self.model(hw, precision, model)

        def run(params, x, valid_q):
            out = model_obj.apply(params, x)
            return self._tail(model_obj, out, valid_q)

        return jax.jit(run, donate_argnums=_donate_argnums())

    def _compile_data_parallel(self, hw, batch, plan,
                               precision: str = "f32",
                               model: str = DEFAULT_MODEL) -> Callable:
        n = mesh_axis_sizes(plan.mesh).get(plan.axis)
        if n is None:
            raise ValueError(
                f"mesh {plan.mesh.axis_names} has no axis {plan.axis!r}"
            )
        if batch % n:
            raise ValueError(
                f"batch {batch} not divisible by {plan.axis}={n}; round "
                f"with plan_batch_multiple()"
            )
        model_obj = self.model(hw, precision, model)
        specs = fcn_activation_specs(batch_axis=plan.axis)
        head = getattr(model_obj, "head", None)
        # per-payload out specs: rank-3 payloads (label/score planes)
        # shard like labels, rank-4 (vector maps) like links
        ranks = getattr(head, "payload_ranks", (3,))
        payload_specs = tuple(
            specs["labels"] if r == 3 else specs["links"] for r in ranks
        )

        def shard(params, x, valid_q):
            out = model_obj.apply(params, x)
            return self._tail(model_obj, out, valid_q)

        return jax.jit(shard_map_compat(
            shard, plan.mesh,
            in_specs=(P(), specs["image"], P(plan.axis)),
            out_specs=(*payload_specs, P(plan.axis)),
        ), donate_argnums=_donate_argnums())

    def _compile_row_band(self, hw, plan, precision: str = "f32",
                          model: str = DEFAULT_MODEL) -> Callable:
        n = mesh_axis_sizes(plan.mesh).get(plan.axis)
        if n is None:
            raise ValueError(
                f"mesh {plan.mesh.axis_names} has no axis {plan.axis!r}"
            )
        bands = plan.bands or n
        if bands != n:
            raise ValueError(
                f"bands={plan.bands} must equal mesh axis {plan.axis}={n}"
            )
        return self._compile_banded(plan.mesh, hw, bands, plan.axis,
                                    precision=precision, model=model)

    def _compile_banded(self, mesh, hw, bands: int, model_axis: str,
                        batch_axis=None, precision: str = "f32",
                        model: str = DEFAULT_MODEL) -> Callable:
        """The shared row-banded engine: each device runs the SAME
        program assembled at the band plane, and every spatial layer
        halo-exchanges its own boundary rows along ``model_axis``
        (FCNEngine._spatial_banded), so outputs are exact per band.
        With ``batch_axis`` the batch dim is sharded too (GridPlan);
        halo exchange still moves along ``model_axis`` only."""
        W = hw[1]
        band_h = self._band_height(hw, bands, precision, model)
        model_obj = self.model(hw, precision, model)
        band_model = (model_obj.for_plane((band_h, W))
                      if hasattr(model_obj, "for_plane")
                      else self._build_model((band_h, W), precision, model))
        ctx = _BandCtx(model_axis, bands)
        specs = fcn_activation_specs(
            batch_axis=batch_axis, rows_axis=model_axis
        )
        head = getattr(model_obj, "head", None)
        # the shard body returns the head's named maps; rank-3 maps
        # (per-pixel scalars) shard like score, rank-4 like links
        maps = getattr(head, "maps", (("score", 3), ("links", 4)))
        map_specs = tuple(
            specs["score"] if r == 3 else specs["links"] for _, r in maps
        )

        def shard(params, x):
            out = band_model.apply(params, x, band_ctx=ctx)
            return tuple(out[n] for n, _ in maps)

        sm = shard_map_compat(
            shard, mesh,
            in_specs=(P(), specs["image"]),
            out_specs=map_specs,
        )

        def run(params, x, valid_q):
            out = dict(zip((n for n, _ in maps), sm(params, x)))
            return self._tail(model_obj, out, valid_q)

        return jax.jit(run, donate_argnums=_donate_argnums())

    def _band_height(self, hw, bands: int, precision: str = "f32",
                     model: str = DEFAULT_MODEL) -> int:
        """Validated per-band height for splitting plane ``hw`` into
        ``bands`` rows: the band must divide evenly through the whole
        stride pyramid so every device's local rows stay integral at the
        deepest scale (``H % (bands * deepest_stride) == 0``)."""
        H, _ = hw
        if H % bands:
            raise ValueError(f"H={H} not divisible into {bands} bands")
        band_h = H // bands
        deepest = self.deepest_stride(hw, precision, model)
        if band_h % deepest:
            raise ValueError(
                f"band height {band_h} must be a multiple of the deepest "
                f"cumulative stride {deepest} (H={H}, bands={bands})"
            )
        return band_h

    def _compile_grid(self, hw, batch, plan: GridPlan,
                      precision: str = "f32",
                      model: str = DEFAULT_MODEL) -> Callable:
        """DataParallel x RowBand composed in one shard_map: batch over
        ``data_axis``, rows over ``model_axis``, per-layer halo exchange
        along ``model_axis`` only."""
        sizes = mesh_axis_sizes(plan.mesh)
        dn = sizes.get(plan.data_axis)
        mn = sizes.get(plan.model_axis)
        for ax, n in ((plan.data_axis, dn), (plan.model_axis, mn)):
            if n is None:
                raise ValueError(
                    f"mesh {plan.mesh.axis_names} has no axis {ax!r}"
                )
        if plan.data_axis == plan.model_axis:
            raise ValueError(
                f"grid axes must differ, got {plan.data_axis!r} twice"
            )
        if batch % dn:
            raise ValueError(
                f"batch {batch} not divisible by {plan.data_axis}={dn}; "
                f"round with plan_batch_multiple()"
            )
        bands = plan.bands or mn
        if bands != mn:
            raise ValueError(
                f"bands={plan.bands} must equal mesh axis "
                f"{plan.model_axis}={mn}"
            )
        return self._compile_banded(
            plan.mesh, hw, bands, plan.model_axis,
            batch_axis=plan.data_axis, precision=precision, model=model,
        )

    # -- introspection ---------------------------------------------------------
    @property
    def engines(self) -> LRUCache:
        return self._engines

    def __len__(self) -> int:
        return len(self._engines)
