"""Collective building blocks used inside shard_map regions.

``compressed_psum`` — BFP-compressed gradient all-reduce (paper C2 applied
to the interconnect): all_gather int8 mantissas + per-block exponents,
dequantize + reduce locally.  Versus an f32 psum this moves ~4x fewer
bytes (~0.27x, exponents included); at 8 bits the EF residual in
optim.grad_utils keeps the update sequence unbiased.

``latency_hiding_flags`` — the XLA flags the launcher sets so the SPMD
scheduler overlaps these collectives with compute (the paper's C4
module-level overlap, compiler edition).

``halo_exchange`` — the paper's §IV.B row-band overlap rows as a
collective: each device holds a horizontal band of an image plane and
receives the boundary rows it needs from its spatial neighbors (ppermute
when the halo fits in one neighbor band, all_gather + slice when the
receptive field spans several bands).  The row-band ExecutionPlan
(runtime/executor.py) builds on it.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import bfp as bfp_lib

F32 = jnp.float32


def compressed_psum(
    x: jax.Array,
    axis_name: str,
    *,
    mantissa_bits: int = 7,
    block_size: int = 32,
) -> jax.Array:
    """Sum x across `axis_name` moving quantized bytes (shard_map only)."""
    q = bfp_lib.quantize(
        x.astype(F32), block_size=block_size, mantissa_bits=mantissa_bits,
        axis=-1, rounding="nearest",
    )
    m8 = q.mantissa.astype(jnp.int8 if mantissa_bits <= 7 else jnp.int16)
    e8 = q.exponent.astype(jnp.int32)
    # the bytes on the wire: int8 mantissas + one exponent per block
    all_m = jax.lax.all_gather(m8, axis_name)       # (n, ...) int8
    all_e = jax.lax.all_gather(e8, axis_name)
    n = all_m.shape[0]

    def deq(i, acc):
        t = bfp_lib.BFPTensor(
            all_m[i].astype(jnp.int32), all_e[i],
            mantissa_bits, block_size, x.ndim - 1,
        )
        return acc + bfp_lib.dequantize(t)

    acc = jax.lax.fori_loop(
        0, n, deq, jnp.zeros(x.shape, F32)
    )
    return acc.astype(x.dtype)


def psum_bytes_model(
    nbytes_f32: int, n_devices: int, *, compressed: bool,
    mantissa_bits: int = 7, block_size: int = 32,
) -> Tuple[int, int]:
    """Napkin-math helper used by the perf log: (bytes_f32_ring,
    bytes_compressed) per device for an all-reduce of a tensor."""
    ring = 2 * (n_devices - 1) * nbytes_f32 // n_devices
    mb = 1 if mantissa_bits <= 7 else 2
    q = nbytes_f32 // 4 * mb + nbytes_f32 // 4 // block_size
    gather = (n_devices - 1) * q // n_devices
    return ring, gather


def halo_exchange(
    x: jax.Array,
    axis_name: str,
    halo: int,
    *,
    axis: int = 1,
    axis_size: int = 0,
) -> jax.Array:
    """Extend a row-band shard by ``halo`` rows from each neighbor.

    Must run inside a shard_map region where ``x`` is the local band of a
    plane split along ``axis`` over mesh axis ``axis_name``.  Returns the
    band extended to ``band + 2*halo`` rows; positions beyond the true
    plane border are zero (matching SAME-padding semantics, so a banded
    conv stack equals the full-plane one — see core.rowband).

    When ``halo`` fits inside one neighbor band the exchange is two
    ppermutes of edge slices (the paper's load-next-band-while-computing
    overlap rows); otherwise it degrades to an all_gather + local slice.
    ``axis_size`` may be passed to avoid a psum when statically known.

    ``axis_name`` must be ONE named mesh axis: on a multi-axis mesh
    (e.g. the 2-D data x model serving mesh of the GridPlan in
    runtime/executor.py) every collective here — ppermute, all_gather,
    axis_index — addresses positions along that axis only, so shards
    that differ on any *other* mesh axis never exchange rows (each
    data-parallel batch shard keeps its own plane).  A tuple of axis
    names would silently break that addressing (perm indices and
    axis_index would refer to the flattened product axis), so it is
    rejected up front.
    """
    if not isinstance(axis_name, str):
        raise TypeError(
            f"halo_exchange needs a single named mesh axis, got "
            f"{axis_name!r}; on a multi-axis mesh pass the band axis "
            f"only (rows are never exchanged across other axes)"
        )
    if halo <= 0:
        return x
    n = axis_size or jax.lax.psum(1, axis_name)
    band = x.shape[axis]
    idx = jax.lax.axis_index(axis_name)
    if n == 1:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (halo, halo)
        return jnp.pad(x, pad)
    if halo <= band:
        down = [(i, (i + 1) % n) for i in range(n)]   # band i -> i+1
        up = [(i, (i - 1) % n) for i in range(n)]     # band i -> i-1
        top = jax.lax.ppermute(          # my predecessor's bottom rows
            jax.lax.slice_in_dim(x, band - halo, band, axis=axis),
            axis_name, down,
        )
        bot = jax.lax.ppermute(          # my successor's top rows
            jax.lax.slice_in_dim(x, 0, halo, axis=axis),
            axis_name, up,
        )
        # zero the wrap-around halos at the true plane borders
        top = top * (idx > 0).astype(x.dtype)
        bot = bot * (idx < n - 1).astype(x.dtype)
        return jnp.concatenate([top, x, bot], axis=axis)
    # wide halo: reconstruct the plane, slice my extended band out of it
    full = jax.lax.all_gather(x, axis_name, axis=axis, tiled=True)
    pad = [(0, 0)] * x.ndim
    pad[axis] = (halo, halo)
    full = jnp.pad(full, pad)
    return jax.lax.dynamic_slice_in_dim(
        full, idx * band, band + 2 * halo, axis=axis
    )


def latency_hiding_flags() -> str:
    return " ".join([
        "--xla_tpu_enable_async_collective_fusion=true",
        "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
        "--xla_tpu_overlap_compute_collective_tc=true",
        "--xla_tpu_enable_async_all_gather=true",
    ])
