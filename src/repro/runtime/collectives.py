"""Collective building blocks used inside shard_map regions.

``compressed_psum`` — BFP-compressed gradient all-reduce (paper C2 applied
to the interconnect): all_gather int8 mantissas + per-block exponents,
dequantize + reduce locally.  Versus an f32 psum this moves ~4x fewer
bytes (~0.27x, exponents included); at 8 bits the EF residual in
optim.grad_utils keeps the update sequence unbiased.

``latency_hiding_flags`` — the XLA flags the launcher sets so the SPMD
scheduler overlaps these collectives with compute (the paper's C4
module-level overlap, compiler edition).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import bfp as bfp_lib

F32 = jnp.float32


def compressed_psum(
    x: jax.Array,
    axis_name: str,
    *,
    mantissa_bits: int = 7,
    block_size: int = 32,
) -> jax.Array:
    """Sum x across `axis_name` moving quantized bytes (shard_map only)."""
    q = bfp_lib.quantize(
        x.astype(F32), block_size=block_size, mantissa_bits=mantissa_bits,
        axis=-1, rounding="nearest",
    )
    m8 = q.mantissa.astype(jnp.int8 if mantissa_bits <= 7 else jnp.int16)
    e8 = q.exponent.astype(jnp.int32)
    # the bytes on the wire: int8 mantissas + one exponent per block
    all_m = jax.lax.all_gather(m8, axis_name)       # (n, ...) int8
    all_e = jax.lax.all_gather(e8, axis_name)
    n = all_m.shape[0]

    def deq(i, acc):
        t = bfp_lib.BFPTensor(
            all_m[i].astype(jnp.int32), all_e[i],
            mantissa_bits, block_size, x.ndim - 1,
        )
        return acc + bfp_lib.dequantize(t)

    acc = jax.lax.fori_loop(
        0, n, deq, jnp.zeros(x.shape, F32)
    )
    return acc.astype(x.dtype)


def psum_bytes_model(
    nbytes_f32: int, n_devices: int, *, compressed: bool,
    mantissa_bits: int = 7, block_size: int = 32,
) -> Tuple[int, int]:
    """Napkin-math helper used by the perf log: (bytes_f32_ring,
    bytes_compressed) per device for an all-reduce of a tensor."""
    ring = 2 * (n_devices - 1) * nbytes_f32 // n_devices
    mb = 1 if mantissa_bits <= 7 else 2
    q = nbytes_f32 // 4 * mb + nbytes_f32 // 4 // block_size
    gather = (n_devices - 1) * q // n_devices
    return ring, gather


def latency_hiding_flags() -> str:
    return " ".join([
        "--xla_tpu_enable_async_collective_fusion=true",
        "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
        "--xla_tpu_overlap_compute_collective_tc=true",
        "--xla_tpu_enable_async_all_gather=true",
    ])
