"""Pipeline parallelism over a mesh axis (GPipe-schedule via shard_map +
ppermute), DESIGN.md §5.

The paper's C4 module-level multithreading — independent compute modules
working on different inputs concurrently — is exactly a pipeline; at pod
scale the stages map onto the "pod" axis so the only cross-pod (DCN-class)
traffic is one microbatch activation per tick instead of full gradient
all-reduces.

Schedule: M microbatches through S stages in M + S - 1 ticks; every tick
each stage runs its block stack on the activation it holds, then the ring
ppermute shifts activations one stage forward.  jax.grad through the loop
replays it in reverse (ppermute transposes to the inverse permutation),
giving the backward pipeline for free; per-stage remat keeps the
activation footprint at O(M) boundary tensors instead of O(M*L_stage).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

F32 = jnp.float32


def split_stages(stacked_params: Any, n_stages: int) -> Any:
    """(L, ...) stacked layer params -> (S, L/S, ...)."""
    def f(a):
        L = a.shape[0]
        assert L % n_stages == 0, f"{L} layers % {n_stages} stages"
        return a.reshape(n_stages, L // n_stages, *a.shape[1:])
    return jax.tree_util.tree_map(f, stacked_params)


def pipeline_apply(
    mesh: Mesh,
    stage_axis: str,
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    staged_params: Any,             # (S, L/S, ...) leaves, sharded dim0
    x: jax.Array,                   # (M, mb, seq, d) microbatched input
    *,
    remat: bool = True,
) -> jax.Array:
    """Run the stage-stacked layer scan as a pipeline; returns (M, mb, s, d).

    ``layer_fn(params_one_layer, h) -> h`` is scanned over the local
    stage's layers; activations ring-shift along `stage_axis`.
    """
    S = dict(zip(mesh.axis_names, mesh.devices.shape))[stage_axis]
    M = x.shape[0]

    def stage_fn(local_params, h):
        def body(c, lp):
            return layer_fn(lp, c), None
        f = jax.checkpoint(
            lambda c, lp: (layer_fn(lp, c), None)
        ) if remat else body
        out, _ = jax.lax.scan(f, h, local_params)
        return out

    perm_fwd = [(i, (i + 1) % S) for i in range(S)]

    def shard_body(local_params, xm):
        # local_params: (1, L/S, ...) on each stage; xm: (M, mb, s, d) full
        lp = jax.tree_util.tree_map(lambda a: a[0], local_params)
        idx = jax.lax.axis_index(stage_axis)
        mb_shape = xm.shape[1:]
        buf = jnp.zeros(mb_shape, xm.dtype)          # activation in flight
        outs = jnp.zeros((M,) + mb_shape, xm.dtype)
        n_ticks = M + S - 1

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (while t < M); others use the
            # ring buffer
            mb_idx = jnp.minimum(t, M - 1)
            inject = jnp.logical_and(idx == 0, t < M)
            h_in = jnp.where(inject, xm[mb_idx], buf)
            h_out = stage_fn(lp, h_in)
            # last stage emits microbatch t - (S - 1)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            emit = jnp.logical_and(idx == S - 1, t >= S - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs,
                jnp.where(emit, h_out, outs[out_idx]),
                out_idx, axis=0,
            )
            buf = jax.lax.ppermute(h_out, stage_axis, perm_fwd)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # broadcast the last stage's outputs to all stages so the head can
        # be computed data-parallel afterwards
        if S > 1:
            outs = jax.lax.all_gather(outs, stage_axis)[S - 1]
        return outs

    in_specs = (
        jax.tree_util.tree_map(lambda _: P(stage_axis), staged_params),
        P(),
    )
    fn = jax.shard_map(
        shard_body, mesh=mesh, in_specs=in_specs, out_specs=P(),
        check_vma=False,
    )
    return fn(staged_params, x)


def microbatch(x: jax.Array, n_micro: int) -> jax.Array:
    B = x.shape[0]
    assert B % n_micro == 0
    return x.reshape(n_micro, B // n_micro, *x.shape[1:])
