"""Pipeline parallelism over a mesh axis (GPipe-schedule via shard_map +
ppermute), DESIGN.md §5.

The paper's C4 module-level multithreading — independent compute modules
working on different inputs concurrently — is exactly a pipeline; at pod
scale the stages map onto the "pod" axis so the only cross-pod (DCN-class)
traffic is one microbatch activation per tick instead of full gradient
all-reduces.

Schedule: M microbatches through S stages in M + S - 1 ticks; every tick
each stage runs its block stack on the activation it holds, then the ring
ppermute shifts activations one stage forward.  jax.grad through the loop
replays it in reverse (ppermute transposes to the inverse permutation),
giving the backward pipeline for free; per-stage remat keeps the
activation footprint at O(M) boundary tensors instead of O(M*L_stage).
"""
from __future__ import annotations

import functools
import queue
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

F32 = jnp.float32


class HostPipeline:
    """The paper's C4 module-level multithreading on host: a chain of
    stages connected by bounded queues, one thread per stage, so stage i
    of item n overlaps stage i+1 of item n-1 (serve.py's preprocess /
    device-infer / CC-postprocess chain is the motivating instance).

    ``stages`` are ``fn(item) -> item``; ``run`` preserves input order.
    A stage exception propagates to the caller and stops the pipeline.
    """

    def __init__(self, stages: Sequence[Callable[[Any], Any]],
                 maxsize: int = 4):
        if not stages:
            raise ValueError("need at least one stage")
        self.stages = list(stages)
        self.maxsize = maxsize

    def run(self, items: Sequence[Any]) -> List[Any]:
        n_stages = len(self.stages)
        qs = [queue.Queue(maxsize=self.maxsize) for _ in range(n_stages + 1)]
        results: List[Any] = [None] * len(items)
        errors: List[BaseException] = []
        abort = threading.Event()        # a stage error must unwind EVERY
                                         # thread, not just downstream ones

        def _put(q, item) -> bool:
            while not abort.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def _get(q):
            """Item, or None sentinel, or False once aborted+drained."""
            while True:
                try:
                    return q.get(timeout=0.05)
                except queue.Empty:
                    if abort.is_set():
                        return False

        def feeder():
            for i, item in enumerate(items):
                if not _put(qs[0], (i, item)):
                    return
            _put(qs[0], None)

        def worker(si: int):
            fn = self.stages[si]
            while True:
                got = _get(qs[si])
                if got is False:
                    return
                if got is None:
                    _put(qs[si + 1], None)
                    return
                i, item = got
                try:
                    out = fn(item)
                except Exception as e:
                    errors.append(e)
                    abort.set()
                    return
                if not _put(qs[si + 1], (i, out)):
                    return

        def sink():
            while True:
                got = _get(qs[n_stages])
                if got is False or got is None:
                    return
                i, item = got
                results[i] = item

        threads = [threading.Thread(target=feeder, daemon=True)]
        threads += [
            threading.Thread(target=worker, args=(si,), daemon=True)
            for si in range(n_stages)
        ]
        threads.append(threading.Thread(target=sink, daemon=True))
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        return results


def split_stages(stacked_params: Any, n_stages: int) -> Any:
    """(L, ...) stacked layer params -> (S, L/S, ...)."""
    def f(a):
        L = a.shape[0]
        assert L % n_stages == 0, f"{L} layers % {n_stages} stages"
        return a.reshape(n_stages, L // n_stages, *a.shape[1:])
    return jax.tree_util.tree_map(f, stacked_params)


def pipeline_apply(
    mesh: Mesh,
    stage_axis: str,
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    staged_params: Any,             # (S, L/S, ...) leaves, sharded dim0
    x: jax.Array,                   # (M, mb, seq, d) microbatched input
    *,
    remat: bool = True,
) -> jax.Array:
    """Run the stage-stacked layer scan as a pipeline; returns (M, mb, s, d).

    ``layer_fn(params_one_layer, h) -> h`` is scanned over the local
    stage's layers; activations ring-shift along `stage_axis`.
    """
    S = dict(zip(mesh.axis_names, mesh.devices.shape))[stage_axis]
    M = x.shape[0]

    def stage_fn(local_params, h):
        def body(c, lp):
            return layer_fn(lp, c), None
        f = jax.checkpoint(
            lambda c, lp: (layer_fn(lp, c), None)
        ) if remat else body
        out, _ = jax.lax.scan(f, h, local_params)
        return out

    perm_fwd = [(i, (i + 1) % S) for i in range(S)]

    def shard_body(local_params, xm):
        # local_params: (1, L/S, ...) on each stage; xm: (M, mb, s, d) full
        lp = jax.tree_util.tree_map(lambda a: a[0], local_params)
        idx = jax.lax.axis_index(stage_axis)
        mb_shape = xm.shape[1:]
        buf = jnp.zeros(mb_shape, xm.dtype)          # activation in flight
        outs = jnp.zeros((M,) + mb_shape, xm.dtype)
        n_ticks = M + S - 1

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (while t < M); others use the
            # ring buffer
            mb_idx = jnp.minimum(t, M - 1)
            inject = jnp.logical_and(idx == 0, t < M)
            h_in = jnp.where(inject, xm[mb_idx], buf)
            h_out = stage_fn(lp, h_in)
            # last stage emits microbatch t - (S - 1)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            emit = jnp.logical_and(idx == S - 1, t >= S - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs,
                jnp.where(emit, h_out, outs[out_idx]),
                out_idx, axis=0,
            )
            buf = jax.lax.ppermute(h_out, stage_axis, perm_fwd)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # broadcast the last stage's outputs to all stages so the head can
        # be computed data-parallel afterwards
        if S > 1:
            outs = jax.lax.all_gather(outs, stage_axis)[S - 1]
        return outs

    in_specs = (
        jax.tree_util.tree_map(lambda _: P(stage_axis), staged_params),
        P(),
    )
    from repro.runtime.sharding import shard_map_compat

    fn = shard_map_compat(
        shard_body, mesh=mesh, in_specs=in_specs, out_specs=P(),
        check=False,
    )
    return fn(staged_params, x)


def microbatch(x: jax.Array, n_micro: int) -> jax.Array:
    B = x.shape[0]
    assert B % n_micro == 0
    return x.reshape(n_micro, B // n_micro, *x.shape[1:])
