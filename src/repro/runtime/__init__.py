from . import collectives, executor, fault_tolerance, pipeline, sharding

__all__ = ["collectives", "executor", "fault_tolerance", "pipeline",
           "sharding"]
