from . import collectives, fault_tolerance, pipeline, sharding

__all__ = ["collectives", "fault_tolerance", "pipeline", "sharding"]
