from . import (
    collectives,
    executor,
    fault_tolerance,
    pipeline,
    sharding,
    telemetry,
)

__all__ = ["collectives", "executor", "fault_tolerance", "pipeline",
           "sharding", "telemetry"]
