"""Mesh-aware sharding rules (DESIGN.md §5).

Parameters carry their own axis preferences (models.lm.params.ParamMeta);
this module resolves *activation* and *input* shardings:

  * batch over ("pod", "data") when divisible, falling back to "data",
    then to replication (long_500k batch=1);
  * when the batch cannot use an axis, long sequences pick it up instead
    (sequence sharding — the LM analogue of the paper's §IV.B row-wise
    image segmentation);
  * logits/activations constrained so the vocab-TP lm_head output stays
    sharded over "model";
  * FCN serving activations (NHWC image planes and the score/link/label
    maps derived from them): batch over "data" for data-parallel plans,
    rows over "model" for row-band plans, or BOTH AT ONCE for the 2-D
    GridPlan (batch_axis="data" + rows_axis="model" compose into one
    P("data", "model", ...) layout) — fcn_activation_specs is consumed
    by runtime.executor's ExecutionPlans; fcn_batch_axis is the
    divisibility rule for callers picking a batch axis themselves.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs, *,
                     check: bool = False):
    """Version-portable shard_map: ``jax.shard_map(check_vma=...)`` on
    newer JAX, ``jax.experimental.shard_map.shard_map(check_rep=...)``
    on 0.4.x."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check,
    )


def batch_seq_spec(
    mesh: Mesh, batch: int, seq: Optional[int] = None
) -> P:
    """Spec for (batch, seq, ...) inputs: shard batch as much as divisible,
    give leftover data-parallel capacity to the sequence axis."""
    sizes = mesh_axis_sizes(mesh)
    batch_axes = []
    seq_axes = []
    remaining = batch
    for ax in ("pod", "data"):
        if ax not in sizes:
            continue
        if remaining % sizes[ax] == 0 and remaining >= sizes[ax]:
            batch_axes.append(ax)
            remaining //= sizes[ax]
        elif seq is not None and seq % sizes[ax] == 0:
            seq_axes.append(ax)
    b = tuple(batch_axes) if batch_axes else None
    s = tuple(seq_axes) if seq_axes else None
    if seq is None:
        return P(b if b is None or len(batch_axes) > 1 else batch_axes[0])
    return P(
        b if b is None or len(batch_axes) > 1 else batch_axes[0],
        s if s is None or len(seq_axes) > 1 else seq_axes[0],
    )


def input_shardings(
    mesh: Mesh, specs: Dict[str, jax.ShapeDtypeStruct]
) -> Dict[str, NamedSharding]:
    """NamedShardings for the input_specs() dict of a shape cell."""
    out = {}
    for name, sds in specs.items():
        if sds.ndim == 0:
            out[name] = NamedSharding(mesh, P())
        elif sds.ndim == 1:
            out[name] = NamedSharding(
                mesh, batch_seq_spec(mesh, sds.shape[0])
            )
        else:
            spec = batch_seq_spec(mesh, sds.shape[0], sds.shape[1])
            # pad spec with None for trailing dims
            out[name] = NamedSharding(mesh, spec)
    return out


def logits_spec(mesh: Mesh, batch: int, seq: int) -> P:
    bs = batch_seq_spec(mesh, batch, seq)
    parts = list(bs) + [None] * (3 - len(bs))
    sizes = mesh_axis_sizes(mesh)
    if "model" in sizes:
        parts[2] = "model"
    return P(*parts)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def fcn_batch_axis(mesh: Mesh, batch: int, axis: str = "data") -> Optional[str]:
    """The mesh axis an FCN batch can shard over, or None (replicate)."""
    n = mesh_axis_sizes(mesh).get(axis, 1)
    return axis if n > 1 and batch % n == 0 else None


def fcn_activation_specs(
    batch_axis: Optional[str] = None, rows_axis: Optional[str] = None
) -> Dict[str, P]:
    """PartitionSpecs for the FCN serving activations.

    NHWC inputs and the 1/4-scale maps share one layout decision: the
    batch dim over ``batch_axis`` (data-parallel plans, paper's batch
    level) and/or the row dim over ``rows_axis`` (row-band plans, paper
    §IV.B).  Keys: "image" (N,H,W,C), "score" (N,h,w), "links"
    (N,h,w,8), "labels" (N,h,w).
    """
    return {
        "image": P(batch_axis, rows_axis, None, None),
        "score": P(batch_axis, rows_axis, None),
        "links": P(batch_axis, rows_axis, None, None),
        "labels": P(batch_axis, rows_axis, None),
    }


def activation_constrainer(mesh: Mesh, global_batch: int,
                           seq_shard: bool = False):
    """Returns shard(x, kind) applying with_sharding_constraint to
    activations so SPMD propagation cannot silently replicate them (the
    18 GiB/layer lesson from the first tinyllama dry-run — EXPERIMENTS.md
    §Perf).

    kinds:
      "bld"      (B, L, D)     batch over pod/data axes
      "blhd"     (B, L, H, hd) + heads over "model" when divisible
      "ecd"      (E, cap, D)   experts over "model" when divisible
      "boundary" (B, L, D)     the residual stream between blocks; with
                 ``seq_shard`` it is L-sharded over "model" (Megatron-SP
                 style) so remat-saved activations shrink by the TP degree
                 — the §Perf memory-term lever for train cells
    """
    sizes = mesh_axis_sizes(mesh)
    batch_axes = []
    rem = global_batch
    for ax in ("pod", "data"):
        if ax in sizes and rem % sizes[ax] == 0 and rem >= sizes[ax]:
            batch_axes.append(ax)
            rem //= sizes[ax]
    b = tuple(batch_axes) if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None
    )
    model_n = sizes.get("model", 1)

    def shard(x, kind: str):
        if kind == "bld":
            spec = P(b, None, None)
        elif kind == "boundary":
            l_ok = (seq_shard and x.shape[1] % model_n == 0
                    and x.shape[1] >= model_n)
            spec = P(b, "model" if l_ok else None, None)
        elif kind == "blhd":
            h_ok = x.shape[2] % model_n == 0 and x.shape[2] >= model_n
            spec = P(b, None, "model" if h_ok else None, None)
        elif kind == "ecd":
            e_ok = x.shape[0] % model_n == 0 and x.shape[0] >= model_n
            spec = P("model" if e_ok else None, None, None)
        else:
            raise ValueError(kind)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec)
        )

    return shard
