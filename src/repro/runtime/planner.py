"""Cost-model plan routing — heterogeneous buckets per plan (ROADMAP).

The serving scheduler used to apply one fixed rule: over-tall images go
to the configured ``tall_plan``, everything else to the service default.
This module replaces that with a small analytic cost model so buckets of
different shapes in ONE service route to different ExecutionPlans
(runtime/executor.py): a FaSTExt-class small bucket stays on
:class:`~repro.runtime.executor.SingleDevice` (sharding overhead would
dominate), a batch-heavy bucket spreads over the mesh "data" axis, a
tall EAST-class plane row-bands over "model", and a tall *and*
batch-heavy bucket takes the composed :class:`GridPlan`.

Per-plan step cost is estimated from three terms:

  compute   per-device FLOPs (the plan's device grid divides the work)
            over achievable FLOP/s,
  halo      the bytes a row-banded device exchanges per step — per-layer
            boundary rows (core.rowband.program_band_costs, which
            mirrors FCNEngine._spatial_banded's halo rule) over ICI
            bandwidth,
  overhead  a fixed dispatch cost plus one collective-launch cost per
            sharded mesh axis — the term that keeps small planes on a
            single chip.

plus a batch-split occupancy effect: data-parallel plans must pad the
batch to a multiple of the axis size, so a batch of 1 on a 4-wide axis
pays full single-device compute *and* the sharding overhead.

The numbers are napkin-math (launch/mesh.py v5e-class constants by
default), not a measured roofline: what matters for routing is the
ORDER of the per-plan costs and where the crossovers sit, both of which
are monotone in the right directions — e.g. a taller plane can only move
further toward row-banded plans (compute grows with H, halo bytes do
not), which test_planner.py pins down.

Costs reach the router through the :class:`CostProvider` seam rather
than a ``CostParams`` default threaded everywhere:

  * :class:`AnalyticCost` — the closed-form model above, parameterized
    by one :class:`CostParams` (napkin defaults, or constants fitted by
    ``benchmarks/serve_bench.py --calibrate`` via
    runtime/telemetry.fit_cost_params);
  * :class:`MeasuredCost` — an overlay over a telemetry
    :class:`~repro.runtime.telemetry.CostBook`: once a
    (bucket, batch, plan_kind) combo has ``min_observations`` measured
    step times, routing uses the measured EWMA; unmeasured combos fall
    back to the analytic model.  Wired by STDService, this adapts
    routing online through the existing (bucket, batch, plan) engine
    LRU — no recompiles, the measured winner is just picked next flush.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Protocol, Tuple

from jax.sharding import Mesh

from repro.launch.mesh import (
    HBM_BW,
    ICI_BW_PER_LINK,
    N_ICI_LINKS,
    PEAK_FLOPS_BF16,
)
from repro.runtime.executor import (
    DEFAULT_MODEL,
    DataParallel,
    ExecutionPlan,
    GridPlan,
    RowBand,
    SingleDevice,
)
from repro.runtime.sharding import mesh_axis_sizes

PLAN_KINDS = ("single_device", "data_parallel", "row_band", "grid")
_BANDED = ("row_band", "grid")


@dataclasses.dataclass(frozen=True)
class PlanFeatures:
    """Per-bucket cost-model inputs, one image at the bucket plane."""

    flops: float                 # forward FLOPs per image
    halo_bytes: float            # bytes one band exchanges per image
    deepest_stride: int = 32     # cumulative stride of the deepest layer
    halo_layers: int = 0         # spatial layers that halo-exchange
                                 # (one ppermute pair each per step)
    act_bytes: float = 0.0       # planned peak activation bytes per image
                                 # (core.memplan drop-at-last-use peak);
                                 # 0 = unknown, the memory term vanishes


def features_for_program(program, deepest_stride: int,
                         *, dtype_bytes: int = 4,
                         mode: str = "optimized") -> PlanFeatures:
    """PlanFeatures from an assembled microcode program (shape walk,
    no device work).  ``mode`` must match the engine's execution mode so
    the upsample FLOPs count the path that actually runs (9-tap fused in
    "optimized", naive in "reference" — core.rowband)."""
    from repro.core.memplan import plan_program
    from repro.core.rowband import program_band_costs

    c = program_band_costs(program, dtype_bytes=dtype_bytes, mode=mode)
    plan = plan_program(program, dtype_bytes=dtype_bytes)
    return PlanFeatures(flops=c["flops"], halo_bytes=c["halo_bytes"],
                        deepest_stride=deepest_stride,
                        halo_layers=c["halo_layers"],
                        act_bytes=float(plan.peak_bytes))


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Hardware/runtime constants of the step-cost estimate.  Defaults
    are the v5e-class napkin numbers from launch/mesh.py with a 35%
    achievable-FLOPs derate."""

    peak_flops: float = 0.35 * PEAK_FLOPS_BF16
    ici_bw: float = ICI_BW_PER_LINK * N_ICI_LINKS
    dispatch_overhead_s: float = 50e-6      # per-step launch cost
    collective_overhead_s: float = 20e-6    # extra per sharded mesh axis
    halo_launch_s: float = 2e-6             # per halo-exchanging layer
                                            # (ppermute pair launch)
    hbm_bw: float = HBM_BW                  # activation traffic bandwidth
                                            # (memory term; act_bytes=0
                                            # features pay nothing)


def padded_batch(batch: int, data_n: int) -> int:
    """Batch after rounding up to the data-parallel divisibility rule."""
    return -(-batch // data_n) * data_n


def step_cost(features: PlanFeatures, kind: str, batch: int, *,
              data_n: int = 1, model_n: int = 1,
              params: Optional[CostParams] = None) -> float:
    """Estimated seconds for one engine step of ``batch`` images under
    plan ``kind`` on a (data_n, model_n) mesh (the analytic model —
    :class:`AnalyticCost` is its CostProvider wrapper)."""
    if kind not in PLAN_KINDS:
        raise ValueError(f"unknown plan kind {kind!r}")
    params = params if params is not None else CostParams()
    dn = data_n if kind in ("data_parallel", "grid") else 1
    mn = model_n if kind in _BANDED else 1
    local_b = padded_batch(batch, dn) // dn   # occupancy: padding runs too
    compute = features.flops * local_b / (mn * params.peak_flops)
    # memory term: the planned peak activation bytes stream through HBM
    # at least once per step (row-banding divides the plane, so a band
    # holds 1/mn of the footprint); small next to compute on these FCNs
    # but it keeps memory-heavy buckets honest in the ordering
    compute += features.act_bytes * local_b / (mn * params.hbm_bw)
    # wire bytes plus one ppermute-pair launch per halo-exchanging layer
    # — dozens of per-layer collectives per banded step, not one
    halo = ((features.halo_bytes * local_b / params.ici_bw
             + features.halo_layers * params.halo_launch_s)
            if mn > 1 else 0.0)
    overhead = (params.dispatch_overhead_s
                + params.collective_overhead_s * ((dn > 1) + (mn > 1)))
    return compute + halo + overhead


class CostProvider(Protocol):
    """The one seam routing reads costs through: estimated (or
    measured) seconds for one step of ``batch`` images of bucket ``hw``
    under plan ``kind`` on a (data_n, model_n) mesh.  ``hw`` rides
    along so measured providers can key their lookups; the analytic
    provider ignores it (features already encode the plane)."""

    def step_cost(self, features: PlanFeatures, hw: Tuple[int, int],
                  kind: str, batch: int, *, data_n: int,
                  model_n: int) -> float: ...


@dataclasses.dataclass(frozen=True)
class AnalyticCost:
    """Today's closed-form model as a CostProvider — the fallback for
    every combo nothing has measured yet.  ``params`` may be the napkin
    defaults or constants fitted by serve_bench --calibrate."""

    params: CostParams = dataclasses.field(default_factory=CostParams)

    def step_cost(self, features: PlanFeatures, hw: Tuple[int, int],
                  kind: str, batch: int, *, data_n: int,
                  model_n: int) -> float:
        return step_cost(features, kind, batch, data_n=data_n,
                         model_n=model_n, params=self.params)


class MeasuredCost:
    """Measured-step overlay: once ``book`` (a duck-typed
    runtime/telemetry.CostBook) holds at least ``min_observations``
    samples for an exact (hw, batch, kind) combo, its EWMA wall time IS
    the cost; anything unmeasured falls back to ``fallback`` (the
    analytic model).  Mixing is sound because both sides are plain
    seconds per step — the overlay just replaces an estimate with an
    observation, so routing adapts online without recompiles."""

    #: default observation floor before a measurement overrides the
    #: analytic estimate (one-off warmup/compile walls must not route)
    MIN_OBSERVATIONS = 3

    def __init__(self, book, fallback: Optional[CostProvider] = None, *,
                 min_observations: int = MIN_OBSERVATIONS,
                 stage: str = "step", precision: str = "f32",
                 model: str = DEFAULT_MODEL):
        if min_observations < 1:
            raise ValueError("min_observations must be >= 1")
        self.book = book
        self.fallback: CostProvider = (
            fallback if fallback is not None else AnalyticCost())
        self.min_observations = min_observations
        self.stage = stage
        # which numerics' walls this overlay reads — a bfp service must
        # route on bfp step times, never the f32 series — and which
        # detection model's (the heads' FLOP profiles differ)
        self.precision = precision
        self.model = model

    def step_cost(self, features: PlanFeatures, hw: Tuple[int, int],
                  kind: str, batch: int, *, data_n: int,
                  model_n: int) -> float:
        if self.book.step_count(
                hw, batch, kind, stage=self.stage,
                precision=self.precision,
                model=self.model) >= self.min_observations:
            measured = self.book.step_ewma(hw, batch, kind,
                                           stage=self.stage,
                                           precision=self.precision,
                                           model=self.model)
            if measured is not None:
                return measured
        return self.fallback.step_cost(features, hw, kind, batch,
                                       data_n=data_n, model_n=model_n)


def eligible_kinds(hw: Tuple[int, int], *, data_n: int, model_n: int,
                   deepest_stride: int) -> List[str]:
    """Plan kinds the mesh and bucket shape admit.  Row-banded kinds
    require real model-axis capacity AND the band-height invariant
    ``H % (bands * deepest_stride) == 0`` (runtime/executor.py enforces
    the same rule at compile time)."""
    kinds = ["single_device"]
    if data_n > 1:
        kinds.append("data_parallel")
    if model_n > 1 and hw[0] % (model_n * deepest_stride) == 0:
        kinds.append("row_band")
        if data_n > 1:
            kinds.append("grid")
    return kinds


def choose_kind(features: PlanFeatures, hw: Tuple[int, int], batch: int, *,
                data_n: int, model_n: int,
                params: Optional[CostParams] = None,
                cost: Optional[CostProvider] = None,
                force_banded: bool = False) -> str:
    """Cheapest eligible plan kind; exact ties break toward the simpler
    plan (PLAN_KINDS order).  Costs come from ``cost`` (any
    CostProvider — measured overlay, fitted analytic...); ``params``
    is the analytic shorthand (``cost=AnalyticCost(params)``), and
    passing both is a contradiction.  ``force_banded`` restricts to
    row-banded kinds when any is eligible — the over-tall/transposed
    routing rule (launch/serve.py pads such heights to the band unit
    first)."""
    if cost is not None and params is not None:
        raise ValueError("pass either cost= or params=, not both")
    provider: CostProvider = (cost if cost is not None
                              else AnalyticCost(params or CostParams()))
    kinds = eligible_kinds(hw, data_n=data_n, model_n=model_n,
                           deepest_stride=features.deepest_stride)
    if force_banded:
        banded = [k for k in kinds if k in _BANDED]
        kinds = banded or kinds
    return min(
        kinds,
        key=lambda k: (provider.step_cost(features, hw, k, batch,
                                          data_n=data_n, model_n=model_n),
                       PLAN_KINDS.index(k)),
    )


class Planner:
    """Routes (bucket_hw, batch) to an ExecutionPlan on one mesh.

    ``features_fn(hw) -> PlanFeatures`` supplies the per-bucket cost
    features (the service wires it to the EngineFactory's assembled
    program — see launch/serve.py); results are memoized per bucket so
    routing a request is dict-lookup cheap after first sight.  It may be
    left None at construction (``Planner(mesh)``) and bound later with
    :meth:`bind_features` — STDService does exactly that, so callers can
    hand the service a bare mesh-shaped planner.

    Costs flow through ``self.cost`` (a :class:`CostProvider`):
    ``params=`` is the analytic shorthand, ``cost=`` injects any
    provider, and :meth:`use_measurements` overlays a telemetry
    CostBook over whatever provider is current — STDService wires its
    book in so routing tracks measured step times online.
    """

    def __init__(self, mesh: Mesh,
                 features_fn: Optional[
                     Callable[[Tuple[int, int]], PlanFeatures]] = None, *,
                 data_axis: str = "data", model_axis: str = "model",
                 params: Optional[CostParams] = None,
                 cost: Optional[CostProvider] = None):
        if cost is not None and params is not None:
            raise ValueError("pass either cost= or params=, not both")
        sizes = mesh_axis_sizes(mesh)
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axis = model_axis
        self.data_n = sizes.get(data_axis, 1)
        self.model_n = sizes.get(model_axis, 1)
        self.cost: CostProvider = (
            cost if cost is not None
            else AnalyticCost(params or CostParams()))
        # feature sources and memos are PER MODEL: the zoo's heads have
        # very different FLOP/channel profiles, so each model's features
        # are re-derived from its own assembled microcode
        self._features_fns: Dict[str, Callable[[Tuple[int, int]],
                                               PlanFeatures]] = {}
        if features_fn is not None:
            self._features_fns[DEFAULT_MODEL] = features_fn
        self._features: Dict[Tuple[Tuple[int, int], str],
                             PlanFeatures] = {}

    @property
    def params(self) -> CostParams:
        """The analytic constants routing currently falls back to (the
        provider itself for AnalyticCost, its fallback chain's params
        for overlays) — introspection/back-compat."""
        c: Any = self.cost
        while not isinstance(c, AnalyticCost):
            nxt = getattr(c, "fallback", None)
            if nxt is None:
                return CostParams()
            c = nxt
        return c.params

    def set_params(self, params: CostParams) -> "Planner":
        """Swap the analytic constants at the bottom of the provider
        chain, preserving any MeasuredCost overlays above them — the
        online-refit seam: launch/router.py's control loop fits
        CostParams from each replica's live book and calls this, so
        unmeasured combos route on the fitted constants from the next
        ``choose()`` on, with no service restart and no engine
        recompiles."""

        def rebuilt(c: Any) -> CostProvider:
            if isinstance(c, MeasuredCost):
                c.fallback = rebuilt(c.fallback)
                return c
            return AnalyticCost(params)

        self.cost = rebuilt(self.cost)
        return self

    def use_measurements(self, book, *,
                         min_observations: int =
                         MeasuredCost.MIN_OBSERVATIONS,
                         precision: str = "f32",
                         model: str = DEFAULT_MODEL) -> "Planner":
        """Overlay a telemetry CostBook over the current provider:
        combos with >= min_observations measured steps route by their
        EWMA wall time, the rest keep the current (analytic) costs.
        ``precision`` selects which numerics' step series the overlay
        reads (a bfp service routes on bfp walls) and ``model`` which
        head's.  Idempotent per (book, precision, model) — re-wiring
        the same triple is a no-op."""
        if (isinstance(self.cost, MeasuredCost) and self.cost.book is book
                and self.cost.precision == precision
                and self.cost.model == model):
            return self
        self.cost = MeasuredCost(book, fallback=self.cost,
                                 min_observations=min_observations,
                                 precision=precision, model=model)
        return self

    def bind_features(
        self, features_fn: Callable[[Tuple[int, int]], PlanFeatures],
        model: str = DEFAULT_MODEL,
    ) -> "Planner":
        """Late-bind one model's feature source (idempotent per model:
        the first binding — incl. a constructor-time features_fn for the
        default model — wins)."""
        if model not in self._features_fns:
            self._features_fns[model] = features_fn
        return self

    def features(self, hw: Tuple[int, int],
                 model: str = DEFAULT_MODEL) -> PlanFeatures:
        hw = tuple(hw)
        f = self._features.get((hw, model))
        if f is None:
            fn = self._features_fns.get(model)
            if fn is None:
                raise RuntimeError(
                    f"Planner has no features_fn for model {model!r}; "
                    f"pass one at construction or call bind_features()"
                )
            f = fn(hw)
            self._features[(hw, model)] = f
        return f

    def height_unit(self, deepest_stride: int) -> int:
        """Heights routed to this planner's row-banded plans must be a
        multiple of this (bands x deepest stride)."""
        return max(self.model_n, 1) * deepest_stride

    def costs(self, hw: Tuple[int, int], batch: int,
              model: str = DEFAULT_MODEL) -> Dict[str, float]:
        """The per-kind cost table for one bucket (bench introspection)."""
        f = self.features(hw, model)
        return {
            k: self.cost.step_cost(f, hw, k, batch, data_n=self.data_n,
                                   model_n=self.model_n)
            for k in eligible_kinds(hw, data_n=self.data_n,
                                    model_n=self.model_n,
                                    deepest_stride=f.deepest_stride)
        }

    def choose(self, hw: Tuple[int, int], batch: int, *,
               force_banded: bool = False,
               model: str = DEFAULT_MODEL) -> ExecutionPlan:
        kind = choose_kind(self.features(hw, model), hw, batch,
                           data_n=self.data_n, model_n=self.model_n,
                           cost=self.cost, force_banded=force_banded)
        return self.plan_for_kind(kind)

    def plan_for_kind(self, kind: str) -> ExecutionPlan:
        if kind == "single_device":
            return SingleDevice()
        if kind == "data_parallel":
            return DataParallel(self.mesh, self.data_axis)
        if kind == "row_band":
            return RowBand(self.mesh, axis=self.model_axis)
        if kind == "grid":
            return GridPlan(self.mesh, self.data_axis, self.model_axis)
        raise ValueError(f"unknown plan kind {kind!r}")
