"""Fault tolerance: preemption-safe training loop, straggler watchdog,
restart/resume — the machinery that makes a 1000-node run survivable
(DESIGN.md §5).

Components:
  * ``Watchdog`` — EMA step-time monitor; flags stragglers (a step slower
    than ``threshold x`` the EMA) and records incidents.  On a real
    cluster the incident hook triggers checkpoint + re-mesh; in tests the
    hook is observed directly (a sleep-injected step must be flagged).
  * ``PreemptionGuard`` — converts SIGTERM/SIGINT into a "save and stop"
    request the loop honours at the next step boundary (TPU maintenance
    events give exactly this kind of grace window).
  * ``TrainRunner`` — step loop glue: deterministic step-indexed data,
    async checkpoint every N steps, auto-resume from the latest manifest,
    bit-exact restart (tested), and elastic restore onto a different mesh
    via the shardings argument.
"""
from __future__ import annotations

import signal
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class Watchdog:
    """EMA step-time monitor.  A step slower than ``threshold x`` the
    EMA is flagged as a straggler incident.  Transient spikes must not
    inflate the baseline, so a straggler step normally leaves the EMA
    untouched — but a *sustained* legitimate slowdown (re-mesh, thermal
    throttle, a permanently slower replica) would then flag every
    subsequent step forever.  After ``adapt_after`` consecutive
    incidents the monitor accepts the slowdown as the new normal and
    starts blending straggler times into the EMA too, so the baseline
    converges and flagging stops; ``consecutive`` exposes the live
    incident streak (launch/router.py reads it as a replica-health
    signal)."""

    def __init__(self, threshold: float = 3.0, ema: float = 0.9,
                 warmup_steps: int = 2, adapt_after: int = 5):
        if adapt_after < 1:
            raise ValueError("adapt_after must be >= 1")
        self.threshold = threshold
        self.ema_coef = ema
        self.warmup_steps = warmup_steps
        self.adapt_after = adapt_after
        self.ema: Optional[float] = None
        self.incidents: List[Dict[str, Any]] = []
        self.consecutive = 0          # live streak of straggler incidents
        self._seen = 0

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler incident."""
        self._seen += 1
        if self._seen <= self.warmup_steps:   # compile steps are outliers
            return False
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = dt > self.threshold * self.ema
        if is_straggler:
            self.consecutive += 1
            self.incidents.append({"step": step, "dt": dt, "ema": self.ema})
            if self.consecutive >= self.adapt_after:
                # sustained slowdown: adapt the baseline toward the new
                # step time so flagging recovers instead of persisting
                # forever (the streak keeps counting until a step passes)
                self.ema = (self.ema_coef * self.ema
                            + (1 - self.ema_coef) * dt)
        else:
            self.consecutive = 0
            self.ema = self.ema_coef * self.ema + (1 - self.ema_coef) * dt
        return is_straggler


class PreemptionGuard:
    def __init__(self, install: bool = True):
        self.requested = False
        self._orig: Dict[int, Any] = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._orig[sig] = signal.signal(sig, self._handler)
                except ValueError:
                    pass   # not on main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True

    def request(self):               # test hook / manual trigger
        self.requested = True

    def uninstall(self):
        for sig, h in self._orig.items():
            signal.signal(sig, h)


class TrainRunner:
    """Generic fault-tolerant step loop.

    step_fn(state, batch) -> (state, metrics);  state is any pytree that
    fully determines training (params, opt state, rng, step counter is
    tracked here).  batch_fn(step) -> batch (deterministic, so resume
    replays the exact stream).
    """

    def __init__(
        self,
        step_fn: Callable,
        batch_fn: Callable[[int], Any],
        ckpt: CheckpointManager,
        *,
        ckpt_every: int = 50,
        watchdog: Optional[Watchdog] = None,
        guard: Optional[PreemptionGuard] = None,
        on_incident: Optional[Callable[[Dict[str, Any]], None]] = None,
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.watchdog = watchdog or Watchdog()
        self.guard = guard or PreemptionGuard(install=False)
        self.on_incident = on_incident
        self.metrics_log: List[Dict[str, Any]] = []

    def resume_or_init(self, init_state, *, shardings=None):
        step, state = self.ckpt.restore_latest(init_state,
                                               shardings=shardings)
        if step is None:
            return 0, init_state
        return step, state

    def run(self, state, start_step: int, n_steps: int,
            *, fail_at: Optional[int] = None):
        """Run to start_step + n_steps.  ``fail_at`` injects a crash
        (tests: restart must be bit-exact)."""
        step = start_step
        end = start_step + n_steps
        try:
            while step < end:
                if self.guard.requested:
                    self.ckpt.save(step, state, blocking=True,
                                   extra_meta={"reason": "preempted"})
                    return step, state, "preempted"
                batch = self.batch_fn(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
                dt = time.perf_counter() - t0
                step += 1
                if self.watchdog.observe(step, dt) and self.on_incident:
                    self.on_incident(self.watchdog.incidents[-1])
                m = dict(metrics)
                m.update(step=step, dt=dt)
                self.metrics_log.append(
                    {k: (float(v) if hasattr(v, "__float__") else v)
                     for k, v in m.items()}
                )
                if fail_at is not None and step == fail_at:
                    raise RuntimeError(f"injected failure at step {step}")
                if step % self.ckpt_every == 0 or step == end:
                    self.ckpt.save(step, state, blocking=(step == end))
        except BaseException:
            # a crash must not strand an in-flight async save: the restart
            # resumes from the checkpoint the manifest ALREADY names, so the
            # write has to land before the exception escapes (and before any
            # teardown deletes the directory under the writer thread)
            self.ckpt.wait()
            raise
        self.ckpt.wait()
        return step, state, "done"
