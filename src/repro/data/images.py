"""Synthetic STD data: images with rectangular "text instances" plus
pixel-level score/link ground truth at 1/4 scale (the PixelLink label
format).  Random-size generation exercises the paper's §IV.B random-size
path (bucketed batching + the transpose trick)."""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.models.fcn.postprocess import NEIGHBORS


def _render_instance(img, score, inst, x0, y0, x1, y1, label, rng):
    # "text" = bright strip with character-ish ticks on dark background
    img[y0:y1, x0:x1] += rng.uniform(0.5, 0.9)
    for cx in range(x0, x1, max((x1 - x0) // 6, 2)):
        img[y0:y1, cx:cx + 1] -= 0.3
    sy0, sy1 = y0 // 4, max(y1 // 4, y0 // 4 + 1)
    sx0, sx1 = x0 // 4, max(x1 // 4, x0 // 4 + 1)
    score[sy0:sy1, sx0:sx1] = 1.0
    inst[sy0:sy1, sx0:sx1] = label


def links_from_instances(inst: np.ndarray) -> np.ndarray:
    """GT links: positive where the 8-neighbor has the same instance id."""
    H, W = inst.shape
    links = np.zeros((H, W, 8), np.float32)
    for d, (dy, dx) in enumerate(NEIGHBORS):
        shifted = np.zeros_like(inst)
        ys = slice(max(dy, 0), H + min(dy, 0))
        yd = slice(max(-dy, 0), H + min(-dy, 0))
        xs = slice(max(dx, 0), W + min(dx, 0))
        xd = slice(max(-dx, 0), W + min(-dx, 0))
        shifted[yd, xd] = inst[ys, xs]
        links[..., d] = ((inst > 0) & (shifted == inst)).astype(np.float32)
    return links


class SyntheticSTDData:
    """Batch generator for the STD examples/benchmarks."""

    def __init__(self, image_size: Tuple[int, int] = (512, 512),
                 max_instances: int = 6, seed: int = 0):
        self.image_size = image_size
        self.max_instances = max_instances
        self.seed = seed

    def sample(self, step: int, batch: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )
        H, W = self.image_size
        imgs = np.zeros((batch, H, W, 3), np.float32)
        scores = np.zeros((batch, H // 4, W // 4), np.float32)
        links = np.zeros((batch, H // 4, W // 4, 8), np.float32)
        boxes: List[List[Tuple[int, int, int, int]]] = []
        for b in range(batch):
            base = rng.uniform(0.0, 0.25, size=(H, W, 1)).astype(np.float32)
            img = np.repeat(base, 3, axis=2)
            score = np.zeros((H // 4, W // 4), np.float32)
            inst = np.zeros((H // 4, W // 4), np.int32)
            bl = []
            n = rng.integers(1, self.max_instances + 1)
            for k in range(n):
                w = int(rng.integers(40, max(W // 3, 48)))
                h = int(rng.integers(12, max(H // 8, 16)))
                x0 = int(rng.integers(0, max(W - w, 1)))
                y0 = int(rng.integers(0, max(H - h, 1)))
                mono = img[..., 0]
                _render_instance(mono, score, inst, x0, y0, x0 + w, y0 + h,
                                 k + 1, rng)
                img = np.repeat(mono[..., None], 3, axis=2)
                bl.append((x0 // 4, y0 // 4, (x0 + w) // 4, (y0 + h) // 4))
            img += rng.normal(0, 0.02, size=img.shape)
            imgs[b] = np.clip(img, 0, 1)
            scores[b] = score
            links[b] = links_from_instances(inst)
            boxes.append(bl)
        return {"images": imgs, "score": scores, "links": links,
                "boxes": boxes}

    def sample_random_size(self, step: int) -> Dict[str, np.ndarray]:
        """Random-size single image (serving path, paper §IV.B)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, 777])
        )
        h = int(rng.integers(16, 128)) * 8
        w = int(rng.integers(16, 128)) * 8
        gen = SyntheticSTDData((h, w), self.max_instances,
                               seed=self.seed + step)
        return gen.sample(0, 1)


class RequestStream:
    """Seeded mixed-resolution request stream for the serving benchmarks:
    ``n`` images with sizes drawn from ``hw_range`` (multiples of
    ``step_px`` so the 1/4-scale label maps stay integral), a fraction of
    over-wide images for the §IV.B transpose trick, and ground-truth box
    counts for sanity checks.  Iterating yields
    ``{"image", "hw", "boxes"}`` dicts; ``images()`` returns just the
    image list."""

    def __init__(self, n: int, seed: int = 0,
                 hw_range: Tuple[Tuple[int, int], Tuple[int, int]] =
                 ((48, 128), (48, 128)),
                 step_px: int = 8, over_wide_frac: float = 0.0,
                 over_wide_w: int = 0, max_instances: int = 4):
        self.n = n
        self.seed = seed
        self.hw_range = hw_range
        self.step_px = step_px
        self.over_wide_frac = over_wide_frac
        self.over_wide_w = over_wide_w
        self.max_instances = max_instances

    def __iter__(self):
        (h0, h1), (w0, w1) = self.hw_range
        for i in range(self.n):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, i, 4242])
            )
            h = int(rng.integers(h0 // self.step_px,
                                 h1 // self.step_px + 1)) * self.step_px
            if (self.over_wide_frac > 0
                    and rng.random() < self.over_wide_frac):
                w = self.over_wide_w
            else:
                w = int(rng.integers(w0 // self.step_px,
                                     w1 // self.step_px + 1)) * self.step_px
            sample = SyntheticSTDData(
                (h, w), self.max_instances, seed=self.seed + i
            ).sample(0, 1)
            yield {"image": sample["images"][0], "hw": (h, w),
                   "boxes": sample["boxes"][0]}

    def images(self) -> List[np.ndarray]:
        return [r["image"] for r in self]
