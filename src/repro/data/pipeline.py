"""Host data pipeline.

``TokenDataset`` — synthetic LM pretraining stream with two crucial
production properties:
  * step-indexed determinism: batch(step) is a pure function of (seed,
    step), so a restarted/resumed job consumes *exactly* the byte stream
    it would have seen — bit-exact resume (tested).
  * host-sharded: each host materializes only its slice of the global
    batch (``host_slice``), the multi-host ingestion pattern.

``Prefetcher`` — double-buffered host->device feed: the next batch's
device_put overlaps the current step (the paper's ping-pong input buffer,
C4, at the host boundary).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class TokenDataset:
    """Synthetic autoregressive data with learnable structure (a noisy
    repeat-copy language) so small models visibly learn — used by the
    examples and convergence tests."""

    def __init__(
        self,
        vocab: int,
        seq_len: int,
        global_batch: int,
        *,
        seed: int = 0,
        n_hosts: int = 1,
        host_id: int = 0,
        structure: str = "repeat",      # repeat|uniform
    ):
        assert global_batch % n_hosts == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // n_hosts
        self.seed = seed
        self.host_id = host_id
        self.structure = structure

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        b, s, v = self.local_batch, self.seq_len, self.vocab
        if self.structure == "uniform":
            toks = rng.integers(0, v, size=(b, s), dtype=np.int32)
        else:
            # repeat-copy: period-p repetition + 10% noise -> predictable
            period = rng.integers(3, 8, size=(b, 1))
            base = rng.integers(0, v, size=(b, 8), dtype=np.int32)
            idx = np.arange(s)[None, :] % period
            toks = np.take_along_axis(base, idx, axis=1).astype(np.int32)
            noise = rng.random((b, s)) < 0.1
            toks = np.where(noise,
                            rng.integers(0, v, size=(b, s)), toks)
        labels = np.concatenate(
            [toks[:, 1:], np.full((b, 1), -1, np.int32)], axis=1
        )
        return {"tokens": toks, "labels": labels.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Depth-2 host->device prefetch (ping-pong buffers)."""

    def __init__(
        self,
        it: Iterator[Any],
        *,
        depth: int = 2,
        put: Optional[Callable[[Any], Any]] = None,
    ):
        self._it = it
        self._put = put or (lambda x: jax.tree_util.tree_map(jnp.asarray, x))
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(self._put(item))
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
