from .pipeline import TokenDataset, Prefetcher
from .images import SyntheticSTDData

__all__ = ["TokenDataset", "Prefetcher", "SyntheticSTDData"]
