"""STD serving driver — the paper's deployment shape (Fig. 2/9): batched
scene-text-detection requests through the microcode FCN engine, with the
paper's throughput tricks:

  * random-size inputs bucketed to a few compiled shapes (§IV.B analogue
    of row-wise segmentation; the transpose trick applied verbatim for
    over-wide images),
  * dynamic micro-batching: an async request queue groups images by
    resolution bucket and runs one compiled batched engine per bucket
    (launch/batching.py), flushing on ``max_batch`` or ``max_wait_ms``,
  * module-level pipelining (C4): host preprocess / device FCN / host
    CC-postprocess overlap as pipeline stages, so stage i of image n
    overlaps stage i+1 of image n-1,
  * an engine LRU keyed by (bucket, batch) so compile cost is paid once
    per shape,
  * TPS + latency accounting (feeds the Fig. 9a benchmark).

  PYTHONPATH=src python -m repro.launch.serve --requests 32 --width 0.25
"""
from __future__ import annotations

import argparse
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.batching import LRUCache, MicroBatcher, round_batch
from repro.runtime.pipeline import HostPipeline

MAX_WIDTH = 4096          # the paper's width limit


def bucket_hw(h: int, w: int, buckets: Tuple[int, ...]) -> Tuple[int, int]:
    bh = min(b for b in buckets if b >= h)
    bw = min(b for b in buckets if b >= w)
    return bh, bw


class STDService:
    """Per-bucket model cache + (bucket, batch)-keyed compiled engines +
    the sequential / pipelined / micro-batched serving modes."""

    def __init__(self, width: float = 0.25, mode: str = "optimized",
                 buckets: Tuple[int, ...] = (64, 128, 256),
                 score_thr: float = 0.5, link_thr: float = 0.5,
                 max_batch: int = 8, max_wait_ms: float = 5.0,
                 batch_round: str = "pow2",
                 engine_cache_capacity: int = 16):
        from repro.models.fcn.pixellink import PixelLinkModel, STDConfig

        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.buckets = buckets
        self.score_thr = score_thr
        self.link_thr = link_thr
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.batch_round = batch_round
        self._models: Dict[Tuple[int, int], Any] = {}
        self._params: Dict[Tuple[int, int], Any] = {}
        self._engines = LRUCache(engine_cache_capacity)
        self._lock = threading.Lock()
        self._batcher: Optional[MicroBatcher] = None
        self._width = width
        self._mode = mode
        self._mk = lambda hw: PixelLinkModel(STDConfig(
            backbone="vgg16", width=width, image_size=hw,
            merge_ch=(16, 16, 8), mode=mode, storage_fp16=False,
        ))
        self.stats: Dict[str, Any] = {"n": 0, "latency_s": [],
                                      "transposed": 0}

    def _get(self, hw: Tuple[int, int]):
        with self._lock:
            if hw not in self._models:
                m = self._mk(hw)
                self._models[hw] = m
                self._params[hw] = m.init_params(jax.random.PRNGKey(0))
            return self._models[hw], self._params[hw]

    def _run_fn(self, hw: Tuple[int, int], batch: int):
        """Compiled engine for one (bucket, batch) shape: FCN forward +
        batched CC labeling with per-image valid-region masking, one jit
        cache entry per shape (LRU-evicted)."""
        key = (hw, batch)
        fn = self._engines.get(key)
        if fn is not None:
            return fn
        model, _ = self._get(hw)
        from repro.models.fcn import postprocess as pp

        def run(params, x, valid_q):
            out = model.apply(params, x)
            h, w = out["score"].shape[1:]
            mask = (
                (jnp.arange(h)[None, :, None] < valid_q[:, 0, None, None])
                & (jnp.arange(w)[None, None, :] < valid_q[:, 1, None, None])
            )
            return pp.cc_label_batched(
                out["score"], out["links"], self.score_thr, self.link_thr,
                valid_mask=mask,
            )

        fn = jax.jit(run)
        self._engines.put(key, fn)
        return fn

    # -- stages ---------------------------------------------------------------
    def preprocess(self, img: np.ndarray):
        """Random-size handling: transpose trick + bucket padding."""
        h, w = img.shape[:2]
        transposed = False
        if w > MAX_WIDTH >= h:                      # paper §IV.B
            img = np.transpose(img, (1, 0, 2))
            h, w = w, h
            transposed = True
            with self._lock:
                self.stats["transposed"] += 1
        bh, bw = bucket_hw(h, w, self.buckets)
        pad = np.zeros((bh, bw, 3), np.float32)
        pad[:h, :w] = img
        return pad, (h, w), transposed

    def infer_labels(self, stack: np.ndarray,
                     valid_hws: List[Tuple[int, int]]) -> np.ndarray:
        """(B, bh, bw, 3) padded batch -> (B, bh/4, bw/4) int32 label maps.

        The batch axis may be padded past ``len(valid_hws)`` (batch-size
        rounding); trailing slots are zero images whose labels are
        discarded by the caller.
        """
        hw = stack.shape[1:3]
        n_live = len(valid_hws)
        b = round_batch(n_live, self.max_batch, self.batch_round)
        if b > n_live:
            stack = np.concatenate(
                [stack, np.zeros((b - n_live,) + stack.shape[1:],
                                 stack.dtype)]
            )
        valid_q = np.zeros((b, 2), np.int32)
        for i, (vh, vw) in enumerate(valid_hws):
            valid_q[i] = (vh // 4, vw // 4)
        fn = self._run_fn(tuple(hw), b)
        _, params = self._get(tuple(hw))
        return np.asarray(fn(params, jnp.asarray(stack),
                             jnp.asarray(valid_q)))

    def postprocess(self, labels: np.ndarray, valid_hw: Tuple[int, int],
                    transposed: bool) -> List[Dict]:
        """One image's label map -> boxes (host-side serving tail)."""
        from repro.models.fcn import postprocess as pp

        vh, vw = valid_hw[0] // 4, valid_hw[1] // 4
        boxes = pp.boxes_from_labels(np.asarray(labels)[:vh, :vw])
        if transposed:                              # inverse transposition
            for b in boxes:
                x0, y0, x1, y1 = b["box"]
                b["box"] = (y0, x0, y1, x1)
        return boxes

    def __call__(self, img: np.ndarray) -> List[Dict]:
        t0 = time.perf_counter()
        x, valid, tr = self.preprocess(img)
        labels = self.infer_labels(x[None], [valid])[0]
        boxes = self.postprocess(labels, valid, tr)
        with self._lock:
            self.stats["n"] += 1
            self.stats["latency_s"].append(time.perf_counter() - t0)
        return boxes

    # -- pipelined server (C4 module-level multithreading) ---------------------
    def serve_pipelined(self, images: List[np.ndarray]) -> List[List[Dict]]:
        def pre(img):
            return self.preprocess(img)

        def infer(item):
            x, valid, tr = item
            labels = self.infer_labels(x[None], [valid])[0]
            return labels, valid, tr

        def post(item):
            labels, valid, tr = item
            return self.postprocess(labels, valid, tr)

        pipe = HostPipeline([pre, infer, post], maxsize=4)
        t0 = time.perf_counter()
        results = pipe.run(images)
        dt = time.perf_counter() - t0
        self.stats["pipelined_tps"] = len(images) / dt
        return results

    # -- micro-batched server (the tentpole path) ------------------------------
    def _mb_infer(self, key, payloads):
        stack = np.stack([p[0] for p in payloads])
        labels = self.infer_labels(stack, [p[1] for p in payloads])
        return [labels[i] for i in range(len(payloads))]

    def _mb_post(self, payload, labels):
        _, valid, tr = payload
        return self.postprocess(labels, valid, tr)

    def start_batched(self) -> "STDService":
        """Start the micro-batching scheduler (idempotent)."""
        if self._batcher is None:
            self._batcher = MicroBatcher(
                self._mb_infer, self._mb_post,
                max_batch=self.max_batch, max_wait_ms=self.max_wait_ms,
            )
            self._batcher.start()
        return self

    def stop_batched(self) -> None:
        if self._batcher is not None:
            self._batcher.stop()
            self.stats["batching"] = self._batcher.stats
            self._batcher = None

    def submit(self, img: np.ndarray) -> Future:
        """Async request: preprocess on the caller thread (the pipeline's
        pre stage), then enqueue on the bucket's micro-batch."""
        if self._batcher is None:
            raise RuntimeError("call start_batched() first")
        x, valid, tr = self.preprocess(img)
        return self._batcher.submit(x.shape[:2], (x, valid, tr))

    def serve_batched(self, images: List[np.ndarray], *,
                      pre_workers: int = 4) -> List[List[Dict]]:
        """Closed-loop batched serving: preprocess+submit from a small
        thread pool (so buckets actually fill), gather futures in order."""
        started_here = self._batcher is None
        self.start_batched()
        lat: List[float] = []
        t0 = time.perf_counter()

        def one(img):
            t = time.perf_counter()
            fut = self.submit(img)
            fut.add_done_callback(
                lambda f, t=t: lat.append(time.perf_counter() - t)
            )
            return fut

        try:
            with ThreadPoolExecutor(pre_workers) as ex:
                futs = list(ex.map(one, images))
            results = [f.result(timeout=600) for f in futs]
            dt = time.perf_counter() - t0
            self.stats["batched_tps"] = len(images) / dt
            self.stats["batched_latency_s"] = lat
            return results
        finally:
            # a failed request must not strand the scheduler threads
            if started_here:
                self.stop_batched()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--mode", default="optimized")
    ap.add_argument("--batched", action="store_true",
                    help="also run the micro-batched scheduler path")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    args = ap.parse_args(argv)

    from repro.data.images import RequestStream

    svc = STDService(width=args.width, mode=args.mode,
                     max_batch=args.max_batch, max_wait_ms=args.max_wait_ms)
    images = RequestStream(
        args.requests, seed=0, hw_range=((48, 120), (48, 120))
    ).images()
    # sequential (includes per-bucket compile on first hit)
    t0 = time.perf_counter()
    for img in images:
        svc(img)
    seq_dt = time.perf_counter() - t0
    # pipelined
    out = svc.serve_pipelined(images)
    msg = (f"[serve] {args.requests} reqs  "
           f"sequential {args.requests/seq_dt:.2f} TPS  "
           f"pipelined {svc.stats['pipelined_tps']:.2f} TPS")
    if args.batched:
        out_b = svc.serve_batched(images)
        assert [[b["box"] for b in r] for r in out] == \
               [[b["box"] for b in r] for r in out_b], "batched parity"
        msg += f"  batched {svc.stats['batched_tps']:.2f} TPS"
        sizes = [b["n"] for b in svc.stats["batching"]["batches"]]
        msg += f"  mean batch {np.mean(sizes):.2f}"
    msg += (f"  median latency {np.median(svc.stats['latency_s'])*1e3:.1f} ms"
            f"  boxes[0]={len(out[0])}")
    print(msg)
    return svc.stats


if __name__ == "__main__":
    main()
