"""STD serving driver — the paper's deployment shape (Fig. 2/9): batched
scene-text-detection requests through the microcode FCN engine, with the
paper's throughput tricks:

  * random-size inputs bucketed to a few compiled shapes (§IV.B analogue
    of row-wise segmentation; the transpose trick applied verbatim for
    over-wide images),
  * module-level pipelining (C4): host preprocess / device FCN / host
    CC-postprocess run as a 3-stage thread pipeline, so stage i of image
    n overlaps stage i+1 of image n-1,
  * TPS + latency accounting (feeds the Fig. 9a benchmark).

  PYTHONPATH=src python -m repro.launch.serve --requests 32 --width 0.25
"""
from __future__ import annotations

import argparse
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MAX_WIDTH = 4096          # the paper's width limit


def bucket_hw(h: int, w: int, buckets: Tuple[int, ...]) -> Tuple[int, int]:
    bh = min(b for b in buckets if b >= h)
    bw = min(b for b in buckets if b >= w)
    return bh, bw


class STDService:
    """Compiled-engine cache per bucket + the serving pipeline."""

    def __init__(self, width: float = 0.25, mode: str = "optimized",
                 buckets: Tuple[int, ...] = (64, 128, 256),
                 score_thr: float = 0.5, link_thr: float = 0.5):
        from repro.models.fcn.pixellink import PixelLinkModel, STDConfig

        self.buckets = buckets
        self.score_thr = score_thr
        self.link_thr = link_thr
        self._models: Dict[Tuple[int, int], Any] = {}
        self._params: Dict[Tuple[int, int], Any] = {}
        self._width = width
        self._mode = mode
        self._mk = lambda hw: PixelLinkModel(STDConfig(
            backbone="vgg16", width=width, image_size=hw,
            merge_ch=(16, 16, 8), mode=mode, storage_fp16=False,
        ))
        self.stats: Dict[str, Any] = {"n": 0, "latency_s": [],
                                      "transposed": 0}

    def _get(self, hw: Tuple[int, int]):
        if hw not in self._models:
            m = self._mk(hw)
            self._models[hw] = m
            self._params[hw] = m.init_params(jax.random.PRNGKey(0))
        return self._models[hw], self._params[hw]

    # -- stages ---------------------------------------------------------------
    def preprocess(self, img: np.ndarray):
        """Random-size handling: transpose trick + bucket padding."""
        h, w = img.shape[:2]
        transposed = False
        if w > MAX_WIDTH >= h:                      # paper §IV.B
            img = np.transpose(img, (1, 0, 2))
            h, w = w, h
            transposed = True
            self.stats["transposed"] += 1
        bh, bw = bucket_hw(h, w, self.buckets)
        pad = np.zeros((bh, bw, 3), np.float32)
        pad[:h, :w] = img
        return pad, (h, w), transposed

    def infer(self, batch: np.ndarray, hw: Tuple[int, int]):
        model, params = self._get(hw)
        return model.apply(params, jnp.asarray(batch))

    def postprocess(self, out, valid_hw: Tuple[int, int],
                    transposed: bool) -> List[Dict]:
        from repro.models.fcn import postprocess as pp

        score = np.asarray(out["score"])[0]
        links = np.asarray(out["links"])[0]
        vh, vw = valid_hw[0] // 4, valid_hw[1] // 4
        labels = np.asarray(pp.cc_label(
            jnp.asarray(score), jnp.asarray(links),
            self.score_thr, self.link_thr,
        ))[:vh, :vw]
        boxes = pp.boxes_from_labels(labels)
        if transposed:                              # inverse transposition
            for b in boxes:
                x0, y0, x1, y1 = b["box"]
                b["box"] = (y0, x0, y1, x1)
        return boxes

    def __call__(self, img: np.ndarray) -> List[Dict]:
        t0 = time.perf_counter()
        x, valid, tr = self.preprocess(img)
        out = self.infer(x[None], x.shape[:2])
        boxes = self.postprocess(out, valid, tr)
        self.stats["n"] += 1
        self.stats["latency_s"].append(time.perf_counter() - t0)
        return boxes

    # -- pipelined server (C4 module-level multithreading) ---------------------
    def serve_pipelined(self, images: List[np.ndarray]) -> List[List[Dict]]:
        q_pre: "queue.Queue" = queue.Queue(maxsize=4)
        q_post: "queue.Queue" = queue.Queue(maxsize=4)
        results: List[Optional[List[Dict]]] = [None] * len(images)

        def pre_worker():
            for i, img in enumerate(images):
                q_pre.put((i,) + self.preprocess(img))
            q_pre.put(None)

        def infer_worker():
            while True:
                item = q_pre.get()
                if item is None:
                    q_post.put(None)
                    return
                i, x, valid, tr = item
                out = self.infer(x[None], x.shape[:2])
                out = {k: np.asarray(v) for k, v in out.items()}
                q_post.put((i, out, valid, tr))

        def post_worker():
            while True:
                item = q_post.get()
                if item is None:
                    return
                i, out, valid, tr = item
                results[i] = self.postprocess(out, valid, tr)

        threads = [threading.Thread(target=f)
                   for f in (pre_worker, infer_worker, post_worker)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        self.stats["pipelined_tps"] = len(images) / dt
        return results  # type: ignore[return-value]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--mode", default="optimized")
    args = ap.parse_args(argv)

    from repro.data.images import SyntheticSTDData

    svc = STDService(width=args.width, mode=args.mode)
    gen = SyntheticSTDData((96, 128), seed=1)
    images = []
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        h = int(rng.integers(6, 16)) * 8
        w = int(rng.integers(6, 16)) * 8
        images.append(
            SyntheticSTDData((h, w), seed=i).sample(0, 1)["images"][0]
        )
    # sequential (includes per-bucket compile on first hit)
    t0 = time.perf_counter()
    for img in images:
        svc(img)
    seq_dt = time.perf_counter() - t0
    # pipelined
    out = svc.serve_pipelined(images)
    print(f"[serve] {args.requests} reqs  sequential {args.requests/seq_dt:.2f} TPS  "
          f"pipelined {svc.stats['pipelined_tps']:.2f} TPS  "
          f"median latency {np.median(svc.stats['latency_s'])*1e3:.1f} ms  "
          f"boxes[0]={len(out[0])}")
    return svc.stats


if __name__ == "__main__":
    main()
