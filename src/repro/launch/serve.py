"""STD serving driver — the paper's deployment shape (Fig. 2/9): batched
scene-text-detection requests through the microcode FCN engine, with the
paper's throughput tricks:

  * random-size inputs bucketed to a few compiled shapes (§IV.B analogue
    of row-wise segmentation; the transpose trick applied verbatim for
    over-wide images),
  * dynamic micro-batching: an async request queue groups images by
    resolution bucket and runs one compiled batched engine per bucket
    (launch/batching.py), flushing on ``max_batch`` or ``max_wait_ms``,
    with optional bounded-queue admission control (reject/block),
  * module-level pipelining (C4): host preprocess / device FCN / host
    CC-postprocess overlap as pipeline stages, so stage i of image n
    overlaps stage i+1 of image n-1,
  * async pipelined dispatch: the micro-batcher's infer path is split
    into a dispatch stage (submits device work without blocking — JAX
    async dispatch) and a completion stage (blocks on D2H), with a
    bounded ``inflight`` queue between them, so H2D/compute/D2H of
    batches from different buckets overlap (docs/serving.md),
  * engine compilation delegated to the ExecutionPlan layer
    (runtime/executor.py): one EngineFactory holds the models, params,
    and a (bucket, batch, plan)-keyed LRU; the service just picks a plan
    — SingleDevice by default, DataParallel over a mesh's "data" axis,
    the §IV.B RowBand plan for over-tall images that exceed the largest
    bucket, or the composed GridPlan (batch over "data" AND rows over
    "model" at once),
  * plan routing: either the fixed rules (service-wide ``plan`` +
    ``tall_plan`` for over-tall images) or a cost model
    (runtime/planner.py ``Planner``) that picks a plan PER BUCKET from
    FLOPs + halo bytes + batch-split occupancy — heterogeneous buckets
    in one service then route to different plans through the same
    engine LRU,
  * device-side postprocess (``postprocess="device"``): the CC tail
    already runs on device; this mode also compacts each label map into
    a fixed-capacity ``(capacity + 1, 6)`` boxes tensor on device
    (EngineFactory.boxes_fn), so the completion stage materializes a
    few hundred bytes per image instead of the full plane and the host
    tail is a trivial O(capacity) decode — per-image walls land in the
    CostBook under ``stage="postprocess"`` for both modes, and images
    whose component count overflows the capacity fall back to the host
    path (counted, never wrong),
  * measured-cost telemetry: every layer writes into one
    runtime/telemetry.CostBook (engine dispatch walls, full
    dispatch-through-D2H step walls, scheduler stage timings and queue
    gauges); with a planner configured the measured step EWMAs overlay
    the analytic cost model (``MeasuredCost``), so routing adapts
    online to what steps actually cost, and
    ``metrics_snapshot()`` / ``metrics_prometheus()`` export the lot
    in a flat scrapeable form for autoscalers,
  * TPS + latency accounting (feeds the Fig. 9a benchmark).

  PYTHONPATH=src python -m repro.launch.serve --requests 32 --width 0.25
"""
from __future__ import annotations

import argparse
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.batching import LatencyRecorder, MicroBatcher, round_batch
from repro.runtime.executor import (
    EngineFactory,
    ExecutionPlan,
    SingleDevice,
    band_height_unit,
    check_precision,
    describe_plan,
    plan_batch_multiple,
    plan_kind,
)
from repro.runtime.pipeline import HostPipeline
from repro.runtime.planner import Planner, features_for_program
from repro.runtime.telemetry import CostBook, prometheus_text

MAX_WIDTH = 4096          # the paper's width limit


def bucket_hw(h: int, w: int, buckets: Tuple[int, ...]) -> Tuple[int, int]:
    """Padded bucket shape for an (h, w) image.  Oversize dimensions
    round up to the next multiple of the largest bucket instead of
    raising, so the compiled-shape count stays bounded and over-tall
    inputs can route to the row-band plan.  Dimensions beyond the
    paper's MAX_WIDTH limit fail fast — a single huge request must not
    stall the infer thread with an unbounded compile/allocation."""
    top = max(buckets)

    def one(v: int) -> int:
        if v <= top:
            return min(b for b in buckets if b >= v)
        if v > MAX_WIDTH:
            raise ValueError(
                f"image dimension {v} exceeds the serving limit "
                f"{MAX_WIDTH} (paper §IV.B width bound)"
            )
        return -(-v // top) * top

    return one(h), one(w)


class STDService:
    """Bucketed STD serving on top of the ExecutionPlan layer: plan
    selection + request scheduling here, all engine compilation in
    runtime.executor.EngineFactory (sequential / pipelined /
    micro-batched serving modes)."""

    def __init__(self, width: float = 0.25, mode: str = "optimized",
                 buckets: Tuple[int, ...] = (64, 128, 256),
                 score_thr: float = 0.5, link_thr: float = 0.5,
                 max_batch: int = 8, max_wait_ms: float = 5.0,
                 batch_round: str = "pow2",
                 engine_cache_capacity: int = 16,
                 plan: Optional[ExecutionPlan] = None,
                 tall_plan: Optional[ExecutionPlan] = None,
                 planner: Optional[Planner] = None,
                 max_pending: int = 0, admission: str = "block",
                 inflight: int = 1,
                 book: Optional[CostBook] = None,
                 measured_routing: bool = True,
                 precision: str = "f32",
                 postprocess: str = "host",
                 boxes_capacity: int = 256,
                 model: str = "pixellink",
                 memplan: bool = True,
                 activation_budget_bytes: Optional[int] = None,
                 engine_cache_bytes: int = 0):
        from repro.models.fcn.heads import (
            DetectionModel, build_head, check_model,
        )
        from repro.models.fcn.pixellink import STDConfig

        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if postprocess not in ("host", "device"):
            raise ValueError(
                f"postprocess must be 'host' or 'device', got {postprocess!r}"
            )
        if boxes_capacity < 1:
            raise ValueError("boxes_capacity must be >= 1")
        # which detection head this service routes requests to — every
        # cache, plan feature, and telemetry series keys on it
        self.model_name = check_model(model)
        self.head = build_head(model, score_thr=score_thr,
                               link_thr=link_thr)
        if postprocess == "device" and \
                not self.head.supports_device_postprocess:
            raise ValueError(
                f"model {model!r} has no label-map payload, so the "
                f"device-compact box tail does not apply; use "
                f"postprocess='host'"
            )
        # "device" compacts boxes on device (EngineFactory.boxes_fn);
        # named _mode because postprocess() is the stage method
        self.postprocess_mode = postprocess
        self.boxes_capacity = boxes_capacity
        self.precision = check_precision(precision)
        self.plan: ExecutionPlan = plan if plan is not None else SingleDevice()
        self.planner = planner
        m = plan_batch_multiple(self.plan)
        if tall_plan is not None:
            # tall_plan may be any plan type now, including data-sharded
            # ones whose padded batches must also stay within max_batch
            m = max(m, plan_batch_multiple(tall_plan))
        if planner is not None:
            # the planner may route any bucket to a data-parallel or grid
            # plan, whose padded batches must stay within max_batch
            m = max(m, planner.data_n)
        if max_batch % m:
            raise ValueError(
                f"max_batch={max_batch} must be a multiple of the plan's "
                f"data-parallel width {m}, or padded batches would exceed "
                f"the configured maximum"
            )
        self.buckets = buckets
        self.max_batch = max_batch
        self._batch_multiple = m
        # memory-aware batching (core.memplan): with a budget configured,
        # each bucket's flush size is capped by how many planned
        # activation footprints fit — a memory-heavy bucket compiles its
        # engines at a SMALLER batch (lower temp bytes), a light bucket
        # may batch above the fixed max_batch.  None = fixed max_batch.
        self.memplan_enabled = bool(memplan)
        self.activation_budget_bytes = activation_budget_bytes
        self._bucket_caps: Dict[Tuple[int, int], int] = {}
        self.max_wait_ms = max_wait_ms
        self.batch_round = batch_round
        self.tall_plan = tall_plan
        self.max_pending = max_pending
        self.admission = admission
        if inflight < 0:
            raise ValueError("inflight must be >= 0")
        self.inflight = inflight
        self._lock = threading.Lock()
        self._batcher: Optional[MicroBatcher] = None
        self._width = width
        self._mode = mode
        # the telemetry book every layer writes into: engine dispatch
        # walls (EngineFactory), full step walls (this service's
        # completion path), scheduler stage timings/gauges
        # (MicroBatcher) — metrics_snapshot() exports it all
        self.book = book if book is not None else CostBook()

        def make_model(hw, precision="f32", model="pixellink"):
            # "bfp" runs the paper's quantized datapath: BFP convs with
            # FP16 data-pool storage, Pallas kernels where the backend
            # compiles them (interpret-mode Pallas off the TPU would be
            # orders of magnitude slower than XLA, so it stays off in
            # serving — the kernels themselves are covered by tests).
            # The model arg selects the detection head; one factory can
            # serve several zoo models through the same LRU.
            from repro.core import BFPConfig

            bfp = precision == "bfp"
            return DetectionModel(STDConfig(
                backbone="vgg16", width=width, image_size=hw,
                merge_ch=(16, 16, 8), mode=mode,
                bfp=BFPConfig() if bfp else None,
                storage_fp16=bfp,
                use_pallas=bfp and jax.default_backend() in ("gpu", "tpu"),
                memplan=memplan,
            ), build_head(model, score_thr=score_thr,
                          link_thr=link_thr))

        self.factory = EngineFactory(
            make_model,
            score_thr=score_thr, link_thr=link_thr,
            capacity=engine_cache_capacity,
            book=self.book,
            engine_bytes_budget=engine_cache_bytes,
        )
        if planner is not None:
            planner.bind_features(self._plan_features,
                                  model=self.model_name)
            if measured_routing:
                # overlay measured step EWMAs over the analytic model:
                # combos the service has actually run route by what they
                # actually cost, through the same engine LRU — reading
                # this service's precision's AND model's step series
                planner.use_measurements(self.book,
                                         precision=self.precision,
                                         model=self.model_name)
        self.stats: Dict[str, Any] = {"n": 0, "latency_s": [],
                                      "transposed": 0, "plan_choices": {},
                                      "nonconverged": 0, "pp_overflow": 0}

    @property
    def _engines(self):
        """The factory's compiled-engine LRU (tests/introspection)."""
        return self.factory.engines

    def _plan_features(self, hw: Tuple[int, int]):
        """Cost-model features for one bucket, from the same assembled
        program the engine will run (planner wiring) — this service's
        OWN model's microcode, so per-model plan features differ."""
        model = self.factory.model(tuple(hw), self.precision,
                                   self.model_name)
        return features_for_program(
            model.program,
            self.factory.deepest_stride(tuple(hw), self.precision,
                                        self.model_name),
            mode=self._mode,
        )

    def _bucket_cap(self, hw: Tuple[int, int]) -> int:
        """Effective max batch for one bucket.  With an activation
        budget configured, the cap is how many planned per-image
        footprints (core.memplan peak bytes) fit, rounded to the plan
        batch multiple; without one it is the fixed max_batch.  Cached —
        MicroBatcher calls this under its scheduler lock."""
        if self.activation_budget_bytes is None or not self.memplan_enabled:
            return self.max_batch
        hw = tuple(hw)
        cap = self._bucket_caps.get(hw)
        if cap is None:
            from repro.core.memplan import admissible_batch

            try:
                per_image = self.factory.memplan(
                    hw, self.precision, self.model_name).peak_bytes
            except Exception:
                per_image = 0            # plan failure must not stop serving
            cap = admissible_batch(per_image, self.activation_budget_bytes,
                                   multiple=self._batch_multiple)
            self._bucket_caps[hw] = cap
        return cap

    def _plan_for(self, hw: Tuple[int, int], batch: int = 1) -> ExecutionPlan:
        """Plan routing.  With a cost-model planner configured, every
        bucket is routed by estimated step cost — over-tall shapes
        (taller than the largest bucket) are restricted to the
        row-banded kinds (RowBand/GridPlan), matching the §IV.B rule.
        Without one, the fixed rules apply: over-tall shapes go to
        ``tall_plan`` when configured, everything else to the service
        default."""
        over_tall = hw[0] > max(self.buckets)
        if self.planner is not None:
            plan = self.planner.choose(hw, batch, force_banded=over_tall,
                                       model=self.model_name)
            # routing runs on the dispatch thread while callers read
            # stats — every stats mutation holds _lock
            with self._lock:
                self.stats["plan_choices"][tuple(hw)] = describe_plan(plan)
            return plan
        if self.tall_plan is not None and over_tall:
            return self.tall_plan
        return self.plan

    def _routes_banded(self) -> bool:
        """Whether over-tall/over-wide images can ride a row-banded plan
        (fixed tall_plan rule or planner routing)."""
        return self.tall_plan is not None or self.planner is not None

    def _tall_height(self, bh: int) -> int:
        """Padded height for an over-tall image headed to a row-banded
        plan: rounded up so every band divides evenly through the stride
        pyramid (bands x deepest cumulative stride) — without this,
        clamped heights like 192 on an 8-band mesh would be rejected by
        the plan compiler."""
        top = max(self.buckets)
        deepest = self.factory.deepest_stride((top, top), self.precision,
                                              self.model_name)
        if self.planner is not None:
            unit = self.planner.height_unit(deepest)
        else:
            unit = band_height_unit(self.tall_plan, deepest)
        return -(-bh // unit) * unit

    # -- stages ---------------------------------------------------------------
    def preprocess(self, img: np.ndarray):
        """Random-size handling: transpose trick + bucket padding."""
        h, w = img.shape[:2]
        transposed = False
        # paper §IV.B over-wide rule; with banded routing configured
        # (fixed tall_plan or cost-model planner) the same trick also
        # turns any over-wide image into an over-tall one so it rides a
        # row-banded plan instead of a one-off monolithic engine at a
        # clamped width
        if w > MAX_WIDTH >= h or (
            self._routes_banded() and w > max(self.buckets) >= h
        ):
            img = np.transpose(img, (1, 0, 2))
            h, w = w, h
            transposed = True
            with self._lock:
                self.stats["transposed"] += 1
        bh, bw = bucket_hw(h, w, self.buckets)
        if self._routes_banded() and bh > max(self.buckets):
            bh = self._tall_height(bh)
        pad = np.zeros((bh, bw, 3), np.float32)
        pad[:h, :w] = img
        return pad, (h, w), transposed

    def _dispatch(self, stack: np.ndarray,
                  valid_hws: List[Tuple[int, int]]):
        """Route + pad + submit one batch; returns the pending device
        tuple — the head's ``(*payload, converged)`` on the
        host-postprocess path (``(labels, converged)`` for the CC
        heads), with the compact on-device ``(rows, counts)`` boxes
        appended on the device path — and the step-telemetry meta
        ``(hw, batch, kind, t0)`` the completion path hands to
        :meth:`_record_step`.  Nothing here blocks: the boxes fn is a
        jitted call on the pending labels, so it joins the same async
        dispatch chain."""
        hw = tuple(stack.shape[1:3])
        n_live = len(valid_hws)
        b = round_batch(n_live, self._bucket_cap(hw), self.batch_round)
        plan = self._plan_for(hw, b)
        m = plan_batch_multiple(plan)            # data-parallel divisibility
        b = -(-b // m) * m
        if b > n_live:
            stack = np.concatenate(
                [stack, np.zeros((b - n_live,) + stack.shape[1:],
                                 stack.dtype)]
            )
        valid_q = np.zeros((b, 2), np.int32)
        for i, (vh, vw) in enumerate(valid_hws):
            valid_q[i] = (vh // 4, vw // 4)
        fn = self.factory.plan_fn(hw, b, plan, self.precision,
                                  self.model_name)
        params = self.factory.params(hw, self.precision, self.model_name)
        t0 = time.perf_counter()
        pending = fn(params, jnp.asarray(stack), jnp.asarray(valid_q))
        if self.postprocess_mode == "device":
            # labels are already valid-masked, so padding contributes no
            # components; coordinates live in label-map (quarter) space
            # (single-label-map heads only — enforced at construction)
            rows, counts = self.factory.boxes_fn(
                hw, b, self.boxes_capacity)(pending[0])
            pending = (*pending, rows, counts)
        return pending, (hw, b, plan_kind(plan), t0)

    def _record_step(self, meta) -> None:
        """One materialized batch's dispatch-through-D2H wall into the
        book — the ``stage="step"`` series MeasuredCost routes by.
        This is the DEPLOYMENT wall: on the async path (inflight > 0)
        it includes time queued behind earlier batches' finalize work,
        which is plan-independent load, roughly uniform across
        whichever plan runs — so steady-state measured-vs-measured
        comparisons stay fair, but measured-vs-analytic ones are biased
        under load (see "Calibrated routing" in docs/plans.md)."""
        hw, b, kind, t0 = meta
        self.book.record_step(hw, b, kind, time.perf_counter() - t0,
                              precision=self.precision,
                              model=self.model_name)

    def dispatch_labels(self, stack: np.ndarray,
                        valid_hws: List[Tuple[int, int]]):
        """(B, bh, bw, 3) padded batch -> pending device tuple —
        ``(labels, converged)`` label maps (B, bh/4, bw/4) int32 plus
        the per-image convergence flags, with the compact
        ``(rows, counts)`` boxes appended on the device-postprocess
        path.  NON-blocking: the returned arrays are un-materialized
        (JAX async dispatch), so the caller can submit the next bucket's
        batch while this one's H2D/compute/D2H run.  Materialize with
        ``np.asarray`` (the completion stage's job).

        The batch axis may be padded past ``len(valid_hws)`` (batch-size
        rounding); trailing slots are zero images whose outputs are
        discarded by the caller.
        """
        return self._dispatch(stack, valid_hws)[0]

    def infer_labels(self, stack: np.ndarray,
                     valid_hws: List[Tuple[int, int]]) -> np.ndarray:
        """Blocking dispatch + materialized LABEL MAPS (the synchronous
        path; benchmarks' warm loops key on this full-plane D2H)."""
        pending, meta = self._dispatch(stack, valid_hws)
        labels = np.asarray(pending[0])
        self._record_step(meta)
        self._count_nonconverged(np.asarray(pending[1]))
        return labels

    def _count_nonconverged(self, converged) -> None:
        """Count label maps that hit max_iters still changing — the
        silently-unconverged case the CC tail used to swallow.  Padded
        batch slots are all-zero images that converge in one round, so
        counting the full padded batch is exact."""
        k = int(np.size(converged) - np.count_nonzero(converged))
        if k:
            with self._lock:
                self.stats["nonconverged"] += k
            self.book.incr("pp_nonconverged", k)

    def _finalize(self, raw):
        """Materialize one dispatched batch into per-item postprocess
        payloads: a ``(rows, count)`` compact-box tuple per image on the
        device path (falling back to the full label map when the
        component count overflows ``boxes_capacity`` — counted, never
        wrong), or the head's per-image payload on the host path (the
        label map for the CC heads, a tuple of maps for multi-payload
        heads like EAST).  Records the ``stage="step"`` wall and the
        non-convergence counter."""
        pending, meta = raw
        n_payload = self.head.n_payload
        if len(pending) == n_payload + 3:       # device (rows, counts)
            labels, converged, rows, counts = pending
            rows = np.asarray(rows)                  # compact D2H payload
            counts = np.asarray(counts)
            self._record_step(meta)
            self._count_nonconverged(np.asarray(converged))
            out: List[Any] = []
            for i in range(rows.shape[0]):
                if counts[i] > self.boxes_capacity:
                    with self._lock:
                        self.stats["pp_overflow"] += 1
                    self.book.incr("pp_overflow")
                    out.append(np.asarray(labels[i]))
                else:
                    out.append((rows[i], int(counts[i])))
            return out
        arrs = [np.asarray(a) for a in pending[:n_payload]]
        self._record_step(meta)
        self._count_nonconverged(np.asarray(pending[n_payload]))
        if n_payload == 1:
            return [arrs[0][i] for i in range(arrs[0].shape[0])]
        return [tuple(a[i] for a in arrs)
                for i in range(arrs[0].shape[0])]

    def postprocess(self, payload, valid_hw: Tuple[int, int],
                    transposed: bool,
                    bucket_hw: Optional[Tuple[int, int]] = None
                    ) -> List[Dict]:
        """One image's inference payload -> boxes (the serving tail).

        The head owns the decode (models/fcn/heads.py): the CC heads
        type-dispatch device-compact ``(rows, count)`` tuples vs label
        maps, EAST runs its geometry decode + NMS.  The per-image wall
        lands in the CostBook under ``stage="postprocess"`` keyed by
        the bucket shape and the head's decode kind (derived from the
        payload plane when ``bucket_hw`` isn't given — device-compact
        rows carry no plane, so they require it)."""
        t0 = time.perf_counter()
        boxes, kind = self.head.decode(payload, valid_hw)
        if bucket_hw is None:
            plane = self.head.payload_plane(payload)
            if plane is None:
                raise ValueError(
                    "device-compact payloads carry no plane shape; pass "
                    "bucket_hw"
                )
            bucket_hw = (plane[0] * 4, plane[1] * 4)
        self.book.record_step(tuple(bucket_hw), 1, kind,
                              time.perf_counter() - t0,
                              stage="postprocess",
                              model=self.model_name)
        if transposed:                              # inverse transposition
            for b in boxes:
                x0, y0, x1, y1 = b["box"]
                b["box"] = (y0, x0, y1, x1)
        return boxes

    def _record_request(self, dt: float) -> None:
        """One finished request's accounting (any thread may call)."""
        with self._lock:
            self.stats["n"] += 1
            self.stats["latency_s"].append(dt)

    # -- scrapeable metrics (ROADMAP plan-aware autoscaling signals) -----------
    def metrics_snapshot(self) -> Dict[str, float]:
        """Everything an autoscaler needs, flat ``{metric_name: value}``
        (labels embedded Prometheus-style, so the dict stays flat):
        request counts and latency percentiles, the live per-bucket
        plan choices, scheduler queue depth / shed rate / batch
        occupancy / stage busy times (live batcher if running, else the
        last stopped one), and the full telemetry book — measured step
        EWMAs/percentiles per (bucket, batch, plan) plus scheduler
        series.  Field meanings are documented in docs/serving.md.
        Safe to call from any thread at any time."""
        out: Dict[str, float] = {}
        with self._lock:
            n = self.stats["n"]
            lat = list(self.stats["latency_s"])
            transposed = self.stats["transposed"]
            choices = dict(self.stats["plan_choices"])
            mb_snap = self.stats.get("batching_snapshot")
            batcher = self._batcher
        out["std_requests_total"] = float(n)
        out["std_transposed_total"] = float(transposed)
        if lat:
            out["std_request_latency_p50_ms"] = float(
                np.percentile(lat, 50) * 1e3)
            out["std_request_latency_p99_ms"] = float(
                np.percentile(lat, 99) * 1e3)
        for hw, desc in sorted(choices.items()):
            out[f'std_plan_choice{{bucket="{hw[0]}x{hw[1]}",'
                f'plan="{desc}"}}'] = 1.0
        if batcher is not None:             # live scrape beats the last stop
            mb_snap = batcher.stats_snapshot()
        for k, v in (mb_snap or {}).items():
            out[f"std_mb_{k}"] = float(v)
        # per-(bucket,batch,plan,model) engine memory gauges — planned
        # peak always; measured temp/peak for shapes a bench ran
        # measure_engine_memory() on (launch/hlo_analysis buffer sizes)
        for row in list(self.factory.stats.get("engine_memory", [])):
            lbl = (f'bucket="{row["hw"][0]}x{row["hw"][1]}",'
                   f'batch="{row["batch"]}",plan="{row["plan"]}",'
                   f'model="{row["model"]}"')
            out[f"std_engine_planned_peak_bytes{{{lbl}}}"] = float(
                row.get("planned_peak_bytes", 0))
            if "temp_bytes" in row:
                out[f"std_engine_temp_bytes{{{lbl}}}"] = float(
                    row["temp_bytes"])
            if "peak_bytes" in row:
                out[f"std_engine_peak_bytes{{{lbl}}}"] = float(
                    row["peak_bytes"])
        for hw, cap in sorted(self._bucket_caps.items()):
            out[f'std_bucket_batch_cap{{bucket="{hw[0]}x{hw[1]}"}}'] = \
                float(cap)
        out.update(self.book.snapshot())
        return out

    def measure_engine_memory(self, hw: Tuple[int, int],
                              batch: Optional[int] = None) -> Dict[str, Any]:
        """AOT-measure one bucket engine's buffer assignment at ``batch``
        (default: this bucket's effective cap) under the plan routing
        would pick — results land in ``stats["engine_memory"]`` and the
        ``std_engine_*_bytes`` gauges.  Explicit opt-in: one extra
        compile per shape."""
        hw = tuple(hw)
        b = int(batch) if batch is not None else self._bucket_cap(hw)
        m = self._batch_multiple
        b = -(-b // m) * m
        plan = self._plan_for(hw, b)
        return self.factory.measure_engine_memory(
            hw, b, plan, self.precision, self.model_name)

    def metrics_prometheus(self) -> str:
        """:meth:`metrics_snapshot` in Prometheus text-exposition form."""
        return prometheus_text(self.metrics_snapshot())

    def queue_gauges(self) -> Dict[str, float]:
        """Live scheduler load — queued requests and in-flight batches
        (zeros when the batcher is not running).  The cheap subset of
        :meth:`metrics_snapshot` a router polls per placement decision
        (launch/router.py scores replicas with it)."""
        batcher = self._batcher
        if batcher is None:
            return {"queue_depth": 0.0, "inflight": 0.0}
        snap = batcher.stats_snapshot()
        return {"queue_depth": snap.get("queue_depth", 0.0),
                "inflight": snap.get("inflight", 0.0)}

    def __call__(self, img: np.ndarray) -> List[Dict]:
        t0 = time.perf_counter()
        x, valid, tr = self.preprocess(img)
        out = self._finalize(self._dispatch(x[None], [valid]))[0]
        boxes = self.postprocess(out, valid, tr,
                                 bucket_hw=tuple(x.shape[:2]))
        self._record_request(time.perf_counter() - t0)
        return boxes

    # -- pipelined server (C4 module-level multithreading) ---------------------
    def serve_pipelined(self, images: List[np.ndarray]) -> List[List[Dict]]:
        def pre(img):
            return self.preprocess(img)

        def infer(item):
            x, valid, tr = item
            out = self._finalize(self._dispatch(x[None], [valid]))[0]
            return out, valid, tr, tuple(x.shape[:2])

        def post(item):
            out, valid, tr, bhw = item
            return self.postprocess(out, valid, tr, bucket_hw=bhw)

        pipe = HostPipeline([pre, infer, post], maxsize=4)
        t0 = time.perf_counter()
        results = pipe.run(images)
        dt = time.perf_counter() - t0
        with self._lock:
            self.stats["pipelined_tps"] = len(images) / dt
        return results

    # -- micro-batched server (the tentpole path) ------------------------------
    def _mb_infer(self, key, payloads):
        """Dispatch stage: submit one batch, return the PENDING device
        array (plus step-telemetry meta) without blocking — the
        completion stage materializes it, so the next bucket's batch
        dispatches while this one computes."""
        stack = np.stack([p[0] for p in payloads])
        return self._dispatch(stack, [p[1] for p in payloads])

    def _mb_finalize(self, key, raw):
        """Completion stage: block on the device result (D2H — the full
        label planes on the host path, the compact boxes tensor on the
        device path), record the measured step wall, and split into
        per-item payloads (the batch axis may be padded; the scheduler
        zips against live items only)."""
        return self._finalize(raw)

    def _mb_post(self, payload, out):
        x, valid, tr = payload
        return self.postprocess(out, valid, tr, bucket_hw=tuple(x.shape[:2]))

    def start_batched(self) -> "STDService":
        """Start the micro-batching scheduler (idempotent)."""
        if self._batcher is None:
            self._batcher = MicroBatcher(
                self._mb_infer, self._mb_post,
                finalize_fn=self._mb_finalize,
                max_batch=self.max_batch, max_wait_ms=self.max_wait_ms,
                max_pending=self.max_pending, admission=self.admission,
                inflight=self.inflight, book=self.book,
                max_batch_for=(self._bucket_cap
                               if self.activation_budget_bytes is not None
                               else None),
            )
            self._batcher.start()
        return self

    def stop_batched(self) -> None:
        if self._batcher is not None:
            self._batcher.stop()
            with self._lock:
                self.stats["batching"] = self._batcher.stats
                # scalar view survives the batcher for metric scrapes
                self.stats["batching_snapshot"] = \
                    self._batcher.stats_snapshot()
            self._batcher = None

    def submit(self, img: np.ndarray) -> Future:
        """Async request: preprocess on the caller thread (the pipeline's
        pre stage), then enqueue on the bucket's micro-batch."""
        if self._batcher is None:
            raise RuntimeError("call start_batched() first")
        x, valid, tr = self.preprocess(img)
        return self._batcher.submit(x.shape[:2], (x, valid, tr))

    def serve_batched(self, images: List[np.ndarray], *,
                      pre_workers: int = 4) -> List[List[Dict]]:
        """Closed-loop batched serving: preprocess+submit from a small
        thread pool (so buckets actually fill), gather futures in order."""
        started_here = self._batcher is None
        self.start_batched()
        rec = LatencyRecorder()
        t0 = time.perf_counter()

        def one(img):
            t = time.perf_counter()
            return rec.track(self.submit(img), t0=t)

        try:
            with ThreadPoolExecutor(pre_workers) as ex:
                futs = list(ex.map(one, images))
            results = [f.result(timeout=600) for f in futs]
            dt = time.perf_counter() - t0
            rec.wait()               # event-driven: no callback lag race
            with self._lock:
                self.stats["batched_tps"] = len(images) / dt
                self.stats["batched_latency_s"] = rec.samples
            return results
        finally:
            # a failed request must not strand the scheduler threads
            if started_here:
                self.stop_batched()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--mode", default="optimized")
    ap.add_argument("--batched", action="store_true",
                    help="also run the micro-batched scheduler path")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--precision", default="f32", choices=["f32", "bfp"])
    ap.add_argument("--postprocess", default="host",
                    choices=["host", "device"],
                    help="box extraction: host label-map decode or "
                         "on-device compact rows")
    ap.add_argument("--model", default="pixellink",
                    choices=["pixellink", "east", "db"],
                    help="detection head to serve (models/fcn/heads.py "
                         "MODEL_ZOO)")
    args = ap.parse_args(argv)

    from repro.data.images import RequestStream

    svc = STDService(width=args.width, mode=args.mode,
                     max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
                     precision=args.precision, postprocess=args.postprocess,
                     model=args.model)
    images = RequestStream(
        args.requests, seed=0, hw_range=((48, 120), (48, 120))
    ).images()
    # sequential (includes per-bucket compile on first hit)
    t0 = time.perf_counter()
    for img in images:
        svc(img)
    seq_dt = time.perf_counter() - t0
    # pipelined
    out = svc.serve_pipelined(images)
    msg = (f"[serve] {args.requests} reqs  "
           f"sequential {args.requests/seq_dt:.2f} TPS  "
           f"pipelined {svc.stats['pipelined_tps']:.2f} TPS")
    if args.batched:
        out_b = svc.serve_batched(images)
        assert [[b["box"] for b in r] for r in out] == \
               [[b["box"] for b in r] for r in out_b], "batched parity"
        msg += f"  batched {svc.stats['batched_tps']:.2f} TPS"
        sizes = [b["n"] for b in svc.stats["batching"]["batches"]]
        msg += f"  mean batch {np.mean(sizes):.2f}"
    msg += (f"  median latency {np.median(svc.stats['latency_s'])*1e3:.1f} ms"
            f"  boxes[0]={len(out[0])}")
    print(msg)
    return svc.stats


if __name__ == "__main__":
    main()
