"""Pod-scale serving: N replicated STDServices behind a telemetry-driven
router (ROADMAP "pod-scale serving"; the paper's closing claim is stable
*deployed* service, not single-mesh throughput).

Two layers:

  * :class:`ServiceReplica` — wraps one service (anything with
    ``submit() -> Future`` and ``start_batched()``/``stop_batched()``,
    i.e. launch/serve.STDService or an in-process simulator) plus its
    scrape surface: the replica names the service's
    :class:`~repro.runtime.telemetry.CostBook` with a
    ``{"replica": name}`` label so N books aggregate into one snapshot
    without gauge clobbering, tracks its own outstanding-request count
    via done-callbacks, feeds completed-request latencies to a
    :class:`~repro.runtime.fault_tolerance.Watchdog` (replica health),
    and owns the per-replica online refit
    (:meth:`ServiceReplica.refit`: live book -> StepMeasurement rows ->
    :func:`~repro.runtime.telemetry.fit_cost_params` ->
    ``planner.set_params`` — the previously offline ``--calibrate``
    loop, closed online).

  * :class:`Router` — places each request on one replica:

      - ``round_robin``   cycle through healthy replicas (the baseline),
      - ``least_loaded``  fewest queued + in-flight requests (from the
                          service's ``queue_gauges()`` when it exposes
                          them, else the router's outstanding count),
      - ``p99``           minimize ``(load + 1) * step_p99`` where the
                          tail estimate comes from the replica book's
                          p99 step windows — heterogeneous replicas
                          (slower host, bigger bucket mix) attract
                          proportionally less traffic, which is what
                          bounds fleet tail latency.

    Deadline-class admission: every request carries a class,
    ``"interactive"`` or ``"batch"``.  Batch requests stop being
    admitted at ``batch_threshold`` total outstanding while interactive
    requests are admitted up to ``max_outstanding`` — so under overload
    batch traffic sheds FIRST and interactive traffic keeps its
    headroom (sheds raise :class:`~repro.launch.batching.QueueFull`,
    same contract as the scheduler's own admission control).

    Replica health: a replica whose watchdog is in an incident streak
    (``consecutive >= unhealthy_after``) is excluded from placement,
    except for a periodic probe request (every ``probe_every``
    placements) that keeps feeding its watchdog — after a *sustained*
    slowdown the watchdog's EMA adapts (fault_tolerance.Watchdog
    ``adapt_after``), the streak resets, and the replica rejoins.

    The control loop: with ``refit_interval_s`` set, the router
    periodically calls every replica's :meth:`~ServiceReplica.refit`.
    On an event-publishing clock (launch/batching.FakeClock) the loop
    runs synchronously inside ``advance()`` — fully deterministic, no
    real sleeps; on a real clock a background thread wakes per
    interval.

The whole fleet runs in-process; tests/test_router.py drives a
multi-replica fleet on one FakeClock and pins the routing, shed
ordering, and online-refit behaviors deterministically.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.launch.batching import QueueFull
from repro.runtime.fault_tolerance import Watchdog
from repro.runtime.telemetry import (
    StepMeasurement,
    fit_cost_params,
    relabel,
)

POLICIES = ("round_robin", "p99", "least_loaded")
DEADLINE_CLASSES = ("interactive", "batch")


class ServiceReplica:
    """One service instance plus its scrape/health/refit surface.

    ``service`` needs ``submit(payload) -> Future``; ``start_batched``
    / ``stop_batched``, ``book``, ``planner``, ``queue_gauges``,
    ``precision``, ``model_name`` and ``_plan_features`` are all
    optional and duck-typed, so simulators and STDService plug in the
    same way."""

    def __init__(self, name: str, service: Any, *,
                 features_fn: Optional[Callable[[Tuple[int, int]], Any]]
                 = None,
                 watchdog: Optional[Watchdog] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.name = str(name)
        self.service = service
        self.clock = clock
        self.book = getattr(service, "book", None)
        if self.book is not None and hasattr(self.book, "labels"):
            # name the book so N replicas' metrics stay disjoint in one
            # aggregated scrape (an explicit label set on the book wins)
            self.book.labels.setdefault("replica", self.name)
        self.features_fn = (features_fn if features_fn is not None
                            else getattr(service, "_plan_features", None))
        # request-latency watchdog = replica health: warmup absorbs
        # compile-time outliers, adapt_after lets a permanently slower
        # replica become its own baseline and rejoin the fleet
        self.watchdog = (watchdog if watchdog is not None
                         else Watchdog(threshold=3.0, ema=0.5,
                                       warmup_steps=2, adapt_after=3))
        self._lock = threading.Lock()
        self._outstanding = 0
        self._completed = 0
        self._step = 0

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> "ServiceReplica":
        fn = getattr(self.service, "start_batched", None)
        if fn is not None:
            fn()
        return self

    def stop(self) -> None:
        fn = getattr(self.service, "stop_batched", None)
        if fn is not None:
            fn()

    # -- request path ----------------------------------------------------------
    def submit(self, payload: Any) -> Future:
        t0 = self.clock()
        fut = self.service.submit(payload)
        with self._lock:
            self._outstanding += 1

        def _done(f: Future) -> None:
            dt = self.clock() - t0
            with self._lock:
                self._outstanding -= 1
                self._completed += 1
                self._step += 1
                step = self._step
            # errored requests are not latency evidence; the watchdog
            # only learns from completed ones
            if f.exception() is None:
                self.watchdog.observe(step, dt)

        fut.add_done_callback(_done)
        return fut

    # -- scoring signals -------------------------------------------------------
    def outstanding(self) -> int:
        with self._lock:
            return self._outstanding

    def load(self) -> float:
        """Queued + in-flight work: the service's own scheduler gauges
        (``queue_gauges()``) when it runs a MicroBatcher, else the
        router-side outstanding count (exact for simulators)."""
        gauges = getattr(self.service, "queue_gauges", None)
        if gauges is not None:
            g = gauges()
            return float(g.get("queue_depth", 0.0)
                         + g.get("inflight", 0.0))
        return float(self.outstanding())

    def step_p99(self) -> Optional[float]:
        """Mean of the book's p99 step walls across every measured
        (bucket, batch, plan) combo for this service's precision/model —
        one scalar tail estimate per replica; None until anything is
        measured."""
        book = self.book
        if book is None:
            return None
        precision = getattr(self.service, "precision", "f32")
        model = getattr(self.service, "model_name", "pixellink")
        vals = []
        for hw, batch, kind in book.step_keys(stage="step",
                                              precision=precision,
                                              model=model):
            p = book.step_percentile(hw, batch, kind, 99, stage="step",
                                     precision=precision, model=model)
            if p is not None:
                vals.append(p)
        if not vals:
            return None
        return sum(vals) / len(vals)

    def healthy(self, unhealthy_after: int) -> bool:
        return self.watchdog.consecutive < unhealthy_after

    # -- online refit ----------------------------------------------------------
    def refit(self) -> Optional[Any]:
        """Fit CostParams from this replica's live book and swap them
        into its planner (``Planner.set_params``) — the offline
        ``serve_bench --calibrate`` loop, run online.  Returns the
        fitted params, or None when the replica has no planner, no
        book, no features, or no measurements yet."""
        planner = getattr(self.service, "planner", None)
        book = self.book
        if planner is None or book is None or self.features_fn is None:
            return None
        precision = getattr(self.service, "precision", "f32")
        model = getattr(self.service, "model_name", "pixellink")
        rows: List[StepMeasurement] = []
        for hw, batch, kind in book.step_keys(stage="step",
                                              precision=precision,
                                              model=model):
            seconds = book.step_ewma(hw, batch, kind, stage="step",
                                     precision=precision, model=model)
            if seconds is None:
                continue
            f = self.features_fn(hw)
            rows.append(StepMeasurement(
                flops=f.flops, halo_bytes=f.halo_bytes,
                halo_layers=f.halo_layers, kind=kind, batch=batch,
                data_n=planner.data_n, model_n=planner.model_n,
                seconds=seconds,
            ))
        if not rows:
            return None
        fitted = fit_cost_params(rows, base=planner.params)
        planner.set_params(fitted)
        return fitted

    # -- scrape ----------------------------------------------------------------
    def metrics_snapshot(self) -> Dict[str, float]:
        """The service's full snapshot plus replica-level gauges, every
        metric name carrying this replica's label (names the book
        already labeled keep theirs)."""
        out: Dict[str, float] = {}
        snap_fn = getattr(self.service, "metrics_snapshot", None)
        if snap_fn is not None:
            out.update(snap_fn())
        elif self.book is not None:
            out.update(self.book.snapshot())
        with self._lock:
            out["std_replica_outstanding"] = float(self._outstanding)
            out["std_replica_completed_total"] = float(self._completed)
        out["std_replica_watchdog_streak"] = float(
            self.watchdog.consecutive)
        out["std_replica_watchdog_incidents_total"] = float(
            len(self.watchdog.incidents))
        return relabel(out, replica=self.name)


class Router:
    """Places requests across replicas; see the module docstring for
    the policy, admission, health, and control-loop semantics."""

    def __init__(self, replicas: List[ServiceReplica], *,
                 policy: str = "p99",
                 max_outstanding: int = 0,
                 batch_threshold: Optional[int] = None,
                 unhealthy_after: int = 3,
                 probe_every: int = 8,
                 refit_interval_s: Optional[float] = None,
                 default_step_s: float = 0.0,
                 clock: Callable[[], float] = time.perf_counter):
        if not replicas:
            raise ValueError("Router needs at least one replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"replica names must be unique: {names}")
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"expected one of {POLICIES}")
        if max_outstanding < 0 or (batch_threshold is not None
                                   and batch_threshold < 0):
            raise ValueError("outstanding bounds must be >= 0")
        self.replicas = list(replicas)
        self.policy = policy
        self.max_outstanding = max_outstanding        # 0 = unbounded
        # batch-class admission stops at this total outstanding depth
        # (default: half the cap), interactive continues to the cap —
        # that ordering is the deadline-class shed policy
        self.batch_threshold = (
            batch_threshold if batch_threshold is not None
            else max_outstanding // 2)
        self.unhealthy_after = unhealthy_after
        self.probe_every = probe_every
        self.refit_interval_s = refit_interval_s
        # an unmeasured replica's tail estimate under the p99 policy:
        # 0.0 makes fresh replicas look free, so they get explored (and
        # measured) before scoring starts discriminating
        self.default_step_s = default_step_s
        self.clock = clock
        self._lock = threading.Lock()
        self.stats: Dict[str, Any] = {
            "submitted": {c: 0 for c in DEADLINE_CLASSES},
            "shed": {c: 0 for c in DEADLINE_CLASSES},
            "placed": {r.name: 0 for r in self.replicas},
            "probes": 0,
            "refits": 0,
        }
        self._outstanding = 0
        self._rr = 0
        self._probe_rr = 0
        self._since_probe = 0
        self._started = False
        self._next_refit: Optional[float] = None
        self._refit_thread: Optional[threading.Thread] = None
        self._stop_ev = threading.Event()
        self._event_driven = hasattr(clock, "subscribe")
        if self._event_driven and refit_interval_s is not None:
            clock.subscribe(self._on_tick)

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> "Router":
        if self._started:
            return self
        for r in self.replicas:
            r.start()
        self._started = True
        if self.refit_interval_s is not None:
            self._next_refit = self.clock() + self.refit_interval_s
            if not self._event_driven:
                self._stop_ev.clear()
                self._refit_thread = threading.Thread(
                    target=self._refit_loop, name="router-refit",
                    daemon=True)
                self._refit_thread.start()
        return self

    def stop(self) -> None:
        if not self._started:
            return
        self._started = False
        self._stop_ev.set()
        if self._refit_thread is not None:
            self._refit_thread.join()
            self._refit_thread = None
        for r in self.replicas:
            r.stop()

    def __enter__(self) -> "Router":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- control loop ----------------------------------------------------------
    def _refit_loop(self) -> None:
        while not self._stop_ev.wait(self.refit_interval_s):
            self.refit_now()

    def _on_tick(self) -> None:
        """Event-driven control loop: runs synchronously inside a
        FakeClock ``advance()``, so refits land at deterministic fake
        times."""
        if not self._started or self._next_refit is None:
            return
        now = self.clock()
        while now >= self._next_refit:
            self._next_refit += self.refit_interval_s
            self.refit_now()

    def refit_now(self) -> Dict[str, Any]:
        """Re-fit every replica's CostParams from its live book and
        swap them into its planner.  Returns {replica_name: params} for
        the replicas that had measurements."""
        fitted = {}
        for r in self.replicas:
            p = r.refit()
            if p is not None:
                fitted[r.name] = p
        with self._lock:
            self.stats["refits"] += 1
        return fitted

    # -- placement -------------------------------------------------------------
    def submit(self, payload: Any, *,
               deadline_class: str = "interactive") -> Future:
        """Admit (or shed) one request and place it on a replica.
        Sheds raise :class:`~repro.launch.batching.QueueFull`."""
        if deadline_class not in DEADLINE_CLASSES:
            raise ValueError(f"unknown deadline class {deadline_class!r}; "
                             f"expected one of {DEADLINE_CLASSES}")
        if not self._started:
            raise RuntimeError("call start() first")
        with self._lock:
            cap = (self.max_outstanding
                   if deadline_class == "interactive"
                   else self.batch_threshold or self.max_outstanding)
            if self.max_outstanding > 0 and self._outstanding >= cap:
                self.stats["shed"][deadline_class] += 1
                raise QueueFull(
                    f"{deadline_class} admission at {self._outstanding} "
                    f"outstanding (cap {cap})"
                )
            replica = self.replicas[self._place_locked()]
            self._outstanding += 1
            self.stats["submitted"][deadline_class] += 1
            self.stats["placed"][replica.name] += 1
        try:
            fut = replica.submit(payload)
        except BaseException:
            # the service's own admission control may shed after the
            # router admitted — roll the outstanding count back so the
            # router's cap does not leak
            with self._lock:
                self._outstanding -= 1
                self.stats["shed"][deadline_class] += 1
            raise

        def _done(f: Future) -> None:
            with self._lock:
                self._outstanding -= 1

        fut.add_done_callback(_done)
        return fut

    def _place_locked(self) -> int:
        idx = list(range(len(self.replicas)))
        healthy = [i for i in idx
                   if self.replicas[i].healthy(self.unhealthy_after)]
        unhealthy = [i for i in idx if i not in healthy]
        if not healthy:
            healthy = idx              # degraded fleet: route anyway
        elif unhealthy:
            # keep probing excluded replicas so their watchdogs see
            # traffic — the EMA adapts, the streak resets, they rejoin
            self._since_probe += 1
            if self._since_probe >= self.probe_every:
                self._since_probe = 0
                self._probe_rr += 1
                self.stats["probes"] += 1
                return unhealthy[self._probe_rr % len(unhealthy)]
        if self.policy == "round_robin":
            self._rr += 1
            return healthy[self._rr % len(healthy)]
        if self.policy == "least_loaded":
            return min(healthy,
                       key=lambda i: (self.replicas[i].load(), i))
        # p99: queue-discounted tail estimate — a slow replica must be
        # this much emptier before it wins a placement
        def score(i: int) -> Tuple[float, float, int]:
            r = self.replicas[i]
            p99 = r.step_p99()
            if p99 is None:
                p99 = self.default_step_s
            load = r.load()
            return ((load + 1.0) * p99, load, i)
        return min(healthy, key=score)

    # -- scrape ----------------------------------------------------------------
    def outstanding(self) -> int:
        with self._lock:
            return self._outstanding

    def metrics_snapshot(self) -> Dict[str, float]:
        """One flat scrape for the whole fleet: every replica's
        snapshot (names disjoint via the per-replica label) plus
        router-level placement/shed/refit counters."""
        out: Dict[str, float] = {}
        for r in self.replicas:
            out.update(r.metrics_snapshot())
        with self._lock:
            out["std_router_outstanding"] = float(self._outstanding)
            out["std_router_refits_total"] = float(self.stats["refits"])
            out["std_router_probes_total"] = float(self.stats["probes"])
            for c in DEADLINE_CLASSES:
                out[f'std_router_submitted_total{{class="{c}"}}'] = float(
                    self.stats["submitted"][c])
                out[f'std_router_shed_total{{class="{c}"}}'] = float(
                    self.stats["shed"][c])
            for name, n in self.stats["placed"].items():
                out[f'std_router_placed_total{{replica="{name}"}}'] = \
                    float(n)
        return out
