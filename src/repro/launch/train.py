"""End-to-end training driver.

Runs on whatever devices exist (CPU smoke -> TPU pod): builds the model
from a config, sets up AdamW + schedule, deterministic data, async
checkpointing, watchdog and preemption guard, then drives TrainRunner.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args(argv)

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config, get_smoke_config
    from repro.data import TokenDataset
    from repro.models.lm import LMModel, cross_entropy
    from repro.optim import adamw, clip_by_global_norm, cosine_with_warmup
    from repro.optim.grad_utils import (
        GradAccumulator, error_feedback_compress, init_residual,
    )
    from repro.runtime.fault_tolerance import (
        PreemptionGuard, TrainRunner, Watchdog,
    )

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LMModel(cfg)
    ds = TokenDataset(cfg.vocab, args.seq, args.batch, seed=0)

    opt_init, opt_update = adamw(
        cosine_with_warmup(args.lr, 20, max(args.steps, 21)),
        moment_dtype=args.moment_dtype, weight_decay=0.01,
    )
    accum = GradAccumulator(args.n_micro)

    def loss_fn(params, batch):
        kw = {}
        if cfg.frontend != "none":
            kw["prefix_embed"] = jnp.zeros(
                (batch["tokens"].shape[0], cfg.frontend_len, cfg.d_model),
                jnp.dtype(cfg.compute_dtype),
            )
        logits = model.forward(params, batch["tokens"], mode="train", **kw)
        return cross_entropy(logits, batch["labels"])

    @jax.jit
    def step_fn(state, batch):
        params, opt_state, residual = state
        loss, grads = accum(loss_fn, params, batch)
        if args.grad_compression:
            grads, residual = error_feedback_compress(grads, residual)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt_update(grads, opt_state, params)
        return (params, opt_state, residual), {"loss": loss,
                                               "grad_norm": gnorm}

    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = opt_init(params)
    residual = init_residual(params) if args.grad_compression else jnp.zeros(())
    state = (params, opt_state, residual)

    ckpt_dir = args.ckpt_dir or os.path.join("/tmp", f"repro_{args.arch}")
    cm = CheckpointManager(ckpt_dir, keep=3)
    losses = []

    def batch_fn(step):
        return jax.tree_util.tree_map(jnp.asarray, ds.batch(step))

    def wrapped_step(state, batch):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        return state, metrics

    runner = TrainRunner(
        wrapped_step, batch_fn, cm, ckpt_every=args.ckpt_every,
        watchdog=Watchdog(), guard=PreemptionGuard(install=True),
    )
    start, state = runner.resume_or_init(state)
    if start:
        print(f"[train] resumed from step {start}")
    t0 = time.time()
    step, state, status = runner.run(state, start, args.steps - start,
                                     fail_at=args.fail_at)
    dt = time.time() - t0
    logs = runner.metrics_log
    for m in logs[:: max(args.log_every, 1)]:
        print(f"[train] step {m['step']:5d} loss {m['loss']:.4f} "
              f"dt {m['dt']*1e3:.0f}ms")
    if logs:
        print(f"[train] {status} at step {step}; final loss "
              f"{logs[-1]['loss']:.4f}; {dt:.1f}s total; "
              f"straggler incidents: {len(runner.watchdog.incidents)}")
    return logs


if __name__ == "__main__":
    main()
