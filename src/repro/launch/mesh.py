"""Production mesh definitions.

Single pod = 16x16 (256 chips, v5e-class pod); multi-pod = 2 pods.
A FUNCTION, not a module-level constant — importing this module must not
touch jax device state (the dry-run sets XLA_FLAGS before first jax use).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Version-portable ``jax.make_mesh``: newer JAX wants explicit
    ``axis_types`` (``jax.sharding.AxisType`` appeared after 0.4.x);
    older JAX has neither the enum nor the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(axis_type.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")) -> jax.sharding.Mesh:
    """Tiny mesh over however many devices the host actually has (tests)."""
    return make_mesh(shape, axes)


# v5e-class hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link (~3 usable links/chip on a
N_ICI_LINKS = 3                 # 2D-torus v5e class part)
HBM_PER_CHIP = 16 * 2**30       # 16 GiB
