"""Dynamic micro-batching for the serving path (paper Fig. 2/9).

The paper's deployment wins OpEx by keeping the engine busy with batches
instead of single images.  This module provides the request scheduler
that makes that possible behind an async `submit() -> Future` API:

  * requests are grouped by a caller-supplied bucket key (the padded
    (H, W) shape, so every image in a batch shares one compiled engine),
  * a bucket flushes when it reaches ``max_batch`` ("full") or when its
    oldest request has waited ``max_wait_ms`` ("timeout"),
  * admission control: ``max_pending`` bounds the total queued depth so
    overload sheds ("reject" -> :class:`QueueFull`) or backpressures
    ("block") instead of growing the queue without bound,
  * one infer thread serializes device work (batches from different
    buckets interleave, never overlap), and a small post pool scatters
    per-item results back to futures — so host preprocess (caller
    threads), device inference, and host postprocess overlap exactly
    like the paper's C4 module-level pipeline.

The scheduler is model-agnostic: ``infer_fn(key, payloads) -> outputs``
runs one batch, ``post_fn(payload, output) -> result`` finishes one item.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, Hashable, List, Optional


class QueueFull(RuntimeError):
    """submit() rejected: the scheduler's pending queue is at
    ``max_pending`` and the admission policy is "reject"."""


def wait_for_samples(samples, n: int, timeout_s: float = 5.0) -> None:
    """Block until ``samples`` holds ``n`` entries (or timeout).

    Future.set_result wakes result() waiters *before* running
    done-callbacks, so latency lists appended from callbacks can lag the
    final result() return — tail percentiles computed immediately would
    see a truncated sample set.  Callers collect results, then wait here
    before reading the samples."""
    deadline = time.perf_counter() + timeout_s
    while len(samples) < n and time.perf_counter() < deadline:
        time.sleep(0.001)


def round_batch(n: int, max_batch: int, mode: str = "pow2") -> int:
    """Padded batch size for ``n`` live items: "pow2" rounds up to the
    next power of two (<= max_batch) so each bucket compiles at most
    log2(max_batch)+1 engine variants; "none" keeps the exact size."""
    if mode == "none":
        return n
    if mode == "pow2":
        b = 1
        while b < n:
            b *= 2
        return min(b, max_batch) if n <= max_batch else n
    raise ValueError(f"unknown batch rounding mode: {mode}")


class LRUCache:
    """Tiny LRU for compiled engines: key -> value, least-recently-used
    eviction at ``capacity`` (0 or negative = unbounded)."""

    def __init__(self, capacity: int = 8):
        self.capacity = capacity
        self._d: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
                self.hits += 1
                return self._d[key]
            self.misses += 1
            return None

    def put(self, key: Hashable, value: Any) -> None:
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while self.capacity > 0 and len(self._d) > self.capacity:
                self._d.popitem(last=False)

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._d


@dataclasses.dataclass
class _Item:
    key: Hashable
    payload: Any
    future: Future
    t_submit: float


class MicroBatcher:
    """Async request queue -> bucketed micro-batches -> futures.

    Lifecycle: ``start()`` / ``stop()`` (or use as a context manager).
    ``stop()`` drains every pending request before returning.
    """

    def __init__(
        self,
        infer_fn: Callable[[Hashable, List[Any]], List[Any]],
        post_fn: Optional[Callable[[Any, Any], Any]] = None,
        *,
        max_batch: int = 8,
        max_wait_ms: float = 5.0,
        queue_depth: int = 4,
        post_workers: int = 2,
        max_pending: int = 0,
        admission: str = "block",
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if admission not in ("block", "reject"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.infer_fn = infer_fn
        self.post_fn = post_fn
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.queue_depth = queue_depth
        self.post_workers = post_workers
        self.max_pending = max_pending           # 0 = unbounded
        self.admission = admission
        self._cond = threading.Condition()
        self._pending: Dict[Hashable, deque] = {}
        self._n_pending = 0                      # total items across buckets
        self._stop = False
        self._running = False
        self.stats: Dict[str, Any] = {
            "batches": [],            # {key, n, reason, queued_ms}
            "flush_full": 0,
            "flush_timeout": 0,
            "flush_drain": 0,
            "submitted": 0,
            "rejected": 0,            # admission-control sheds
            "item_latency_s": [],     # submit -> future resolved
        }

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> "MicroBatcher":
        if self._running:
            return self
        self._stop = False
        self._running = True
        self._infer_q: "queue.Queue" = queue.Queue(maxsize=self.queue_depth)
        self._post_pool = ThreadPoolExecutor(
            self.post_workers, thread_name_prefix="mb-post"
        )
        self._sched_t = threading.Thread(
            target=self._sched_loop, name="mb-sched", daemon=True
        )
        self._infer_t = threading.Thread(
            target=self._infer_loop, name="mb-infer", daemon=True
        )
        self._sched_t.start()
        self._infer_t.start()
        return self

    def stop(self) -> None:
        if not self._running:
            return
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._sched_t.join()
        self._infer_t.join()
        self._post_pool.shutdown(wait=True)
        self._running = False

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request side ----------------------------------------------------------
    def submit(self, key: Hashable, payload: Any) -> Future:
        """Enqueue one request.  At ``max_pending`` queued items the
        admission policy applies: "reject" raises :class:`QueueFull`
        immediately (load shedding), "block" waits for the scheduler to
        drain a batch (backpressure on the caller thread)."""
        fut: Future = Future()
        with self._cond:
            if self._stop or not self._running:
                raise RuntimeError("MicroBatcher is not running")
            while self.max_pending > 0 and self._n_pending >= self.max_pending:
                if self.admission == "reject":
                    self.stats["rejected"] += 1
                    raise QueueFull(
                        f"pending queue at max_pending={self.max_pending}"
                    )
                self._cond.wait()
                if self._stop or not self._running:
                    raise RuntimeError("MicroBatcher is not running")
            item = _Item(key, payload, fut, time.perf_counter())
            self._pending.setdefault(key, deque()).append(item)
            self._n_pending += 1
            self.stats["submitted"] += 1
            self._cond.notify_all()
        return fut

    # -- scheduler thread ------------------------------------------------------
    def _next_batch(self):
        """Block until a bucket is ready; None once stopped AND drained."""
        with self._cond:
            while True:
                now = time.perf_counter()
                ready_key, reason, deadline = None, None, None
                for k, dq in self._pending.items():
                    if not dq:
                        continue
                    if len(dq) >= self.max_batch:
                        ready_key, reason = k, "full"
                        break
                    if self._stop:
                        ready_key, reason = k, "drain"
                        break
                    d = dq[0].t_submit + self.max_wait_s
                    if d <= now:
                        ready_key, reason = k, "timeout"
                        break
                    deadline = d if deadline is None else min(deadline, d)
                if ready_key is not None:
                    dq = self._pending[ready_key]
                    n = min(len(dq), self.max_batch)
                    items = [dq.popleft() for _ in range(n)]
                    self._n_pending -= n
                    self._cond.notify_all()      # wake blocked submitters
                    return ready_key, reason, items
                if self._stop:
                    return None
                self._cond.wait(
                    timeout=None if deadline is None
                    else max(deadline - now, 0.0)
                )

    def _sched_loop(self):
        while True:
            batch = self._next_batch()
            self._infer_q.put(batch)          # None = drained sentinel
            if batch is None:
                return

    # -- infer thread ----------------------------------------------------------
    def _infer_loop(self):
        while True:
            got = self._infer_q.get()
            if got is None:
                return
            key, reason, items = got
            self.stats[f"flush_{reason}"] += 1
            self.stats["batches"].append({
                "key": key, "n": len(items), "reason": reason,
                "queued_ms": (time.perf_counter() - items[0].t_submit) * 1e3,
            })
            try:
                outs = self.infer_fn(key, [it.payload for it in items])
            except Exception as e:
                for it in items:
                    it.future.set_exception(e)
                continue
            for it, out in zip(items, outs):
                if self.post_fn is None:
                    self._resolve(it, out)
                else:
                    self._post_pool.submit(self._post_one, it, out)

    def _post_one(self, item: _Item, out: Any):
        try:
            self._resolve(item, self.post_fn(item.payload, out))
        except Exception as e:
            item.future.set_exception(e)

    def _resolve(self, item: _Item, result: Any):
        self.stats["item_latency_s"].append(
            time.perf_counter() - item.t_submit
        )
        item.future.set_result(result)
