"""Dynamic micro-batching for the serving path (paper Fig. 2/9).

The paper's deployment wins OpEx by keeping the engine busy with batches
instead of single images.  This module provides the request scheduler
that makes that possible behind an async `submit() -> Future` API:

  * requests are grouped by a caller-supplied bucket key (the padded
    (H, W) shape, so every image in a batch shares one compiled engine),
  * a bucket flushes when it reaches ``max_batch`` ("full") or when its
    oldest request has waited ``max_wait_ms`` ("timeout"),
  * admission control: ``max_pending`` bounds the total queued depth so
    overload sheds ("reject" -> :class:`QueueFull`) or backpressures
    ("block") instead of growing the queue without bound,
  * the device path is a two-stage pipeline (the paper's C4
    module-level multithreading applied to the engine itself): the
    DISPATCH stage submits a batch's computation and — when the engine
    is asynchronous, i.e. ``infer_fn`` returns un-materialized device
    arrays the way JAX async dispatch does — immediately moves on to
    the next bucket's batch, while the COMPLETION stage blocks on the
    pending result (``finalize_fn``) and scatters per-item outputs to a
    small post pool.  A bounded queue of depth ``inflight`` sits
    between the stages, so H2D/compute/D2H of different buckets overlap
    without unbounded device-memory growth; ``inflight=0`` collapses
    the two stages back into one thread (the fully synchronous path).

Time is read through an injectable ``clock`` (default
``time.perf_counter``): flush deadlines, queued/latency stats all use
it, and with a non-real clock the scheduler waits event-driven (a
:class:`FakeClock` notifies :meth:`MicroBatcher.wake` on every advance)
instead of on real timeouts — so timeout-flush tests run without real
sleeps.

The scheduler is model-agnostic: ``infer_fn(key, payloads) -> raw``
runs one batch (returning either final outputs or a pending device
handle), ``finalize_fn(key, raw) -> outputs`` materializes it, and
``post_fn(payload, output) -> result`` finishes one item.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, Hashable, List, Optional


class QueueFull(RuntimeError):
    """submit() rejected: the scheduler's pending queue is at
    ``max_pending`` and the admission policy is "reject"."""


class LatencyRecorder:
    """Event-driven per-request latency samples (replaces the old
    ``wait_for_samples`` sleep-polling helper).

    ``Future.set_result`` wakes ``result()`` waiters *before* running
    done-callbacks, so a latency list appended from callbacks can lag
    the final ``result()`` return.  ``track(fut)`` registers a callback
    that appends the sample and releases a semaphore; ``wait()``
    acquires once per tracked future, so when it returns every sample
    has landed — no sleep loop, no truncated tail percentiles.

    Done-callbacks run on whichever thread resolves the future
    (mb-post workers, completion stage, ...), so ``samples`` is a
    shared list: appends happen under ``_lock``, and ``wait()`` returns
    a snapshot copied under the same lock — callers can sort/percentile
    the return value while later-tracked futures keep resolving."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.samples: List[float] = []
        self._clock = clock
        self._sem = threading.Semaphore(0)
        self._lock = threading.Lock()
        self._tracked = 0

    def track(self, fut: Future, t0: Optional[float] = None) -> Future:
        """Register one future; latency is measured from ``t0`` (or from
        now) to the moment the future resolves."""
        t = self._clock() if t0 is None else t0
        with self._lock:
            self._tracked += 1

        def _record(f, t=t):
            dt = self._clock() - t
            with self._lock:
                self.samples.append(dt)
            self._sem.release()

        fut.add_done_callback(_record)
        return fut

    def wait(self, timeout_s: float = 60.0) -> List[float]:
        """Block until every tracked future's sample has landed; returns
        a snapshot of the samples (not the live list)."""
        deadline = time.monotonic() + timeout_s
        with self._lock:
            n, self._tracked = self._tracked, 0
        for _ in range(n):
            left = deadline - time.monotonic()
            if left <= 0 or not self._sem.acquire(timeout=left):
                raise TimeoutError(
                    f"latency samples missing after {timeout_s}s"
                )
        with self._lock:
            return list(self.samples)


class FakeClock:
    """Deterministic manual clock for scheduler tests.

    Calling the instance reads the current fake time; :meth:`advance`
    moves it forward and notifies every subscriber — a
    :class:`MicroBatcher` built with ``clock=FakeClock()`` subscribes
    its :meth:`~MicroBatcher.wake`, so timeout flushes fire exactly when
    the test advances time, with no real sleeps anywhere."""

    def __init__(self, t0: float = 0.0):
        self._t = t0
        self._lock = threading.Lock()
        self._subs: List[Callable[[], None]] = []

    def __call__(self) -> float:
        with self._lock:
            return self._t

    def subscribe(self, fn: Callable[[], None]) -> None:
        with self._lock:
            self._subs.append(fn)

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("clocks only move forward")
        with self._lock:
            self._t += dt
            t, subs = self._t, list(self._subs)
        for fn in subs:
            fn()
        return t


def round_batch(n: int, max_batch: int, mode: str = "pow2") -> int:
    """Padded batch size for ``n`` live items: "pow2" rounds up to the
    next power of two (<= max_batch) so each bucket compiles at most
    log2(max_batch)+1 engine variants; "none" keeps the exact size."""
    if mode == "none":
        return n
    if mode == "pow2":
        b = 1
        while b < n:
            b *= 2
        return min(b, max_batch) if n <= max_batch else n
    raise ValueError(f"unknown batch rounding mode: {mode}")


class LRUCache:
    """Tiny LRU for compiled engines: key -> value, least-recently-used
    eviction at ``capacity`` (0 or negative = unbounded).

    ``byte_budget`` adds a second, byte-weighted eviction rule: callers
    that know an entry's footprint pass ``put(key, value, weight=bytes)``
    and the cache also evicts LRU-first while the summed weights exceed
    the budget (0 = no byte rule).  The most-recent entry always stays —
    a single engine over budget must still be usable.  Entries stored
    without a weight count 0 bytes (capacity still bounds them).
    """

    def __init__(self, capacity: int = 8, *, byte_budget: int = 0):
        self.capacity = capacity
        self.byte_budget = byte_budget
        self._d: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._w: Dict[Hashable, int] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
                self.hits += 1
                return self._d[key]
            self.misses += 1
            return None

    def put(self, key: Hashable, value: Any, *, weight: int = 0) -> None:
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            self._w[key] = int(weight)
            while self.capacity > 0 and len(self._d) > self.capacity:
                k, _ = self._d.popitem(last=False)
                self._w.pop(k, None)
            while (self.byte_budget > 0 and len(self._d) > 1
                   and sum(self._w.values()) > self.byte_budget):
                k, _ = self._d.popitem(last=False)
                self._w.pop(k, None)

    @property
    def weight_bytes(self) -> int:
        """Summed weights of resident entries."""
        with self._lock:
            return sum(self._w.values())

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._d


@dataclasses.dataclass
class _Item:
    key: Hashable
    payload: Any
    future: Future
    t_submit: float


class MicroBatcher:
    """Async request queue -> bucketed micro-batches -> futures.

    Lifecycle: ``start()`` / ``stop()`` (or use as a context manager).
    ``stop()`` drains every pending request before returning.

    Threads: ``mb-sched`` forms batches, ``mb-dispatch`` runs
    ``infer_fn`` (non-blocking under JAX async dispatch), ``mb-complete``
    runs ``finalize_fn`` on the pending result (the stage that actually
    blocks on the device), and a small ``mb-post`` pool scatters per-item
    results.  At most ``inflight`` dispatched-but-unfinalized batches
    queue between dispatch and completion (plus the one each stage is
    holding), which bounds device memory while letting H2D/compute/D2H
    of consecutive batches overlap.  ``inflight=0`` finalizes inline in
    the dispatch thread — the fully serialized legacy path.
    """

    def __init__(
        self,
        infer_fn: Callable[[Hashable, List[Any]], Any],
        post_fn: Optional[Callable[[Any, Any], Any]] = None,
        *,
        finalize_fn: Optional[Callable[[Hashable, Any], List[Any]]] = None,
        max_batch: int = 8,
        max_wait_ms: float = 5.0,
        queue_depth: int = 4,
        post_workers: int = 2,
        max_pending: int = 0,
        admission: str = "block",
        inflight: int = 1,
        clock: Callable[[], float] = time.perf_counter,
        book: Optional[Any] = None,
        max_batch_for: Optional[Callable[[Hashable], int]] = None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if inflight < 0:
            raise ValueError("inflight must be >= 0")
        if admission not in ("block", "reject"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.infer_fn = infer_fn
        self.post_fn = post_fn
        self.finalize_fn = finalize_fn
        self.max_batch = max_batch
        # optional per-bucket batch cap (memory-aware batching): the
        # scheduler flushes bucket ``key`` at min(max_batch,
        # max_batch_for(key)).  The callable must be cheap — it runs
        # under the scheduler condition lock (cache inside, as
        # STDService._bucket_cap does).
        self.max_batch_for = max_batch_for
        self.max_wait_s = max_wait_ms / 1e3
        self.queue_depth = queue_depth
        self.post_workers = post_workers
        self.max_pending = max_pending           # 0 = unbounded
        self.admission = admission
        self.inflight = inflight
        self.clock = clock
        # telemetry sink (runtime/telemetry.CostBook): per-batch stage
        # timing series, shed/submit counters, batch occupancy — the
        # autoscaling signals STDService.metrics_snapshot() exports
        # (live queue depth / in-flight come from stats_snapshot(), so
        # their metric names stay per-batcher even on a shared book).
        # The book carries its own leaf lock and never takes _cond or
        # _stats_lock, so recording from any point here is inversion-free.
        self.book = book
        # flush deadlines are measured on the injected clock.  A clock
        # that publishes advances (has ``subscribe``, like FakeClock) is
        # event-driven: the scheduler waits without a real timeout and
        # the clock wakes it on every advance.  Any plain callable
        # (perf_counter, monotonic, ...) is assumed to tick in real
        # seconds, so deadline deltas convert directly to wait timeouts.
        self._event_driven = hasattr(clock, "subscribe")
        if self._event_driven:
            clock.subscribe(self.wake)
        self._cond = threading.Condition()
        self._pending: Dict[Hashable, deque] = {}
        self._n_pending = 0                      # total items across buckets
        self._in_flight = 0                      # dispatched, not finalized
        self._wall_s = 0.0                       # running wall across starts
        self._stop = False
        self._running = False
        # stats are mutated from scheduler, dispatch, completion, post,
        # and caller threads — every mutation holds _stats_lock (the
        # counters are read-modify-write, so the GIL alone loses updates)
        self._stats_lock = threading.Lock()
        self.stats: Dict[str, Any] = {
            "batches": [],            # {key, n, reason, queued_ms}
            "flush_full": 0,
            "flush_timeout": 0,
            "flush_drain": 0,
            "submitted": 0,
            "batch_items": 0,         # running sum of formed-batch sizes
            "rejected": 0,            # admission-control sheds
            "finalize_short": 0,      # finalize arity errors (stranded futures)
            "item_latency_s": [],     # submit -> future resolved
            "pending_peak": 0,        # max queued items ever observed
            "inflight_peak": 0,       # max dispatched-but-unfinalized
            "dispatch_busy_s": 0.0,   # real time inside infer_fn
            "complete_busy_s": 0.0,   # real time inside finalize_fn
            "post_busy_s": 0.0,       # real time inside post_fn (all workers)
            "stage_occupancy": {},    # busy/wall per stage, set by stop()
        }

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> "MicroBatcher":
        if self._running:
            return self
        self._stop = False
        self._running = True
        self._in_flight = 0
        self._infer_q: "queue.Queue" = queue.Queue(maxsize=self.queue_depth)
        # dispatch -> completion handoff; its bound IS the in-flight bound
        self._done_q: "queue.Queue" = queue.Queue(
            maxsize=max(self.inflight, 1)
        )
        self._post_pool = ThreadPoolExecutor(
            self.post_workers, thread_name_prefix="mb-post"
        )
        self._sched_t = threading.Thread(
            target=self._sched_loop, name="mb-sched", daemon=True
        )
        self._dispatch_t = threading.Thread(
            target=self._dispatch_loop, name="mb-dispatch", daemon=True
        )
        self._complete_t = (
            threading.Thread(target=self._complete_loop, name="mb-complete",
                             daemon=True)
            if self.inflight > 0 else None
        )
        # occupancy is a wall-time diagnostic, always on the real clock;
        # wall accumulates across stop()/start() cycles because the busy
        # counters (and every other stat) do too
        self._t_start = time.perf_counter()
        self._sched_t.start()
        self._dispatch_t.start()
        if self._complete_t is not None:
            self._complete_t.start()
        return self

    def stop(self) -> None:
        if not self._running:
            return
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._sched_t.join()
        self._dispatch_t.join()
        if self._complete_t is not None:
            self._complete_t.join()
        self._post_pool.shutdown(wait=True)
        self._wall_s += time.perf_counter() - self._t_start
        with self._stats_lock:
            self.stats["stage_occupancy"] = {
                "dispatch": (self.stats["dispatch_busy_s"] / self._wall_s
                             if self._wall_s > 0 else 0.0),
                "complete": (self.stats["complete_busy_s"] / self._wall_s
                             if self._wall_s > 0 else 0.0),
                # the post pool runs post_workers threads, so its busy
                # time is normalized per worker to stay a [0, 1] occupancy
                "post": (self.stats["post_busy_s"]
                         / (self._wall_s * max(self.post_workers, 1))
                         if self._wall_s > 0 else 0.0),
            }
        self._running = False

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def wake(self) -> None:
        """Re-check flush deadlines now (the event-driven flush wait:
        clock owners call this after advancing a non-real clock)."""
        with self._cond:
            self._cond.notify_all()

    def stats_snapshot(self) -> Dict[str, float]:
        """Scalar stats copied under the lock, plus the live queue
        depth and in-flight count — safe to scrape while the scheduler
        runs (STDService.metrics_snapshot feeds autoscalers from
        this)."""
        with self._stats_lock:
            out = {k: float(v) for k, v in self.stats.items()
                   if isinstance(v, (int, float))}
            out["inflight"] = float(self._in_flight)
            # running counters, not an O(batches) scan — scrapes must
            # not stall the per-batch hot paths behind _stats_lock
            n_batches = len(self.stats["batches"])
            if n_batches:
                out["mean_batch"] = self.stats["batch_items"] / n_batches
                out["batch_occupancy"] = out["mean_batch"] / self.max_batch
        with self._cond:
            out["queue_depth"] = float(self._n_pending)
        return out

    # -- request side ----------------------------------------------------------
    def submit(self, key: Hashable, payload: Any) -> Future:
        """Enqueue one request.  At ``max_pending`` queued items the
        admission policy applies: "reject" raises :class:`QueueFull`
        immediately (load shedding), "block" waits for the scheduler to
        drain a batch (backpressure on the caller thread)."""
        fut: Future = Future()
        with self._cond:
            if self._stop or not self._running:
                raise RuntimeError("MicroBatcher is not running")
            while self.max_pending > 0 and self._n_pending >= self.max_pending:
                if self.admission == "reject":
                    with self._stats_lock:
                        self.stats["rejected"] += 1
                    if self.book is not None:
                        self.book.incr("mb_shed")
                    raise QueueFull(
                        f"pending queue at max_pending={self.max_pending}"
                    )
                self._cond.wait()
                if self._stop or not self._running:
                    raise RuntimeError("MicroBatcher is not running")
            item = _Item(key, payload, fut, self.clock())
            self._pending.setdefault(key, deque()).append(item)
            self._n_pending += 1
            with self._stats_lock:
                self.stats["submitted"] += 1
                if self._n_pending > self.stats["pending_peak"]:
                    self.stats["pending_peak"] = self._n_pending
            if self.book is not None:
                self.book.incr("mb_submitted")
            self._cond.notify_all()
        return fut

    # -- scheduler thread ------------------------------------------------------
    def _cap(self, key: Hashable) -> int:
        """Effective flush size for one bucket.  When a per-bucket cap
        is wired (memory-aware batching) it REPLACES the fixed
        max_batch — a memory-light bucket may batch above it, a
        memory-heavy one is held below; <=0 falls back to max_batch."""
        if self.max_batch_for is None:
            return self.max_batch
        try:
            cap = int(self.max_batch_for(key))
        except Exception:
            return self.max_batch
        return cap if cap > 0 else self.max_batch

    def _next_batch(self):
        """Block until a bucket is ready; None once stopped AND drained.

        Every non-empty bucket is classified (full / drain-on-stop /
        timeout) and, among the ready ones, the bucket whose HEAD
        request is oldest wins.  Scanning ``self._pending`` in dict
        insertion order and taking the first ready bucket — the old
        behaviour — let an early bucket under sustained full-batch load
        starve a later bucket's timeout flush indefinitely."""
        with self._cond:
            while True:
                now = self.clock()
                ready_key, reason, deadline = None, None, None
                oldest_head = None
                for k, dq in self._pending.items():
                    if not dq:
                        continue
                    head_t = dq[0].t_submit
                    if len(dq) >= self._cap(k):
                        r = "full"
                    elif self._stop:
                        r = "drain"
                    elif head_t + self.max_wait_s <= now:
                        r = "timeout"
                    else:
                        d = head_t + self.max_wait_s
                        deadline = d if deadline is None else min(deadline, d)
                        continue
                    if oldest_head is None or head_t < oldest_head:
                        ready_key, reason, oldest_head = k, r, head_t
                if ready_key is not None:
                    dq = self._pending[ready_key]
                    n = min(len(dq), self._cap(ready_key))
                    items = [dq.popleft() for _ in range(n)]
                    self._n_pending -= n
                    self._cond.notify_all()      # wake blocked submitters
                    return ready_key, reason, items
                if self._stop:
                    return None
                # an event-driven clock wakes us on every advance; a
                # plain real-seconds clock converts the deadline delta
                # to a wait timeout
                timeout = None
                if deadline is not None and not self._event_driven:
                    timeout = max(deadline - now, 0.0)
                self._cond.wait(timeout=timeout)

    def _sched_loop(self):
        while True:
            batch = self._next_batch()
            self._infer_q.put(batch)          # None = drained sentinel
            if batch is None:
                return

    # -- dispatch stage --------------------------------------------------------
    def _dispatch_loop(self):
        """Submit each batch's computation and hand the (possibly
        un-materialized) result to the completion stage.  With an async
        engine this thread never blocks on the device, so batch i+1's
        H2D/compute dispatch overlaps batch i's D2H in mb-complete."""
        while True:
            got = self._infer_q.get()
            if got is None:
                if self._complete_t is not None:
                    self._done_q.put(None)
                return
            key, reason, items = got
            with self._stats_lock:
                self.stats[f"flush_{reason}"] += 1
                self.stats["batch_items"] += len(items)
                self.stats["batches"].append({
                    "key": key, "n": len(items), "reason": reason,
                    "queued_ms": (self.clock() - items[0].t_submit) * 1e3,
                })
            if self.book is not None:
                self.book.observe("mb_batch_occupancy",
                                  len(items) / self.max_batch)
            t0 = time.perf_counter()
            try:
                raw = self.infer_fn(key, [it.payload for it in items])
            except Exception as e:
                for it in items:
                    it.future.set_exception(e)
                continue
            finally:
                dt = time.perf_counter() - t0
                with self._stats_lock:
                    self.stats["dispatch_busy_s"] += dt
                if self.book is not None:
                    self.book.observe("mb_dispatch_s", dt)
            with self._stats_lock:
                self._in_flight += 1
                if self._in_flight > self.stats["inflight_peak"]:
                    self.stats["inflight_peak"] = self._in_flight
            if self._complete_t is None:
                self._complete_one(key, items, raw)
            else:
                self._done_q.put((key, items, raw))   # bounded: backpressure

    # -- completion stage ------------------------------------------------------
    def _complete_loop(self):
        while True:
            got = self._done_q.get()
            if got is None:
                return
            self._complete_one(*got)

    def _complete_one(self, key, items, raw):
        t0 = time.perf_counter()
        try:
            outs = raw if self.finalize_fn is None \
                else self.finalize_fn(key, raw)
            n_out = len(outs)
        except Exception as e:
            for it in items:
                it.future.set_exception(e)
            return
        finally:
            dt = time.perf_counter() - t0
            with self._stats_lock:
                self._in_flight -= 1
                self.stats["complete_busy_s"] += dt
            if self.book is not None:
                self.book.observe("mb_complete_s", dt)
        if n_out < len(items):
            # a finalize returning fewer outputs than live items would
            # silently strand the tail futures (zip stops early) and
            # hang their callers forever — fail them loudly instead.
            # MORE outputs than items is legal: the batch axis may be
            # padded, and zip ignores the padding rows.
            err = RuntimeError(
                f"finalize_fn returned {n_out} outputs for {len(items)} "
                f"batch items (key={key!r}); stranded futures failed"
            )
            with self._stats_lock:
                self.stats["finalize_short"] += 1
            if self.book is not None:
                self.book.incr("mb_finalize_short")
            for it in items[n_out:]:
                it.future.set_exception(err)
            items = items[:n_out]
        for it, out in zip(items, outs):
            if self.post_fn is None:
                self._resolve(it, out)
            else:
                self._post_pool.submit(self._post_one, it, out)

    def _post_one(self, item: _Item, out: Any):
        t0 = time.perf_counter()
        try:
            self._resolve(item, self.post_fn(item.payload, out))
        except Exception as e:
            item.future.set_exception(e)
        finally:
            with self._stats_lock:
                self.stats["post_busy_s"] += time.perf_counter() - t0

    def _resolve(self, item: _Item, result: Any):
        # sample lands BEFORE set_result, so anything observable through
        # result() implies its latency sample is already readable
        with self._stats_lock:
            self.stats["item_latency_s"].append(
                self.clock() - item.t_submit
            )
        item.future.set_result(result)
