"""Step-function builders shared by dryrun/train/serve.

Every builder returns (jitted_fn, abstract_args, shardings) so the
dry-run can ``.lower(**abstract).compile()`` without allocating a single
parameter — params/opt-state/caches come from ParamMeta trees as
ShapeDtypeStructs, inputs from ``configs.input_specs``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, input_specs
from repro.core import bfp as bfp_lib
from repro.models.lm import LMModel, cross_entropy
from repro.models.lm import params as params_lib
from repro.optim import adamw, clip_by_global_norm, cosine_with_warmup
from repro.runtime import sharding as shd

F32 = jnp.float32


def default_moment_dtype(cfg: ArchConfig) -> str:
    n = cfg.param_count()
    if n > 100e9:
        return "bfp8"        # kimi/grok class: §6 memory budget
    if n > 10e9:
        return "bfloat16"
    return "float32"


def _bfp_spec_like(param_spec: P, mantissa_shape, exp_shape, mesh) -> Any:
    """Shardings for a BFPTensor moment: mantissa inherits the param spec;
    the exponent (last dim / block) keeps axes that still divide."""
    sizes = shd.mesh_axis_sizes(mesh)
    parts = list(param_spec) + [None] * (len(mantissa_shape) - len(param_spec))
    eparts = list(parts)
    last = eparts[-1] if eparts else None
    if last is not None:
        ax = last if isinstance(last, tuple) else (last,)
        total = int(np.prod([sizes[a] for a in ax]))
        if exp_shape[-1] % total != 0:
            eparts[-1] = None
    return {
        "mantissa": NamedSharding(mesh, P(*parts)),
        "exponent": NamedSharding(mesh, P(*eparts)),
    }


def opt_state_shardings(metas, mesh: Mesh, moment_dtype: str, opt_init):
    """Shardings matching the OptState structure (moments follow params).

    Built by pairing the abstract opt-state leaves (post eval_shape) with
    the param metas in flatten order, so BFPTensor aux data matches the
    real state tree exactly.
    """
    abstract_params = params_lib.abstract(metas)
    abstract_opt = jax.eval_shape(opt_init, abstract_params)
    pspecs = params_lib.specs(metas, mesh)
    is_bfp = lambda x: isinstance(x, bfp_lib.BFPTensor)
    spec_leaves = jax.tree_util.tree_leaves(pspecs)

    def moment_shardings(abstract_m):
        leaves, treedef = jax.tree_util.tree_flatten(abstract_m,
                                                     is_leaf=is_bfp)
        out = []
        for leaf, spec in zip(leaves, spec_leaves):
            if is_bfp(leaf):
                d = _bfp_spec_like(
                    spec, leaf.mantissa.shape, leaf.exponent.shape, mesh
                )
                out.append(dataclasses.replace(
                    leaf, mantissa=d["mantissa"], exponent=d["exponent"]
                ))
            else:
                out.append(NamedSharding(mesh, spec))
        return jax.tree_util.tree_unflatten(treedef, out)

    from repro.optim import OptState

    return abstract_opt, OptState(
        NamedSharding(mesh, P()),
        moment_shardings(abstract_opt.mu),
        moment_shardings(abstract_opt.nu),
        None,
    )


@dataclasses.dataclass
class BuiltStep:
    fn: Any                     # jitted function
    abstract_args: Tuple        # positional ShapeDtypeStruct args
    arg_shardings: Tuple
    model: LMModel
    meta: Dict[str, Any]


def build_train_step(
    cfg: ArchConfig,
    mesh: Mesh,
    shape: ShapeConfig,
    *,
    moment_dtype: Optional[str] = None,
    lr: float = 3e-4,
    grad_clip: float = 1.0,
    scan_unroll: int = 1,
    seq_shard: bool = False,
    n_micro: int = 1,
) -> BuiltStep:
    model = LMModel(cfg)
    metas = model.param_meta()
    md = moment_dtype or default_moment_dtype(cfg)
    opt_init, opt_update = adamw(
        cosine_with_warmup(lr, 2000, 100_000), moment_dtype=md
    )
    abstract_params = params_lib.abstract(metas)
    param_sh = params_lib.shardings(metas, mesh)
    abstract_opt, opt_sh = opt_state_shardings(metas, mesh, md, opt_init)

    in_specs = input_specs(cfg, shape)
    batch_sh = shd.input_shardings(mesh, in_specs)

    cstr = shd.activation_constrainer(mesh, shape.global_batch,
                                      seq_shard=seq_shard)
    from repro.optim.grad_utils import GradAccumulator

    accum = GradAccumulator(n_micro)

    def train_step(params, opt_state, batch):
        def loss_fn(p, b):
            logits = model.forward(
                p, b["tokens"],
                prefix_embed=b.get("prefix_embed"),
                mode="train",
                ctx_extra={"shard": cstr, "scan_unroll": scan_unroll},
            )
            return cross_entropy(logits, b["labels"])

        loss, grads = accum(loss_fn, params, batch)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_params, new_opt = opt_update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    fn = jax.jit(
        train_step,
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, None),
        donate_argnums=(0, 1),
    )
    return BuiltStep(
        fn=fn,
        abstract_args=(abstract_params, abstract_opt, in_specs),
        arg_shardings=(param_sh, opt_sh, batch_sh),
        model=model,
        meta={"moment_dtype": md, "kind": "train"},
    )


def build_prefill(cfg: ArchConfig, mesh: Mesh, shape: ShapeConfig,
                  *, scan_unroll: int = 1,
                  bfp_weights: bool = False) -> BuiltStep:
    model = LMModel(cfg)
    metas = model.param_meta()
    if bfp_weights:
        abstract_params = params_lib.bfp_abstract(metas)
        param_sh = params_lib.bfp_shardings(metas, mesh)
    else:
        abstract_params = params_lib.abstract(metas)
        param_sh = params_lib.shardings(metas, mesh)
    in_specs = input_specs(cfg, shape)
    batch_sh = shd.input_shardings(mesh, in_specs)
    b = shape.global_batch
    # VLM prefill: the vision prefix occupies cache slots too
    max_len = shape.seq_len + (
        cfg.frontend_len if cfg.family == "vlm" else 0
    )
    cache_metas = model.cache_meta(b, max_len)
    cache_sh = params_lib.shardings(cache_metas, mesh)

    cstr = shd.activation_constrainer(mesh, shape.global_batch)

    def prefill(params, batch):
        logits, cache = model.forward(
            params, batch["tokens"],
            prefix_embed=batch.get("prefix_embed"),
            mode="serve", cache_out=True, max_len=max_len,
            ctx_extra={"shard": cstr, "scan_unroll": scan_unroll},
        )
        # serving returns only the last-position logits + the filled cache
        return logits[:, -1, :], cache

    fn = jax.jit(
        prefill,
        in_shardings=(param_sh, batch_sh),
        out_shardings=(None, cache_sh),
    )
    return BuiltStep(
        fn=fn,
        abstract_args=(abstract_params, in_specs),
        arg_shardings=(param_sh, batch_sh),
        model=model,
        meta={"kind": "prefill"},
    )


def build_serve_step(cfg: ArchConfig, mesh: Mesh, shape: ShapeConfig,
                     *, scan_unroll: int = 1,
                     bfp_weights: bool = False) -> BuiltStep:
    """Single-token decode against a seq_len-deep cache (decode shapes)."""
    model = LMModel(cfg)
    metas = model.param_meta()
    if bfp_weights:
        abstract_params = params_lib.bfp_abstract(metas)
        param_sh = params_lib.bfp_shardings(metas, mesh)
    else:
        abstract_params = params_lib.abstract(metas)
        param_sh = params_lib.shardings(metas, mesh)
    b = shape.global_batch
    cache_metas = model.cache_meta(b, shape.seq_len)
    abstract_cache = params_lib.abstract(cache_metas)
    cache_sh = params_lib.shardings(cache_metas, mesh)
    in_specs = input_specs(cfg, shape)
    tok_sh = shd.input_shardings(mesh, in_specs)

    cstr = shd.activation_constrainer(mesh, shape.global_batch)

    def serve_step(params, cache, tokens, cache_len):
        logits, new_cache = model.decode_step(
            params, tokens, cache, cache_len,
            ctx_extra={"shard": cstr, "scan_unroll": scan_unroll},
        )
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    fn = jax.jit(
        serve_step,
        in_shardings=(
            param_sh, cache_sh, tok_sh["tokens"], NamedSharding(mesh, P())
        ),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    )
    return BuiltStep(
        fn=fn,
        abstract_args=(
            abstract_params, abstract_cache, in_specs["tokens"],
            in_specs["cache_len"],
        ),
        arg_shardings=(param_sh, cache_sh, tok_sh["tokens"], None),
        model=model,
        meta={"kind": "decode"},
    )


def build_step(cfg: ArchConfig, mesh: Mesh, shape: ShapeConfig, **kw) -> BuiltStep:
    if shape.kind == "train":
        kw.pop("bfp_weights", None)
        return build_train_step(cfg, mesh, shape, **kw)
    kw.pop("moment_dtype", None)
    kw.pop("seq_shard", None)
    kw.pop("n_micro", None)
    if shape.kind == "prefill":
        return build_prefill(cfg, mesh, shape, **kw)
    return build_serve_step(cfg, mesh, shape, **kw)
