import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile EVERY
(architecture x input-shape x mesh) cell against the production mesh,
with ShapeDtypeStruct stand-ins — no parameter is ever allocated.

The two lines above MUST stay the first statements in this module (before
any jax-importing import): jax locks the device count at first init.

Per cell we record:
  * memory_analysis()      — bytes per device (proves it fits / flags it)
  * cost_analysis()        — HLO FLOPs + bytes accessed (roofline terms)
  * collective bytes       — parsed from optimized HLO (hlo_analysis)
into reports/dryrun/<arch>__<shape>__<mesh>.json, which §Roofline and
EXPERIMENTS.md read.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def _mesh_tag(multi_pod: bool) -> str:
    return "multipod" if multi_pod else "singlepod"


def run_cell(
    arch: str, shape_name: str, *, multi_pod: bool,
    report_dir: str = REPORT_DIR, verbose: bool = True,
    extra_tag: str = "", cfg_overrides: Dict[str, Any] | None = None,
    **build_kw,
) -> Dict[str, Any]:
    import dataclasses

    import jax

    from repro.configs import SHAPES, get_config, shape_applicable
    from repro.launch import hlo_analysis
    from repro.launch.mesh import make_production_mesh
    from repro.launch.step_fns import build_step

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tag = f"{arch}__{shape_name}__{_mesh_tag(multi_pod)}{extra_tag}"
    ok, reason = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
        "n_devices": mesh.devices.size,
    }
    if not ok:
        rec.update(status="skipped", reason=reason)
        _write(report_dir, tag, rec)
        if verbose:
            print(f"[dryrun] {tag}: SKIP ({reason})")
        return rec

    t0 = time.time()
    try:
        if build_kw.pop("unroll_analysis", False):
            # cost_analysis counts while bodies ONCE; the roofline pass
            # compiles with fully unrolled layer scans for true totals
            build_kw["scan_unroll"] = 4096
        built = build_step(cfg, mesh, shape, **build_kw)
        with mesh:
            lowered = built.fn.lower(*built.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = hlo_analysis.collective_bytes(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops_per_device=float(cost.get("flops", -1.0)),
            bytes_accessed_per_device=float(cost.get("bytes accessed", -1.0)),
            collective_bytes_per_device=coll,
            memory={
                "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
                "alias_size_bytes": getattr(mem, "alias_size_in_bytes", None),
            },
            hlo_op_histogram=hlo_analysis.op_histogram(hlo),
            step_kind=built.meta.get("kind"),
        )
        if verbose:
            ma = rec["memory"]
            per_dev = (ma["argument_size_bytes"] or 0) + (
                ma["temp_size_bytes"] or 0)
            print(
                f"[dryrun] {tag}: OK  flops/dev={rec['flops_per_device']:.3e}"
                f"  bytes/dev={rec['bytes_accessed_per_device']:.3e}"
                f"  coll/dev={coll['total']:.3e}B"
                f"  mem/dev~{per_dev/2**30:.2f}GiB"
                f"  (lower {t_lower:.0f}s compile {t_compile:.0f}s)"
            )
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug; record it
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[dryrun] {tag}: ERROR {type(e).__name__}: {e}")
    _write(report_dir, tag, rec)
    return rec


def _write(report_dir: str, tag: str, rec: Dict[str, Any]):
    os.makedirs(report_dir, exist_ok=True)
    with open(os.path.join(report_dir, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--report-dir", default=REPORT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unrolled-scan analysis compile (true FLOP/byte/"
                         "collective counts; see benchmarks.roofline)")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, SHAPES

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh]

    n_bad = 0
    extra = "__unrolled" if args.unroll else ""
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{_mesh_tag(mp)}{extra}"
                path = os.path.join(args.report_dir, f"{tag}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[dryrun] {tag}: cached")
                            continue
                rec = run_cell(arch, shape, multi_pod=mp,
                               report_dir=args.report_dir,
                               extra_tag=extra,
                               unroll_analysis=args.unroll)
                if rec["status"] == "error":
                    n_bad += 1
    print(f"[dryrun] done, {n_bad} failed cells")
    raise SystemExit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
