"""HLO-text analysis for the roofline report.

``cost_analysis()`` gives FLOPs and bytes accessed, but NOT collective
traffic — we parse the optimized HLO and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op (per-device bytes, since SPMD HLO shapes are per-device).

:func:`memory_stats` / :func:`lowered_memory` read the backend's buffer
assignment off a compiled executable (``memory_analysis()``) — the
ground truth the memplan peak-bytes prediction and the serve_bench
--memplan A/B are judged against.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %ag = bf16[8,128,256]{2,1,0} all-gather(...), or tuple shapes
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (output shapes;
    '-done' ops are skipped so async pairs are not double counted)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def memory_stats(compiled: Any) -> Optional[Dict[str, int]]:
    """Buffer-assignment sizes of a compiled executable, or None when
    the backend exposes no ``memory_analysis()`` (older jaxlibs, some
    plugin backends).  ``temp_bytes`` is the scratch the program needs
    beyond arguments/outputs — the number liveness planning moves;
    ``peak_bytes`` approximates total residency while a step runs."""
    analysis_fn = getattr(compiled, "memory_analysis", None)
    if analysis_fn is None:
        return None
    try:
        mem = analysis_fn()
    except Exception:
        return None
    if mem is None:
        return None

    def _get(name: str) -> int:
        return int(getattr(mem, name, 0) or 0)

    temp = _get("temp_size_in_bytes")
    args = _get("argument_size_in_bytes")
    outs = _get("output_size_in_bytes")
    return {
        "temp_bytes": temp,
        "argument_bytes": args,
        "output_bytes": outs,
        "generated_code_bytes": _get("generated_code_size_in_bytes"),
        "peak_bytes": temp + args + outs,
    }


def lowered_memory(fn: Any, *args: Any) -> Optional[Dict[str, int]]:
    """AOT-lower ``fn`` (a jax.jit callable) at ``args`` (concrete
    arrays or ShapeDtypeStructs), compile, and return
    :func:`memory_stats` — one explicit compile, separate from any
    call-path jit cache."""
    try:
        compiled = fn.lower(*args).compile()
    except Exception:
        return None
    return memory_stats(compiled)


def op_histogram(hlo_text: str, top: int = 15) -> List[Tuple[str, int]]:
    """Count HLO opcodes — used to spot remat recompute / layout churn."""
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^)]*\)|[\w\[\],{}\s]+?)\s+([\w\-]+)\(", line)
        if m:
            counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
