from .ops import winograd_conv2d  # noqa: F401
