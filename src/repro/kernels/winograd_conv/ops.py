"""Public Winograd conv op: XLA-side tiling/input transform + Pallas MXU
contraction with fused output transform + bias/ReLU epilogue."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import winograd as wg

from .kernel import winograd_tile_matmul


def _pad_axis(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.partial(
    jax.jit,
    static_argnames=("padding", "bp", "bn", "bk", "relu", "interpret"),
)
def winograd_conv2d(
    x: jax.Array,              # (N, H, W, Cin) NHWC
    w: jax.Array,              # (3, 3, Cin, Cout)
    b: jax.Array | None = None,
    *,
    padding: str = "SAME",
    bp: int = 128,
    bn: int = 128,
    bk: int = 128,
    relu: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """F(4x4,3x3) convolution with the bias add and optional ReLU fused
    into the kernel's output-transform flush — one launch per conv+bias+
    ReLU microcode sequence.  ``interpret=None`` derives from the backend
    (compiled on TPU, interpreted elsewhere — see
    repro.kernels.default_interpret); pass an explicit bool to override.
    """
    n, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert (kh, kw) == (3, 3) and cin2 == cin
    if padding == "SAME":
        ph, out_h, out_w = 1, h, wd
    elif padding == "VALID":
        ph, out_h, out_w = 0, h - 2, wd - 2
    else:
        raise ValueError(padding)
    th = -(-out_h // wg.TILE_OUT)
    tw = -(-out_w // wg.TILE_OUT)
    need_h = th * wg.TILE_OUT + 2
    need_w = tw * wg.TILE_OUT + 2
    xp = jnp.pad(
        x.astype(jnp.float32),
        ((0, 0), (ph, need_h - h - ph), (ph, need_w - wd - ph), (0, 0)),
    )
    # tile extraction + input transform (layout work — XLA)
    idx_h = (jnp.arange(th) * wg.TILE_OUT)[:, None] + jnp.arange(wg.TILE_IN)
    idx_w = (jnp.arange(tw) * wg.TILE_OUT)[:, None] + jnp.arange(wg.TILE_IN)
    tiles = xp[:, idx_h][:, :, :, idx_w]          # (N, th, 6, tw, 6, C)
    tiles = jnp.moveaxis(tiles, 2, 3)             # (N, th, tw, 6, 6, C)
    v = wg.transform_input(jnp.moveaxis(tiles, -1, -3))  # (N,th,tw,C,6,6)
    P = n * th * tw
    v = v.reshape(P, cin, 36).transpose(0, 2, 1)  # (P, 36, Cin)
    u = wg.transform_weights(w.astype(jnp.float32))      # (6,6,Cin,Cout)
    u = u.reshape(36, cin, cout)

    # pad P/Cin/Cout to tile multiples for the kernel grid
    bp_ = min(bp, P)
    bn_ = min(bn, cout)
    bk_ = min(bk, cin)
    vp = _pad_axis(_pad_axis(v, bp_, 0), bk_, 2)
    up = _pad_axis(_pad_axis(u, bk_, 1), bn_, 2)
    bias = None if b is None else _pad_axis(b.astype(jnp.float32), bn_, 0)
    y = winograd_tile_matmul(
        vp, up, bias, bp=bp_, bn=bn_, bk=bk_, relu=relu,
        interpret=interpret,
    )[:P, :, :cout]                               # (P, 16, Cout)

    y = y.reshape(n, th, tw, wg.TILE_OUT, wg.TILE_OUT, cout)
    y = y.transpose(0, 1, 3, 2, 4, 5).reshape(
        n, th * wg.TILE_OUT, tw * wg.TILE_OUT, cout
    )[:, :out_h, :out_w, :]
    return y
