"""Oracle for the Winograd kernel: direct convolution + the pure-jnp
Winograd implementation from repro.core (both must agree)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.winograd import winograd_conv2d as winograd_conv2d_jnp  # noqa: F401


def direct_conv2d(x: jax.Array, w: jax.Array, padding: str = "SAME") -> jax.Array:
    """NHWC stride-1 3x3 convolution via lax — the ground truth."""
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32,
    )
