"""Winograd F(4x4,3x3) Pallas kernel — paper C3 on the MXU.

Split of work (DESIGN.md §2): the input transform BᵀXB is a small
data-layout computation done in XLA (ops.py); this kernel runs the part
the paper puts on its DSP supertile arrays — the 36 per-position
(tiles × Cin) · (Cin × Cout) contractions — on the MXU, and *fuses the
output transform AᵀYA in-kernel*.  Fusing the output transform matters on
TPU: the intermediate M tensor is 36/16 = 2.25x the output size, so
writing it to HBM would more than double the kernel's write traffic.

The conv's bias add and ReLU ride the same flush (paper Fig. 5: the
microcode's per-layer ReLU flag drives a datapath epilogue, not a
separate pass) — one launch covers contraction, output transform, bias,
and activation, so the optimized engine issues a single dispatch per
fused conv+bias+ReLU microcode word.

Grid: (P/bp, Cout/bn, Cin/bk) with Cin innermost; the (36, bp, bn) f32
accumulator lives in VMEM scratch across the Cin sweep.

VMEM per step (bp=128, bn=128, bk=128):
    V tile   128*36*128*4  = 2.25 MiB   (x2 ping-pong)
    U tile   36*128*128*4  = 2.25 MiB
    acc      36*128*128*4  = 2.25 MiB
    out      128*16*128*4  = 1.00 MiB
  ~10 MiB with double buffering — inside a v5e-class core budget; tests
  sweep smaller blocks too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.winograd import AT
from repro.kernels import default_interpret


def _winograd_mm_kernel(at_ref, v_ref, u_ref, b_ref, o_ref, acc_ref, *,
                        relu: bool):
    """at: (4, 6) Aᵀ; v: (bp, 36, bk); u: (36, bk, bn); b: (1, bn);
    o: (bp, 16, bn)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    v = v_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    # 36 independent MXU contractions, batched over the position axis
    acc_ref[...] += jax.lax.dot_general(
        jnp.swapaxes(v, 0, 1),            # (36, bp, bk)
        u,                                # (36, bk, bn)
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                     # (36, bp, bn)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        acc = acc_ref[...]                # (36, bp, bn)
        bp, bn = acc.shape[1], acc.shape[2]
        at = at_ref[...]                  # (4, 6)
        m = acc.reshape(6, 6, bp, bn)
        # Y = Aᵀ M A over the two 6-axes (VPU work, fused with the flush)
        y = jnp.einsum("ij,jkpn,lk->ilpn", at, m, at)    # (4, 4, bp, bn)
        y = y.reshape(16, bp, bn).transpose(1, 0, 2)
        y = y + b_ref[...][None]          # (bp, 16, bn) + (1, 1, bn)
        if relu:
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y


@functools.partial(
    jax.jit, static_argnames=("bp", "bn", "bk", "relu", "interpret")
)
def winograd_tile_matmul(
    v: jax.Array,          # (P, 36, Cin)  transformed input tiles
    u: jax.Array,          # (36, Cin, Cout) transformed weights (G W Gᵀ)
    b: jax.Array | None = None,            # (Cout,) fused bias
    *,
    bp: int = 128,
    bn: int = 128,
    bk: int = 128,
    relu: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (P, 16, Cout) output tiles (4x4 row-major per tile), with
    the bias add and optional ReLU fused into the output-transform flush
    (``interpret=None`` derives from the backend — see
    repro.kernels.default_interpret)."""
    if interpret is None:
        interpret = default_interpret()
    P, t36, K = v.shape
    _, _, N = u.shape
    assert t36 == 36
    bp = min(bp, P)
    bn = min(bn, N)
    bk = min(bk, K)
    assert P % bp == 0 and N % bn == 0 and K % bk == 0, (P, N, K, bp, bn, bk)
    bias = (jnp.zeros((1, N), jnp.float32) if b is None
            else b.astype(jnp.float32).reshape(1, N))
    return pl.pallas_call(
        functools.partial(_winograd_mm_kernel, relu=relu),
        grid=(P // bp, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((4, 6), lambda i, j, k: (0, 0)),
            pl.BlockSpec((bp, 36, bk), lambda i, j, k: (i, 0, k)),
            pl.BlockSpec((36, bk, bn), lambda i, j, k: (0, k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bp, 16, bn), lambda i, j, k: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((P, 16, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((36, bp, bn), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(AT, jnp.float32), v, u, bias)
