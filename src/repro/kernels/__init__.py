"""Pallas TPU kernels for the paper's compute hot-spots.

Each package ships three layers:
  kernel.py  pl.pallas_call body + BlockSpec VMEM tiling (TPU target)
  ops.py     jit'd public wrapper (layout, quantization, padding)
  ref.py     pure-jnp oracle used by tests and as the interpreter fallback

  bfp_matmul/       paper C2 — shared-exponent block-FP matmul, int8
                    mantissa HBM traffic, f32 wide accumulation (§IV.C)
  winograd_conv/    paper C3 — F(4x4,3x3), 36 MXU contractions per tile,
                    output transform fused in-kernel
  flash_attention/  blockwise online-softmax GQA attention (prefill path)
  ssd_scan/         Mamba2 state-space-dual intra-chunk quadratic kernel
  cc_label/         paper §III.A — PixelLink CC labeling, tile-local
                    VMEM convergence + global log-hop merge rounds

Every public op takes ``interpret`` (default ``None`` = derive from the
backend via :func:`default_interpret`): the kernel bodies target the TPU
Mosaic compiler (``pltpu.VMEM`` scratch, MXU dot shapes), so everywhere
else they execute through the Pallas interpreter — which makes opting
into the kernels (``use_pallas=True``) safe on any backend, just not
fast off-TPU.
"""
from __future__ import annotations


def default_interpret() -> bool:
    """Whether Pallas calls should run interpreted on this backend.

    The kernels here compile with the TPU Mosaic backend only; on cpu/gpu
    the interpreter is the working path.  Resolved at trace time so the
    decision follows the backend the enclosing jit actually lowers for.
    """
    import jax

    return jax.default_backend() != "tpu"
