"""Pallas TPU kernels for the paper's compute hot-spots.

Each package ships three layers:
  kernel.py  pl.pallas_call body + BlockSpec VMEM tiling (TPU target)
  ops.py     jit'd public wrapper (layout, quantization, padding)
  ref.py     pure-jnp oracle used by tests and as the interpreter fallback

  bfp_matmul/       paper C2 — shared-exponent block-FP matmul, int8
                    mantissa HBM traffic, f32 wide accumulation (§IV.C)
  winograd_conv/    paper C3 — F(4x4,3x3), 36 MXU contractions per tile,
                    output transform fused in-kernel
  flash_attention/  blockwise online-softmax GQA attention (prefill path)
  ssd_scan/         Mamba2 state-space-dual intra-chunk quadratic kernel
"""
