"""Mamba2 SSD intra-chunk Pallas kernel.

The state-space dual form splits the recurrence into (i) an intra-chunk
quadratic part — attention-like matmuls, MXU work, done here — and (ii) a
cheap inter-chunk state scan done in XLA (ops.py).  This mirrors the
paper's module-level split (fixed compute engines + thin control), and is
the TPU-idiomatic shape for SSMs: chunked matmuls instead of a length-L
sequential loop.

Per (batch-chunk, group, head) program:
    cb[t,s]    = C_t · B_s                       (Lc x Lc MXU)
    decay[t,s] = exp(scum_t - scum_s) for t>=s   (VPU)
    y_intra    = (cb * decay * mask) @ xdt       (Lc x P MXU)
    state      = xdtᵀ @ (B * exp(s_L - scum))    (P x N MXU, chunk-end
                                                  state for the carry scan)

VMEM per step (Lc=128, N=128, P=64): ~0.4 MiB — small; the grid is large
(B*nc*H) which is exactly what the scalar-prefetch pipeline wants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_chunk_kernel(c_ref, b_ref, xdt_ref, scum_ref, y_ref, st_ref):
    c = c_ref[0, 0].astype(jnp.float32)          # (Lc, N)
    b = b_ref[0, 0].astype(jnp.float32)          # (Lc, N)
    xdt = xdt_ref[0, 0, 0].astype(jnp.float32)   # (Lc, P)
    scum = scum_ref[0, 0, 0].astype(jnp.float32)  # (Lc, 1)

    lc = c.shape[0]
    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (Lc, Lc)
    rows = jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 1)
    # decay exp(s_t - s_s), t >= s; mask the exponent BEFORE exp — the
    # t < s entries are exp(+large) and would overflow to inf.
    arg = scum - scum.reshape(1, lc)
    dec = jnp.exp(jnp.where(rows >= cols, arg, -jnp.inf))
    w = cb * dec
    y_ref[0, 0, 0] = jax.lax.dot_general(
        w, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (Lc, P)

    # chunk-end state: sum_s exp(s_last - s_s) xdt_s ⊗ B_s
    s_last = scum[lc - 1, 0]
    bw = b * jnp.exp(s_last - scum)              # (Lc, N)
    st_ref[0, 0, 0] = jax.lax.dot_general(
        xdt, bw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (P, N)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(
    c: jax.Array,      # (BC, G, Lc, N)
    b: jax.Array,      # (BC, G, Lc, N)
    xdt: jax.Array,    # (BC, G, HPG, Lc, P)
    scum: jax.Array,   # (BC, G, HPG, Lc, 1)  inclusive cumsum of dt*A
    *,
    interpret: bool = False,
):
    BC, G, Lc, N = c.shape
    _, _, HPG, _, P = xdt.shape
    y, st = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(BC, G, HPG),
        in_specs=[
            pl.BlockSpec((1, 1, Lc, N), lambda i, g, h: (i, g, 0, 0)),
            pl.BlockSpec((1, 1, Lc, N), lambda i, g, h: (i, g, 0, 0)),
            pl.BlockSpec((1, 1, 1, Lc, P), lambda i, g, h: (i, g, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, Lc, 1), lambda i, g, h: (i, g, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Lc, P), lambda i, g, h: (i, g, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda i, g, h: (i, g, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BC, G, HPG, Lc, P), jnp.float32),
            jax.ShapeDtypeStruct((BC, G, HPG, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(c, b, xdt, scum)
    return y, st
