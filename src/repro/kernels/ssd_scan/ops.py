"""Public SSD op: chunked Mamba2 scan.

Intra-chunk quadratic work runs in the Pallas kernel; the O(L/Lc)
inter-chunk state carry is a lax.scan in XLA.  Exactly equivalent to the
sequential recurrence in ref.py (tests assert allclose), but built from
MXU-shaped matmuls — the TPU-idiomatic form of the paper's "fixed compute
modules, thin control" discipline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,      # (B, L, H, P)
    dt: jax.Array,     # (B, L, H)     (positive; softplus applied upstream)
    A: jax.Array,      # (H,)          (negative)
    Bm: jax.Array,     # (B, L, G, N)
    Cm: jax.Array,     # (B, L, G, N)
    D: jax.Array,      # (H,)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    Bsz, L, H, P = x.shape
    _, _, G, N = Bm.shape
    hpg = H // G
    Lc = min(chunk, L)
    assert L % Lc == 0, (L, Lc)
    nc = L // Lc

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    la = dtf * A[None, None, :]                        # (B, L, H) log-decay
    # chunked views
    lac = la.reshape(Bsz, nc, Lc, H)
    scum = jnp.cumsum(lac, axis=2)                     # inclusive, per chunk
    xdt = (xf * dtf[..., None]).reshape(Bsz, nc, Lc, H, P)
    Bc = Bm.reshape(Bsz, nc, Lc, G, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, Lc, G, N).astype(jnp.float32)

    # kernel layout: (BC, G, [HPG,] ...)
    BC = Bsz * nc
    c_k = Cc.transpose(0, 1, 3, 2, 4).reshape(BC, G, Lc, N)
    b_k = Bc.transpose(0, 1, 3, 2, 4).reshape(BC, G, Lc, N)
    xdt_k = (
        xdt.transpose(0, 1, 3, 2, 4)                   # (B, nc, H, Lc, P)
        .reshape(BC, G, hpg, Lc, P)
    )
    scum_k = (
        scum.transpose(0, 1, 3, 2)                     # (B, nc, H, Lc)
        .reshape(BC, G, hpg, Lc, 1)
    )
    y_intra, st = ssd_chunk(c_k, b_k, xdt_k, scum_k, interpret=interpret)
    y_intra = (
        y_intra.reshape(Bsz, nc, H, Lc, P).transpose(0, 1, 3, 2, 4)
    )                                                   # (B, nc, Lc, H, P)
    st = st.reshape(Bsz, nc, H, P, N)                  # chunk-local end state

    # inter-chunk carry: h_c = exp(s_L)^c h_{c-1} + st_c
    tot = jnp.exp(scum[:, :, -1, :])                   # (B, nc, H) chunk decay

    def carry(h, inp):
        st_c, dec_c = inp                              # (B,H,P,N), (B,H)
        h_out = h                                      # state *entering* chunk
        h = h * dec_c[..., None, None] + st_c
        return h, h_out

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    _, h_in = jax.lax.scan(
        carry,
        h0,
        (jnp.moveaxis(st, 1, 0), jnp.moveaxis(tot, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)                    # (B, nc, H, P, N)

    # inter-chunk output: y_t += exp(s_t) * C_t · h_in(chunk)
    Ch = jnp.repeat(Cc, hpg, axis=3)                   # (B, nc, Lc, H, N)
    dec_t = jnp.exp(scum)                              # (B, nc, Lc, H)
    y_inter = jnp.einsum(
        "bclhn,bchpn->bclhp", Ch * dec_t[..., None], h_in
    )
    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y + xf * D[None, None, :, None]


def ssd_decode_step(
    h: jax.Array,      # (B, H, P, N) carried state
    x_t: jax.Array,    # (B, H, P)
    dt_t: jax.Array,   # (B, H)
    A: jax.Array,      # (H,)
    B_t: jax.Array,    # (B, G, N)
    C_t: jax.Array,    # (B, G, N)
    D: jax.Array,      # (H,)
):
    """O(1) single-token decode — the SSM's long-context superpower."""
    Bsz, H, P = x_t.shape
    G = B_t.shape[1]
    hpg = H // G
    Bh = jnp.repeat(B_t, hpg, axis=1)                  # (B, H, N)
    Ch = jnp.repeat(C_t, hpg, axis=1)
    a = jnp.exp(dt_t * A[None, :])                     # (B, H)
    h = h * a[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", x_t * dt_t[..., None], Bh
    )
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch) + x_t * D[None, :, None]
    return h, y
