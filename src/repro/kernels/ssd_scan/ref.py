"""Naive sequential recurrence — the SSD oracle (Mamba2, arXiv:2405.21060).

State h: (H, P, N) per batch element.  Per timestep t:
    a_t = exp(dt_t * A_h)                    (scalar decay per head)
    h_t = a_t * h_{t-1} + dt_t * x_t ⊗ B_t   (outer product over (P, N))
    y_t = h_t · C_t + D_h * x_t

B and C are shared across the heads of a group (G groups, H heads,
head h uses group h // (H // G)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_reference(
    x: jax.Array,      # (B, L, H, P)
    dt: jax.Array,     # (B, L, H)        (already softplus'd, > 0)
    A: jax.Array,      # (H,)             (negative)
    Bm: jax.Array,     # (B, L, G, N)
    Cm: jax.Array,     # (B, L, G, N)
    D: jax.Array,      # (H,)
) -> jax.Array:
    Bsz, L, H, P = x.shape
    _, _, G, N = Bm.shape
    hpg = H // G
    Bh = jnp.repeat(Bm, hpg, axis=2)       # (B, L, H, N)
    Ch = jnp.repeat(Cm, hpg, axis=2)

    def step(h, inp):
        xt, dtt, bt, ct = inp              # (B,H,P) (B,H) (B,H,N) (B,H,N)
        a = jnp.exp(dtt * A[None, :])      # (B, H)
        h = h * a[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xt * dtt[..., None], bt
        )
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Ch, 1, 0).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)             # (B, L, H, P)
    return y + x.astype(jnp.float32) * D[None, None, :, None]
