"""Public BFP matmul op: quantize (Algorithm 1) then run the Pallas kernel.

The quantization step is the paper's "model weight normalization" /
activation normalization module (Fig. 6); in production weights are
quantized once at load time (see ``models/lm`` BFP mode) while activations
are quantized on the fly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bfp as bfp_lib
from repro.kernels import default_interpret

from .kernel import bfp_matmul_quantized


def _mantissa_dtype(mantissa_bits: int):
    if mantissa_bits <= 7:
        return jnp.int8
    if mantissa_bits <= 15:
        return jnp.int16
    return jnp.int32


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % m
    if not pad:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_size", "mantissa_bits", "rounding", "bm", "bn", "bk",
        "interpret",
    ),
)
def bfp_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_size: int = bfp_lib.DEFAULT_BLOCK,
    mantissa_bits: int = bfp_lib.DEFAULT_MANTISSA,
    rounding: str = "trunc",
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """C = A @ B through shared-exponent BFP (A:(M,K), B:(K,N)).
    ``interpret=None`` derives from the backend (compiled on TPU,
    interpreted elsewhere — see repro.kernels.default_interpret)."""
    if interpret is None:
        interpret = default_interpret()
    M, K = a.shape
    _, N = b.shape
    qa = bfp_lib.quantize(
        a, block_size=block_size, mantissa_bits=mantissa_bits, axis=-1,
        rounding=rounding,
    )
    qb = bfp_lib.quantize(
        b, block_size=block_size, mantissa_bits=mantissa_bits, axis=0,
        rounding=rounding,
    )
    mdt = _mantissa_dtype(mantissa_bits)
    # pad every dim to tile multiples (zero mantissa == exact zero value)
    bm_ = min(bm, max(8, M))
    bn_ = min(bn, max(128, N)) if N >= 128 else N
    # K tile must stay a multiple of the BFP block so exponent tiles align
    k_blocks = -(-K // block_size)
    bk_ = min(bk, k_blocks * block_size)
    bk_ = (bk_ // block_size) * block_size
    ma = _pad_to(_pad_to(qa.mantissa.astype(mdt), bm_, 0), bk_, 1)
    ea = _pad_to(_pad_to(qa.exponent, bm_, 0), bk_ // block_size, 1)
    mb = _pad_to(_pad_to(qb.mantissa.astype(mdt), bk_, 0), bn_, 1)
    eb = _pad_to(_pad_to(qb.exponent, bn_, 0), bk_ // block_size, 1)
    out = bfp_matmul_quantized(
        ma, ea, mb, eb,
        block_size=block_size, mantissa_bits=mantissa_bits,
        bm=bm_, bn=bn_, bk=bk_, interpret=interpret,
    )
    return out[:M, :N]
