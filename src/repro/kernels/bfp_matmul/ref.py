"""Pure-jnp oracle for the BFP matmul kernel (paper Algorithm 1 + §IV.C)."""
from __future__ import annotations

import jax

from repro.core import bfp as bfp_lib


def bfp_matmul_ref(
    a: jax.Array,
    b: jax.Array,
    *,
    block_size: int = bfp_lib.DEFAULT_BLOCK,
    mantissa_bits: int = bfp_lib.DEFAULT_MANTISSA,
    rounding: str = "trunc",
) -> jax.Array:
    """Bit-faithful BFP semantics with the wide (f32) accumulator."""
    return bfp_lib.bfp_matmul_reference(
        a,
        b,
        block_size=block_size,
        mantissa_bits=mantissa_bits,
        rounding=rounding,
        wide_accum=True,
    )
