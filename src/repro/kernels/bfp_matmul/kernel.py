"""BFP matmul Pallas kernel — paper C2 adapted to TPU (DESIGN.md §2).

The FPGA runs fixed-point MACs on shared-exponent mantissas because DSPs
are cheap and FP is expensive.  On TPU the MXU is already fixed-function;
what BFP buys is *HBM/ICI bandwidth*: the kernel streams int8 mantissas
(one int8 exponent per `block_size` values) from HBM — a 4x reduction
versus f32 and 2x versus bf16 — dequantizes in VMEM on the VPU, and runs
the MXU in f32 with full-width accumulation (the §IV.C wide-accumulator
discipline: inputs are quantized, the accumulator never is).

Tiling: grid (M/bm, N/bn, K/bk), K innermost so the f32 accumulator tile
lives in a VMEM scratch across the K sweep.  `bk` must be a multiple of
the BFP block size so exponent tiles align with mantissa tiles.

VMEM budget per step (defaults bm=bn=256, bk=512, bs=32):
    A mantissa  256*512   int8   = 128 KiB     (x2 for pipeline ping-pong)
    B mantissa  512*256   int8   = 128 KiB
    exponents   256*16*2  int8   =   8 KiB
    accumulator 256*256   f32    = 256 KiB
  ~0.9 MiB with double buffering — far under the ~16 MiB/core class
  budget, leaving room for the compiler to widen tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bfp_matmul_kernel(
    ma_ref,      # (bm, bk)   int8/int16 mantissas of A
    ea_ref,      # (bm, bk//bs) int32 block exponents of A
    mb_ref,      # (bk, bn)   mantissas of B
    eb_ref,      # (bn, bk//bs) int32 block exponents of B (N-major layout)
    o_ref,       # (bm, bn)   f32 out
    acc_ref,     # (bm, bn)   f32 VMEM scratch
    *,
    block_size: int,
    mantissa_bits: int,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # dequantize tiles in VMEM (VPU work): value = m * 2^(e - mantissa_bits)
    # (exact power-of-two via exponent-field bitcast — see core.bfp.exp2i)
    from repro.core.bfp import exp2i

    ea = jnp.repeat(ea_ref[...], block_size, axis=1)            # (bm, bk)
    a = ma_ref[...].astype(jnp.float32) * exp2i(ea - mantissa_bits)
    eb = jnp.repeat(eb_ref[...], block_size, axis=1)            # (bn, bk)
    b = mb_ref[...].astype(jnp.float32) * exp2i(eb - mantissa_bits).T
    # MXU contraction with f32 (wide) accumulation:
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_size", "mantissa_bits", "bm", "bn", "bk", "interpret",
    ),
)
def bfp_matmul_quantized(
    ma: jax.Array,   # (M, K) int mantissas
    ea: jax.Array,   # (M, K//bs) int32 exponents
    mb: jax.Array,   # (K, N) int mantissas
    eb: jax.Array,   # (N, K//bs) int32 exponents
    *,
    block_size: int,
    mantissa_bits: int,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, K = ma.shape
    K2, N = mb.shape
    assert K == K2 and K % block_size == 0
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk % block_size == 0
    ebk = bk // block_size

    return pl.pallas_call(
        functools.partial(
            _bfp_matmul_kernel,
            block_size=block_size,
            mantissa_bits=mantissa_bits,
        ),
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, ebk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn, ebk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(ma, ea, mb, eb)
