from .ops import bfp_matmul  # noqa: F401
