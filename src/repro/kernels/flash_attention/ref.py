"""Dense-softmax oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_reference(
    q: jax.Array,        # (B, Hq, Lq, D)
    k: jax.Array,        # (B, Hkv, Lkv, D)
    v: jax.Array,        # (B, Hkv, Lkv, D)
    *,
    sm_scale: float | None = None,
    causal: bool = True,
    kv_len: int | None = None,
) -> jax.Array:
    B, Hq, Lq, D = q.shape
    _, Hkv, Lkv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if sm_scale is None:
        sm_scale = D ** -0.5
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * sm_scale
    cols = jnp.arange(Lkv)[None, :]
    rows = jnp.arange(Lq)[:, None]
    mask = jnp.ones((Lq, Lkv), bool)
    if kv_len is not None:
        mask = mask & (cols < kv_len)
    if causal:
        mask = mask & (cols <= rows + (Lkv - Lq))  # right-aligned causal
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
