"""Public attention ops.

``flash_attention`` — Pallas blockwise kernel for prefill/training
(Lq == Lkv, causal).  ``decode_attention`` — single-token decode against a
KV cache; this is a bandwidth-bound matvec that XLA already emits
optimally, so it stays pure-jnp (kernel would add nothing — see
EXPERIMENTS.md §Perf napkin math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_padded


def _pad_len(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "causal", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,        # (B, Hq, L, D)
    k: jax.Array,        # (B, Hkv, L, D)
    v: jax.Array,        # (B, Hkv, L, D)
    *,
    sm_scale: float | None = None,
    causal: bool = True,
    bq: int = 512,
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    B, Hq, L, D = q.shape
    if sm_scale is None:
        sm_scale = float(D) ** -0.5
    bq_ = min(bq, L)
    bk_ = min(bk, L)
    qp = _pad_len(q, bq_, 2)
    kp = _pad_len(k, bk_, 2)
    vp = _pad_len(v, bk_, 2)
    out = flash_attention_padded(
        qp, kp, vp,
        sm_scale=float(sm_scale), causal=causal, kv_len=L,
        bq=bq_, bk=bk_, interpret=interpret,
    )
    return out[:, :, :L, :]


@jax.jit
def decode_attention(
    q: jax.Array,         # (B, Hq, 1, D) — one new token
    k_cache: jax.Array,   # (B, Hkv, S, D)
    v_cache: jax.Array,   # (B, Hkv, S, D)
    cache_len: jax.Array | int,   # valid prefix length(s), (B,) or scalar
) -> jax.Array:
    B, Hq, _, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    group = Hq // Hkv
    sm_scale = float(D) ** -0.5
    qg = q.reshape(B, Hkv, group, D)
    s = jnp.einsum(
        "bhgd,bhsd->bhgs", qg.astype(jnp.float32),
        k_cache.astype(jnp.float32),
    ) * sm_scale
    pos = jnp.arange(S)[None, None, None, :]
    lim = jnp.asarray(cache_len)
    lim = lim.reshape(-1, 1, 1, 1) if lim.ndim else lim
    s = jnp.where(pos < lim, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, 1, D).astype(q.dtype)
