from .ops import flash_attention, decode_attention  # noqa: F401
