"""Blockwise online-softmax attention (flash) — the prefill hot-spot.

Not a paper contribution per se, but the paper's *discipline* applies
directly: quantized/streamed inputs, wide accumulators, VMEM-resident
running statistics (the ping-pong buffer idea at the register level).
GQA is expressed in the BlockSpec index maps: query head h reads KV head
h // group, so KV tiles are fetched once per group from HBM.

Grid: (B, Hq, Lq/bq, Lkv/bk) with the KV axis innermost; running max m,
normalizer l and the (bq, D) f32 accumulator live in VMEM scratch across
the KV sweep.  Causal masking is done on global indices; fully-masked
KV blocks are skipped with pl.when (block-level early-out).

VMEM per step (bq=512, bk=512, D=128):
    q 512*128*4 = 256 KiB, k/v 2*512*128*4 = 512 KiB, acc 256 KiB,
    m/l 4 KiB  ->  ~1.3 MiB (+ ping-pong) — comfortable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,        # (1, 1, bq, D)
    k_ref,        # (1, 1, bk, D)
    v_ref,        # (1, 1, bk, D)
    o_ref,        # (1, 1, bq, D)
    m_ref,        # (bq, 1) f32 scratch — running max
    l_ref,        # (bq, 1) f32 scratch — running normalizer
    acc_ref,      # (bq, D) f32 scratch
    *,
    sm_scale: float,
    causal: bool,
    bq: int,
    bk: int,
    kv_len: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    # causal early-out: the whole KV block is in the future
    run = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale                                    # (bq, bk)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols < kv_len
        if causal:
            mask = mask & (cols <= rows)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "causal", "bq", "bk", "kv_len", "interpret"),
)
def flash_attention_padded(
    q: jax.Array,        # (B, Hq, Lq, D)
    k: jax.Array,        # (B, Hkv, Lkv, D)
    v: jax.Array,        # (B, Hkv, Lkv, D)
    *,
    sm_scale: float,
    causal: bool,
    kv_len: int,         # true (unpadded) KV length for masking
    bq: int = 512,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Lq, D = q.shape
    _, Hkv, Lkv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    bq = min(bq, Lq)
    bk = min(bk, Lkv)
    assert Lq % bq == 0 and Lkv % bk == 0

    return pl.pallas_call(
        functools.partial(
            _flash_kernel,
            sm_scale=sm_scale, causal=causal, bq=bq, bk=bk, kv_len=kv_len,
        ),
        grid=(B, Hq, Lq // bq, Lkv // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Lq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
