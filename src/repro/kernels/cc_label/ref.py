"""Pure-jnp oracle for the Pallas CCL op: the postprocess log-hop path
itself, with the same calling convention as ``cc_label_pallas``."""
from __future__ import annotations

from typing import Optional

import jax

from repro.models.fcn import postprocess as pp


def cc_label_ref(
    score: jax.Array,
    links: jax.Array,
    score_thr: float = 0.5,
    link_thr: float = 0.5,
    max_iters: int = 256,
    valid_mask: Optional[jax.Array] = None,
    *,
    return_stats: bool = False,
):
    """Reference labels for :func:`repro.kernels.cc_label.cc_label_pallas`
    — ``cc_label_batched(hop="log")`` with 2-D inputs promoted."""
    unbatched = score.ndim == 2
    if unbatched:
        score = score[None]
        links = links[None]
        if valid_mask is not None:
            valid_mask = valid_mask[None]
    out = pp.cc_label_batched(
        score, links, score_thr, link_thr, max_iters,
        valid_mask=valid_mask, hop="log", return_stats=return_stats,
    )
    if not unbatched:
        return out
    if return_stats:
        labels, iters, converged = out
        return labels[0], iters[0], converged[0]
    return out[0]
