"""Block-local CC propagation kernel — phase 1 of the Pallas CCL path.

Each grid step owns one (th, tw) tile of one image and iterates the
PixelLink one-hop max-label spread entirely in VMEM until the tile stops
changing.  Label values are opaque here (just monotone max propagation),
so tiles converge independently; the cross-tile merge is phase 2 in
ops.py (global log-hop rounds).  The payoff is HBM traffic: the naive
while_loop re-reads and re-writes the full plane every hop, while this
kernel touches HBM once per tile no matter how many local hops the tile
needs.

Grid: (N, H/th, W/tw); blocks are (1, th, tw) label/positive planes and
(1, th, tw, 8) link stacks, int32 throughout (TPU-friendly — the bool
masks are rebuilt in-register).  Edge handling uses iota row/col masks
instead of ``.at[].set`` so the rolls never import the wrap-around rows;
a tile edge therefore behaves exactly like an image edge, which is what
makes the phase block-local.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret
from repro.models.fcn.postprocess import NEIGHBORS


def _local_cc_kernel(lab_ref, pos_ref, lnk_ref, out_ref, *, th: int,
                     tw: int):
    """lab/pos: (1, th, tw) int32; lnk: (1, th, tw, 8) int32."""
    lab = lab_ref[0]
    pos = pos_ref[0] != 0
    lnk = lnk_ref[0] != 0
    rows = jax.lax.broadcasted_iota(jnp.int32, (th, tw), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (th, tw), 1)

    def spread(l):
        out = l
        for d, (dy, dx) in enumerate(NEIGHBORS):
            sh = jnp.roll(l, shift=(-dy, -dx), axis=(0, 1))
            # zero the wrapped rows/cols: the tile edge acts as an image
            # edge, keeping the propagation strictly block-local
            if dy == 1:
                sh = jnp.where(rows < th - 1, sh, 0)
            elif dy == -1:
                sh = jnp.where(rows > 0, sh, 0)
            if dx == 1:
                sh = jnp.where(cols < tw - 1, sh, 0)
            elif dx == -1:
                sh = jnp.where(cols > 0, sh, 0)
            out = jnp.where(lnk[..., d] & pos, jnp.maximum(out, sh), out)
        return jnp.where(pos, out, 0)

    def cond(state):
        _, changed, it = state
        # local fixpoint is reached in <= tile pixel-count hops (a label
        # value strictly grows somewhere every non-final iteration)
        return changed & (it < th * tw)

    def body(state):
        l, _, it = state
        new = spread(l)
        return new, jnp.any(new != l), it + 1

    lab, _, _ = jax.lax.while_loop(
        cond, body, (lab, jnp.bool_(True), jnp.int32(0))
    )
    out_ref[0] = lab


@functools.partial(jax.jit, static_argnames=("th", "tw", "interpret"))
def local_spread_converge(
    labels: jax.Array,         # (N, H, W) int32 initial label map
    pos: jax.Array,            # (N, H, W) int32 (0/1 positive mask)
    lnk: jax.Array,            # (N, H, W, 8) int32 (0/1 symmetrized links)
    *,
    th: int = 32,
    tw: int = 32,
    interpret: bool | None = None,
) -> jax.Array:
    """Run every (th, tw) tile to its local spread fixpoint in VMEM.

    Returns the (N, H, W) int32 label map with all within-tile
    propagation complete; cross-tile merging is the caller's phase 2
    (``interpret=None`` derives from the backend — see
    repro.kernels.default_interpret)."""
    if interpret is None:
        interpret = default_interpret()
    N, H, W = labels.shape
    assert H % th == 0 and W % tw == 0, (H, W, th, tw)
    return pl.pallas_call(
        functools.partial(_local_cc_kernel, th=th, tw=tw),
        grid=(N, H // th, W // tw),
        in_specs=[
            pl.BlockSpec((1, th, tw), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, th, tw), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, th, tw, 8), lambda b, i, j: (b, i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, th, tw), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((N, H, W), jnp.int32),
        interpret=interpret,
    )(labels, pos, lnk)
