"""Pallas connected-component labeling (paper §III.A PixelLink tail).

``cc_label_pallas`` (ops.py) runs the label propagation in two phases:
a Pallas kernel iterates block-locally in VMEM until every tile reaches
its local fixpoint (kernel.py), then global log-hop merge rounds
(one-hop spread + pointer jumping, shared with
``repro.models.fcn.postprocess``) stitch tiles together — cutting the
HBM round-trips per iteration from O(diameter) full-plane sweeps to one
kernel launch plus O(log diameter)-ish merge rounds.  ref.py is the
pure-jnp oracle (the postprocess log-hop path itself).
"""
from repro.kernels.cc_label.ops import cc_label_pallas
from repro.kernels.cc_label.ref import cc_label_ref

__all__ = ["cc_label_pallas", "cc_label_ref"]
