"""Public Pallas CCL op: tile-local VMEM convergence + global log-hop
merge rounds.

``cc_label_pallas`` matches the semantics of
``repro.models.fcn.postprocess.cc_label_batched(hop="log")`` — same
thresholds, same valid-mask padding rule, same label values (component
max linear index + 1) — but restructures the iteration for HBM
economy:

  phase 1  one Pallas launch runs every (th, tw) tile to its local
           spread fixpoint entirely in VMEM (kernel.py), so the many
           short-range hops that dominate real text maps never touch
           HBM per-iteration;
  phase 2  global merge rounds (one-hop spread + pointer jump, the
           exact ops the postprocess module exports) stitch tiles —
           only components that CROSS tile boundaries still pay
           full-plane traffic, and the pointer jumps keep those rounds
           sublinear in component diameter.

Both phases are monotone toward the same fixpoint as the plain spread,
so labels are exactly ``cc_label_batched``'s (property-pinned against
the union-find oracle in tests/test_postprocess_device.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.cc_label.kernel import local_spread_converge
from repro.models.fcn import postprocess as pp


@functools.partial(
    jax.jit,
    static_argnames=("score_thr", "link_thr", "max_iters", "th", "tw",
                     "interpret", "return_stats"),
)
def cc_label_pallas(
    score: jax.Array,          # (N, H, W) or (H, W) probabilities
    links: jax.Array,          # (N, H, W, 8) or (H, W, 8)
    score_thr: float = 0.5,
    link_thr: float = 0.5,
    max_iters: int = 256,
    valid_mask: Optional[jax.Array] = None,
    *,
    th: int = 32,
    tw: int = 32,
    interpret: bool | None = None,
    return_stats: bool = False,
):
    """Pallas-accelerated CC labeling -> (N, H, W) int32 label map
    (0 = background, labels = component max linear index + 1 — identical
    values to ``cc_label_batched``).

    ``max_iters`` bounds the PHASE-2 merge rounds per image (phase 1
    always reaches the tile-local fixpoint); with ``return_stats`` the
    result is ``(labels, iters, converged)`` where ``iters`` counts
    merge rounds and ``converged`` is per-image.  Planes that don't
    divide into (th, tw) tiles are zero-padded for phase 1 only — label
    values always index the ORIGINAL plane, and padding can never grow
    or merge components (padded pixels are background)."""
    unbatched = score.ndim == 2
    if unbatched:
        score = score[None]
        links = links[None]
        if valid_mask is not None:
            valid_mask = valid_mask[None]
    if valid_mask is not None:
        score = jnp.where(valid_mask, score, 0.0)
    N, H, W = score.shape
    pos = score > score_thr
    lnk = pp.link_symmetrize(links) > link_thr
    init = jax.vmap(pp.cc_init_labels)(pos)

    # -- phase 1: tile-local fixpoint in VMEM ------------------------------
    bh, bw = min(th, H), min(tw, W)
    ph, pw = (-H) % bh, (-W) % bw
    pad = lambda a: (jnp.pad(a, ((0, 0), (0, ph), (0, pw)) + ((0, 0),) *
                             (a.ndim - 3)) if ph or pw else a)
    local = local_spread_converge(
        pad(init), pad(pos.astype(jnp.int32)), pad(lnk.astype(jnp.int32)),
        th=bh, tw=bw, interpret=interpret,
    )[:, :H, :W]

    # -- phase 2: global log-hop merge rounds ------------------------------
    def gcond(state):
        _, changed, it = state
        return jnp.any(changed & (it < max_iters))

    def gbody(state):
        lab, changed, it = state
        active = changed & (it < max_iters)
        new = jax.vmap(pp.cc_spread)(lab, pos, lnk)
        new = jax.vmap(pp.cc_pointer_jump)(new, pos)
        new = jnp.where(active[:, None, None], new, lab)
        delta = jnp.any(new != lab, axis=(1, 2))
        return (new, jnp.where(active, delta, changed),
                it + active.astype(jnp.int32))

    labels, changed, iters = jax.lax.while_loop(
        gcond, gbody,
        (local, jnp.ones((N,), jnp.bool_), jnp.zeros((N,), jnp.int32)),
    )
    converged = ~changed
    if unbatched:
        labels, iters, converged = labels[0], iters[0], converged[0]
    if return_stats:
        return labels, iters, converged
    return labels
