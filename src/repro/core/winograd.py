"""Winograd F(4x4, 3x3) minimal filtering — paper §III.D, Eq. (1).

Y = Aᵀ[(G W Gᵀ) ⊙ (Bᵀ X B)] A  with 6x6 input tiles, 4x4 output tiles:
36 multiplies per tile versus 144 for direct convolution — the paper's
4x multiply reduction on the DSP arrays.

This module holds the exact Lavin–Gray transform matrices and a pure-jnp
tiled convolution built on them.  ``kernels/winograd_conv`` implements the
same computation as a Pallas TPU kernel (transforms in VMEM, the 36
per-position contractions on the MXU); this file is its oracle and the
fallback path of the interpreter's optimized mode.

Honest TPU note (DESIGN.md §2): on the MXU the multiply-count argument is
weak — the measured trade-off is recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

TILE_IN = 6    # input tile
TILE_OUT = 4   # output tile  (m = 4, r = 3)

# Lavin & Gray, "Fast algorithms for convolutional neural networks".
AT = np.array(
    [
        [1, 1, 1, 1, 1, 0],
        [0, 1, -1, 2, -2, 0],
        [0, 1, 1, 4, 4, 0],
        [0, 1, -1, 8, -8, 1],
    ],
    dtype=np.float32,
)
G = np.array(
    [
        [1 / 4, 0, 0],
        [-1 / 6, -1 / 6, -1 / 6],
        [-1 / 6, 1 / 6, -1 / 6],
        [1 / 24, 1 / 12, 1 / 6],
        [1 / 24, -1 / 12, 1 / 6],
        [0, 0, 1],
    ],
    dtype=np.float32,
)
BT = np.array(
    [
        [4, 0, -5, 0, 1, 0],
        [0, -4, -4, 1, 1, 0],
        [0, 4, -4, -1, 1, 0],
        [0, -2, -1, 2, 1, 0],
        [0, 2, -1, -2, 1, 0],
        [0, 4, 0, -5, 0, 1],
    ],
    dtype=np.float32,
)


def transform_weights(w: jax.Array) -> jax.Array:
    """G W Gᵀ, precomputed once per model load (paper: stored in supertile
    RAM and ping-ponged against compute).

    w: (3, 3, Cin, Cout) -> (6, 6, Cin, Cout)
    """
    g = jnp.asarray(G, w.dtype)
    return jnp.einsum("ij,jkcf,lk->ilcf", g, w, g)


def transform_input(tiles: jax.Array) -> jax.Array:
    """Bᵀ X B for a batch of 6x6 input tiles: (..., 6, 6) -> (..., 6, 6)."""
    bt = jnp.asarray(BT, tiles.dtype)
    return jnp.einsum("ij,...jk,lk->...il", bt, tiles, bt)


def transform_output(tiles: jax.Array) -> jax.Array:
    """Aᵀ Y A: (..., 6, 6) -> (..., 4, 4)."""
    at = jnp.asarray(AT, tiles.dtype)
    return jnp.einsum("ij,...jk,lk->...il", at, tiles, at)


def _extract_tiles(x: jax.Array, th: int, tw: int) -> jax.Array:
    """(N, H', W', C) -> (N, th, tw, 6, 6, C) overlapping stride-4 tiles."""
    idx_h = (jnp.arange(th) * TILE_OUT)[:, None] + jnp.arange(TILE_IN)[None, :]
    idx_w = (jnp.arange(tw) * TILE_OUT)[:, None] + jnp.arange(TILE_IN)[None, :]
    # gather rows then cols
    xh = x[:, idx_h]                      # (N, th, 6, W', C)
    return xh[:, :, :, idx_w]             # (N, th, 6, tw, 6, C) -> fix order


@partial(jax.jit, static_argnames=("padding",))
def winograd_conv2d(x: jax.Array, w: jax.Array, padding: str = "SAME") -> jax.Array:
    """Stride-1 3x3 convolution via F(4x4, 3x3).

    x: (N, H, W, Cin) NHWC; w: (3, 3, Cin, Cout).  Matches
    ``lax.conv_general_dilated`` with SAME/VALID padding to f32 tolerance.
    """
    n, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert (kh, kw) == (3, 3) and cin2 == cin
    if padding == "SAME":
        ph = pw = 1
        out_h, out_w = h, wd
    elif padding == "VALID":
        ph = pw = 0
        out_h, out_w = h - 2, wd - 2
    else:
        raise ValueError(padding)
    th = -(-out_h // TILE_OUT)
    tw = -(-out_w // TILE_OUT)
    # pad so tiles cover the full output: input extent needed = 4*t + 2
    need_h = th * TILE_OUT + 2
    need_w = tw * TILE_OUT + 2
    xp = jnp.pad(
        x,
        ((0, 0), (ph, need_h - h - ph), (pw, need_w - wd - pw), (0, 0)),
    )
    tiles = _extract_tiles(xp, th, tw)            # (N, th, 6, tw, 6, C)
    tiles = jnp.moveaxis(tiles, 2, 3)             # (N, th, tw, 6, 6, C)
    v = transform_input(jnp.moveaxis(tiles, -1, -3))   # (N,th,tw,C,6,6)
    u = transform_weights(w)                      # (6, 6, Cin, Cout)
    # 36 independent (tiles x Cin) @ (Cin x Cout) contractions — the MXU
    # work in the Pallas kernel:
    mprod = jnp.einsum(
        "ntwcij,ijcf->ntwijf",
        v,
        u,
        preferred_element_type=jnp.float32,
    )                                             # (N,th,tw,6,6,Cout)
    y = transform_output(jnp.moveaxis(mprod, -1, -3))  # (N,th,tw,Cout,4,4)
    y = jnp.moveaxis(y, 3, -1)                    # (N,th,tw,4,4,Cout)
    y = y.transpose(0, 1, 3, 2, 4, 5).reshape(n, th * TILE_OUT, tw * TILE_OUT, cout)
    return y[:, :out_h, :out_w, :]


def multiply_count(h: int, w: int, cin: int, cout: int) -> dict:
    """Napkin math used in benchmarks: multiplies per output for direct vs
    Winograd (the paper's 144 -> 36 per 4x4 tile)."""
    tiles = -(-h // TILE_OUT) * (-(-w // TILE_OUT))
    direct = h * w * 9 * cin * cout
    wino = tiles * 36 * cin * cout
    # input/output transform multiplies (the paper rearranges BᵀXB from 12
    # to 6 multiplies per row-pass; A/B entries are small ints/zeros)
    transforms = tiles * (6 * 6 + 6 * 4) * (cin + cout)
    return {"direct": direct, "winograd_mac": wino, "transform_ops": transforms,
            "mac_reduction": direct / wino}
