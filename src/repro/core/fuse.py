"""Complexity-reduction fusions — paper contribution C6.

1. BatchNorm folding: the paper merges the batch-norm layer into the
   preceding convolution ("An efficient method is applied to merge the
   batch norm layer into convolutional layer", §I.B(2)).  Exact algebra:
       y = gamma * (conv(x, W) + b - mean) / sqrt(var + eps) + beta
         = conv(x, W * s) + (b - mean) * s + beta,   s = gamma / sqrt(var+eps)

2. Upsample padding minimization (−75% upsample compute): a 2x
   zero-insertion upsample followed by a 3x3 convolution spends 3/4 of its
   MACs multiplying structural zeros.  Phase-decomposing the kernel over
   the four output phases computes only the non-zero taps:

       phase (0,0): 1 tap   (w[1,1])
       phase (0,1): 2 taps  (w[1,0], w[1,2])
       phase (1,0): 2 taps  (w[0,1], w[2,1])
       phase (1,1): 4 taps  (w[0,0], w[0,2], w[2,0], w[2,2])

   9 taps per 4 outputs versus 36 for the naive version — exactly the
   paper's 75% reduction.  ``upsample2x_conv3x3_fused`` is bit-identical
   to the naive zero-insert+conv (test-verified).

3. Conv epilogue fusion (paper Fig. 5): the microcode's per-layer ReLU
   flag is a datapath epilogue, not a separate pass — a conv+bias+ReLU
   sequence is one launch.  :func:`can_fuse_conv_epilogue` is the
   trace-time eligibility rule the interpreter consults (the residual
   cache/add register reads the PRE-activation value, so a word that
   caches or adds must keep its ReLU after the residual op), and
   :func:`conv_epilogue` is the jnp epilogue for non-Pallas conv paths;
   the Pallas Winograd kernel applies the same epilogue inside its
   output-transform flush (kernels/winograd_conv).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def fold_batchnorm(
    w: jax.Array,
    b: jax.Array | None,
    gamma: jax.Array,
    beta: jax.Array,
    mean: jax.Array,
    var: jax.Array,
    eps: float = 1e-5,
) -> Tuple[jax.Array, jax.Array]:
    """Fold BN(conv(x, w) + b) into a single conv's (w', b').

    w: (kh, kw, cin, cout); BN params: (cout,).
    """
    s = gamma * lax.rsqrt(var + eps)
    w_f = w * s[None, None, None, :]
    b0 = jnp.zeros_like(beta) if b is None else b
    b_f = (b0 - mean) * s + beta
    return w_f, b_f


# ---------------------------------------------------------------------------
# Conv epilogue fusion (bias + ReLU into the conv launch)
# ---------------------------------------------------------------------------

def can_fuse_conv_epilogue(mc) -> bool:
    """Whether a conv microcode word's ReLU may fuse into the conv
    launch.  The residual register reads the pre-activation value
    (res=cache stores it, res=add sums before the activation), so only
    words without a residual op are eligible."""
    from .microcode import ResOp

    return bool(mc.relu) and mc.res_op == ResOp.NONE


def conv_epilogue(y: jax.Array, b: jax.Array | None = None,
                  relu: bool = False) -> jax.Array:
    """The fused conv tail for non-Pallas paths: bias add + optional
    ReLU in one jnp expression (XLA fuses it into the conv's consumer);
    the Pallas Winograd kernel applies the identical epilogue in-kernel."""
    if b is not None:
        y = y + b
    if relu:
        y = jax.nn.relu(y)
    return y


# ---------------------------------------------------------------------------
# Upsample-conv phase decomposition
# ---------------------------------------------------------------------------

def zero_insert_2x(x: jax.Array) -> jax.Array:
    """(N, H, W, C) -> (N, 2H, 2W, C) with x at even coordinates."""
    n, h, w, c = x.shape
    out = jnp.zeros((n, 2 * h, 2 * w, c), x.dtype)
    return out.at[:, ::2, ::2, :].set(x)


@jax.jit
def upsample2x_conv3x3_naive(x: jax.Array, w: jax.Array) -> jax.Array:
    """Reference: conv3x3(zero_insert_2x(x)), SAME padding.  36 MACs / 4 out."""
    y = zero_insert_2x(x)
    return lax.conv_general_dilated(
        y, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


@jax.jit
def upsample2x_conv3x3_fused(x: jax.Array, w: jax.Array) -> jax.Array:
    """Phase-decomposed equivalent — 9 MACs / 4 outputs (−75%).

    Output z[p, q] = sum_{u,v in {-1,0,1}} w[1+u, 1+v] * y[p+u, q+v] where y
    is the zero-inserted input; y is non-zero only at even coordinates, so
    each output phase (p%2, q%2) touches a fixed sub-kernel:

        z[2i, 2j]     = w[1,1] x[i,j]
        z[2i, 2j+1]   = w[1,0] x[i,j] + w[1,2] x[i,j+1]
        z[2i+1, 2j]   = w[0,1] x[i,j] + w[2,1] x[i+1,j]   (note: u=-1 maps
        z[2i+1, 2j+1] = w[0,0] x[i,j] + w[0,2] x[i,j+1]    to row 0 of w and
                      + w[2,0] x[i+1,j] + w[2,2] x[i+1,j+1]  hits x[i+1,·])
    """
    n, h, wd, cin = x.shape
    _, _, cin2, cout = w.shape
    assert cin2 == cin
    dn = ("NHWC", "HWIO", "NHWC")

    # phase (0,0): 1x1 conv with w[1,1]
    p00 = lax.conv_general_dilated(
        x, w[1:2, 1:2], (1, 1), "VALID", dimension_numbers=dn)
    # phase (0,1): z[2i,2j+1] = w[1,0] x[i,j] + w[1,2] x[i,j+1]
    #   == 1x2 conv over x columns with kernel [w[1,0], w[1,2]], pad right 1
    p01 = lax.conv_general_dilated(
        x, w[1:2, 0::2], (1, 1), [(0, 0), (0, 1)], dimension_numbers=dn)
    # phase (1,0): z[2i+1,2j] = w[0,1] x[i,j] + w[2,1] x[i+1,j]
    #   == 2x1 conv over rows with kernel [w[0,1]; w[2,1]], pad bottom 1.
    #   Note row order: output row 2i+1 sees y rows 2i (u=-1 -> w[0]) and
    #   2i+2 (u=+1 -> w[2]); y row 2i = x[i], y row 2i+2 = x[i+1].
    p10 = lax.conv_general_dilated(
        x, w[0::2, 1:2], (1, 1), [(0, 1), (0, 0)], dimension_numbers=dn)
    # phase (1,1): 2x2 conv with the four corners
    p11 = lax.conv_general_dilated(
        x, w[0::2, 0::2], (1, 1), [(0, 1), (0, 1)], dimension_numbers=dn)

    # interleave the four phases
    out = jnp.zeros((n, 2 * h, 2 * wd, cout), p00.dtype)
    out = out.at[:, 0::2, 0::2].set(p00)
    out = out.at[:, 0::2, 1::2].set(p01)
    out = out.at[:, 1::2, 0::2].set(p10)
    out = out.at[:, 1::2, 1::2].set(p11)
    return out


def upsample_nearest_2x(x: jax.Array) -> jax.Array:
    """Plain nearest upsample (EAST-style fusion merge path)."""
    n, h, w, c = x.shape
    x = jnp.broadcast_to(x[:, :, None, :, None, :], (n, h, 2, w, 2, c))
    return x.reshape(n, 2 * h, 2 * w, c)


def upsample_mac_counts(h: int, w: int, cin: int, cout: int) -> dict:
    naive = (2 * h) * (2 * w) * 9 * cin * cout
    fused = h * w * (1 + 2 + 2 + 4) * cin * cout
    return {"naive": naive, "fused": fused, "reduction": 1 - fused / naive}
