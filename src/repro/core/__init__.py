"""repro.core — the paper's contributions as composable JAX modules.

C1 microcode ISA/assembler/interpreter, C2 block floating-point,
C3 Winograd F(4x4,3x3), C6 BN folding + fused upsample.  See DESIGN.md.
"""
from . import assembler, bfp, fuse, interpreter, memplan, microcode, winograd
from .assembler import Assembler, LayerSpec, Program
from .interpreter import BFPConfig, FCNEngine, build_stream_fn
from .memplan import MemPlan, WordPlan, plan_program
from .microcode import ExtOp, Kernel, LayerType, Microcode, ResOp

__all__ = [
    "assembler", "bfp", "fuse", "interpreter", "memplan", "microcode",
    "winograd", "Assembler", "LayerSpec", "Program", "BFPConfig", "FCNEngine",
    "build_stream_fn", "MemPlan", "WordPlan", "plan_program",
    "ExtOp", "Kernel", "LayerType", "Microcode", "ResOp",
]
