"""Row-wise segmentation (paper §IV.B) — layer execution in horizontal
bands.

The FPGA streams each feature map through the datapath in bands of rows
("multiple rows from different input channels are loaded and computed in
each round until the entire feature map is scanned"), sizing the band so
the on-chip buffer is filled but not blown — balancing load time against
compute time.  On TPU the same pattern bounds the VMEM working set of a
spatial layer: band = BlockSpec rows + halo.

``conv2d_banded`` is bit-equivalent to the full-plane convolution
(test-verified): band b computes output rows [r0, r1); it needs input
rows [r0*s - p, (r1-1)*s + k - p] clipped to the plane, zero-padding only
at the true image border.

``band_schedule`` reproduces the paper's sizing rule: pick rows-per-round
so (rows x W x Cin x bytes) fits the buffer budget.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def band_schedule(
    h: int, w: int, cin: int, *, buffer_bytes: int, dtype_bytes: int = 2,
    halo: int = 1,
) -> List[Tuple[int, int]]:
    """Output-row ranges per round such that each round's input band fits
    the buffer (the paper's dynamic rows-per-round rule)."""
    row_bytes = max(w * cin * dtype_bytes, 1)
    rows = max(int(buffer_bytes // row_bytes) - 2 * halo, 1)
    return [(r0, min(r0 + rows, h)) for r0 in range(0, h, rows)]


def conv2d_banded(
    x: jax.Array,            # (N, H, W, Cin)
    w: jax.Array,            # (k, k, Cin, Cout)
    *,
    stride: int = 1,
    n_bands: int = 0,
    bands: List[Tuple[int, int]] | None = None,
) -> jax.Array:
    """SAME-padding conv computed band-by-band; equals the full conv."""
    n, h, wd, cin = x.shape
    k = w.shape[0]
    pad = (k - 1) // 2
    out_h = -(-h // stride)
    if bands is None:
        n_bands = max(n_bands, 1)
        per = -(-out_h // n_bands)
        bands = [(r0, min(r0 + per, out_h)) for r0 in range(0, out_h, per)]
    outs = []
    for r0, r1 in bands:
        in_lo = r0 * stride - pad
        in_hi = (r1 - 1) * stride + k - pad          # exclusive
        lo = max(in_lo, 0)
        hi = min(in_hi, h)
        band = x[:, lo:hi]
        # zero halo only where the true image border was crossed
        top = lo - in_lo
        bot = in_hi - hi
        if top or bot:
            band = jnp.pad(band, ((0, 0), (top, bot), (0, 0), (0, 0)))
        y = lax.conv_general_dilated(
            band, w, (stride, stride),
            [(0, 0), (pad, pad)],                    # W padded, H exact
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        outs.append(y)
    return jnp.concatenate(outs, axis=1)


def bytes_per_round(h0: int, h1: int, w: int, cin: int, k: int,
                    stride: int, dtype_bytes: int = 2) -> int:
    """Input bytes loaded for one round (halo included) — the load-vs-
    compute balance term in the paper's §IV.B."""
    pad = (k - 1) // 2
    rows = (h1 - 1 - h0) * stride + k - 2 * pad + 2 * pad
    return rows * w * cin * dtype_bytes
