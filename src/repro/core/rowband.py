"""Row-wise segmentation (paper §IV.B) — layer execution in horizontal
bands.

The FPGA streams each feature map through the datapath in bands of rows
("multiple rows from different input channels are loaded and computed in
each round until the entire feature map is scanned"), sizing the band so
the on-chip buffer is filled but not blown — balancing load time against
compute time.  On TPU the same pattern bounds the VMEM working set of a
spatial layer: band = BlockSpec rows + halo.

``conv2d_banded`` is bit-equivalent to the full-plane convolution
(test-verified): band b computes output rows [r0, r1); it needs input
rows [r0*s - p, (r1-1)*s + k - p] clipped to the plane, zero-padding only
at the true image border.

``band_schedule`` reproduces the paper's sizing rule: pick rows-per-round
so (rows x W x Cin x bytes) fits the buffer budget.

``program_halo_rows`` extends the single-layer halo rule to a whole
assembled :class:`~repro.core.assembler.Program`: it walks the microcode
and returns an upper bound on the input-row receptive-field radius of
any program output — the analysis/sizing view of banding (how much
context an end-to-end band would need).  The multi-device row-band
ExecutionPlan (runtime/executor.py) does NOT use one end-to-end halo: it
exchanges each layer's own kernel halo instead
(FCNEngine._spatial_banded), which is exact and moves far fewer rows.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


def band_schedule(
    h: int, w: int, cin: int, *, buffer_bytes: int, dtype_bytes: int = 2,
    halo: int = 1,
) -> List[Tuple[int, int]]:
    """Output-row ranges per round such that each round's input band fits
    the buffer (the paper's dynamic rows-per-round rule)."""
    row_bytes = max(w * cin * dtype_bytes, 1)
    rows = max(int(buffer_bytes // row_bytes) - 2 * halo, 1)
    return [(r0, min(r0 + rows, h)) for r0 in range(0, h, rows)]


def conv2d_banded(
    x: jax.Array,            # (N, H, W, Cin)
    w: jax.Array,            # (k, k, Cin, Cout)
    *,
    stride: int = 1,
    n_bands: int = 0,
    bands: List[Tuple[int, int]] | None = None,
) -> jax.Array:
    """SAME-padding conv computed band-by-band; equals the full conv."""
    n, h, wd, cin = x.shape
    k = w.shape[0]
    pad = (k - 1) // 2
    out_h = -(-h // stride)
    if bands is None:
        n_bands = max(n_bands, 1)
        per = -(-out_h // n_bands)
        bands = [(r0, min(r0 + per, out_h)) for r0 in range(0, out_h, per)]
    outs = []
    for r0, r1 in bands:
        in_lo = r0 * stride - pad
        in_hi = (r1 - 1) * stride + k - pad          # exclusive
        lo = max(in_lo, 0)
        hi = min(in_hi, h)
        band = x[:, lo:hi]
        # zero halo only where the true image border was crossed
        top = lo - in_lo
        bot = in_hi - hi
        if top or bot:
            band = jnp.pad(band, ((0, 0), (top, bot), (0, 0), (0, 0)))
        y = lax.conv_general_dilated(
            band, w, (stride, stride),
            [(0, 0), (pad, pad)],                    # W padded, H exact
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        outs.append(y)
    return jnp.concatenate(outs, axis=1)


def program_halo_rows(program) -> int:
    """Input-row receptive-field radius (upper bound) of a whole program
    — an analysis tool (how much context one end-to-end band would
    need); the executor's RowBand plan exchanges per-layer halos
    instead.

    Tracks per-address (jump, radius) in input-row units: a conv/pool of
    kernel k grows the radius by (k-1)*jump (covering SAME-padding
    asymmetry), a strided layer multiplies the jump, an upsample halves
    it.  Concat reads mirror the interpreter's adjacent-extent walk; the
    residual cache/add register and binary adds take the max over their
    inputs.  Unknown producers fall back to the worst (jump, radius) seen
    so far, so the result can only over-estimate — a larger-than-needed
    halo costs bandwidth, never correctness.
    """
    from .assembler import STORAGE_BYTES
    from .microcode import ExtOp, LayerType, ResOp

    info = {program.input_addr: (1.0, 0.0)}     # addr -> (jump, radius)

    def worst():
        return (max(j for j, _ in info.values()),
                max(r for _, r in info.values()))

    def read(addr, want_ch):
        j = r = 0.0
        cur, got = addr, 0
        while got < want_ch:
            if cur not in info or cur not in program.addr_shapes:
                return worst()
            ji, ri = info[cur]
            j, r = max(j, ji), max(r, ri)
            h, w, c = program.addr_shapes[cur]
            got += c
            cur += h * w * c * STORAGE_BYTES
        return j, r

    cache = (1.0, 0.0)
    for idx, mc in enumerate(program.words):
        spec = program.layer_specs[idx]
        j, r = read(mc.in_addr, mc.in_ch)
        lt = LayerType(mc.layer_type)
        if lt == LayerType.CONV:
            r += (mc.kernel_size - 1) * j
            j *= mc.stride_n
        elif lt == LayerType.POOL:
            k = 2 if mc.kernel == 0 else 3
            r += (k - 1) * j
            j *= mc.stride_n
        elif lt == LayerType.UPSAMPLE:
            j /= 2.0
            if spec.upsample_mode == "fused":
                r += 2 * j                       # the fused 3x3 conv
        elif ExtOp(mc.ext_opcode) == ExtOp.ADD and mc.ext_addr2:
            j2, r2 = read(mc.ext_addr2, mc.in_ch)
            j, r = max(j, j2), max(r, r2)
        if mc.res_op == ResOp.CACHE:
            cache = (j, r)
        elif mc.res_op == ResOp.ADD:
            j, r = max(j, cache[0]), max(r, cache[1])
        info[mc.out_addr] = (j, r)

    out_addrs = program.outputs.values()
    return int(np.ceil(max(info[a][1] for a in out_addrs)))


def program_band_costs(program, *, dtype_bytes: int = 4,
                       mode: str = "optimized") -> dict:
    """Per-image cost features of running an assembled program row-banded
    over a device mesh — the inputs to the serving cost model
    (runtime/planner.py):

      ``flops``      forward FLOPs of one image at this plane (MACs x 2
                     for conv/upsample, one op per output element for
                     pool/ext words),
      ``halo_bytes`` bytes ONE band exchanges with its neighbors per
                     image when every spatial layer with k > s swaps its
                     own boundary rows — mirrors
                     FCNEngine._spatial_banded's halo rule (stride-phase
                     rounding, then up to a multiple of 4 rows), two
                     directions per layer,
      ``halo_layers`` how many layers exchange at all (each one is a
                     ppermute pair on the wire).

    ``mode`` matches FCNEngine's execution mode and only changes the
    upsample term: "optimized" runs the phase-decomposed 9-tap fused
    path (fuse.upsample2x_conv3x3_fused — one 3x3 MAC per *input*
    position, a 4x reduction), "reference" runs the naive
    upsample-then-conv path (one 3x3 MAC per *output* position).  The
    cost model must count what actually executes or banded/grid routing
    overweights upsample-heavy heads by 4x on those words.

    Pure microcode-shape arithmetic: no params, no device work.
    """
    from .microcode import ExtOp, LayerType

    if mode not in ("reference", "optimized"):
        raise ValueError(mode)

    flops = 0.0
    halo_bytes = 0.0
    halo_layers = 0
    for idx, mc in enumerate(program.words):
        spec = program.layer_specs[idx]
        oh, ow, oc = program.addr_shapes[mc.out_addr]
        lt = LayerType(mc.layer_type)
        if lt == LayerType.CONV:
            k, s = mc.kernel_size, mc.stride_n
            flops += 2.0 * k * k * mc.in_ch * oc * oh * ow
        elif lt == LayerType.POOL:
            k, s = (2 if mc.kernel == 0 else 3), mc.stride_n
            flops += float(k * k * oh * ow * oc)
        elif lt == LayerType.UPSAMPLE:
            k, s = (1 if spec.upsample_mode == "nearest" else 3), 1
            if spec.upsample_mode != "nearest":
                pos = (oh // 2) * (ow // 2) if mode == "optimized" else oh * ow
                flops += 2.0 * k * k * mc.in_ch * oc * pos
        else:
            if ExtOp(mc.ext_opcode) != ExtOp.NONE:
                flops += float(oh * ow * oc)
            continue
        if k > s:                       # this layer halo-exchanges
            halo = s * (-(-(k - 1) // s))
            halo = -(-halo // 4) * 4
            iw = ow * s if lt != LayerType.UPSAMPLE else ow // 2
            halo_bytes += 2.0 * halo * iw * mc.in_ch * dtype_bytes
            halo_layers += 1
    return {"flops": flops, "halo_bytes": halo_bytes,
            "halo_layers": halo_layers}


def bytes_per_round(h0: int, h1: int, w: int, cin: int, k: int,
                    stride: int, dtype_bytes: int = 2) -> int:
    """Input bytes loaded for one round (halo included) — the load-vs-
    compute balance term in the paper's §IV.B."""
    pad = (k - 1) // 2
    rows = (h1 - 1 - h0) * stride + k - 2 * pad + 2 * pad
    return rows * w * cin * dtype_bytes
