"""Block floating-point (BFP) — paper Algorithm 1 + §III.E / §IV.C.

The paper stores activations/weights in FP16 and computes MACs on
block-floating-point mantissas: every block of N numbers shares the block's
maximum exponent; mantissas are right-shifted by the exponent difference
(Algorithm 1) so the MAC array runs pure fixed-point.  Partial sums use a
widened 15-bit mantissa and are truncated back to storage precision only at
the end (§IV.C "accuracy maintenance") — i.e. *quantize the inputs, never
narrow the accumulator*.

TPU adaptation (see DESIGN.md §2): the MXU natively accumulates in f32, so
the wide-accumulator discipline is expressed as int/f32 accumulation over
shared-exponent integer mantissas.  What BFP buys on TPU is *bandwidth*
(an 8-bit mantissa block with one exponent per 32 values is ~4x smaller
than f32 and ~2x smaller than bf16), so the same quantizer here feeds

  * the BFP matmul kernels (forward compute, kernels/bfp_matmul),
  * compressed gradient all-reduce (optim/grad_utils),
  * 8-bit Adam moments (optim/optimizers).

All functions are pure and jit-friendly.  ``quantize`` is bit-exact to
Algorithm 1 (integer mantissas, arithmetic right shift == hardware
truncation); tests cross-check against a numpy oracle.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BLOCK = 32          # values per shared exponent (paper: norm block)
DEFAULT_MANTISSA = 10       # FP16 mantissa width used by the paper
WIDE_MANTISSA = 15          # paper's widened accumulator mantissa


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BFPTensor:
    """A block-floating-point tensor.

    ``mantissa`` is a signed integer tensor with the original shape;
    ``exponent`` holds one power-of-two exponent per block along ``axis``,
    laid out as ``moveaxis(x, axis, -1).shape[:-1] + (n_blocks,)``.  The
    represented value is ``mantissa * 2**(exponent - mantissa_bits)``.
    """

    mantissa: jax.Array          # int-valued (stored int8/int16/int32)
    exponent: jax.Array          # int32, per block
    mantissa_bits: int
    block_size: int
    axis: int

    def tree_flatten(self):
        return (self.mantissa, self.exponent), (
            self.mantissa_bits,
            self.block_size,
            self.axis,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        m, e = children
        return cls(m, e, *aux)

    @property
    def shape(self):
        return self.mantissa.shape

    def nbytes_model(self) -> int:
        """Modelled storage cost (what HBM/ICI would carry on TPU)."""
        mbytes = 1 if self.mantissa_bits <= 7 else (2 if self.mantissa_bits <= 15 else 4)
        return int(
            np.prod(self.mantissa.shape) * mbytes
            + np.prod(self.exponent.shape)  # 1 byte/exponent
        )


def exp2i(e: jax.Array) -> jax.Array:
    """EXACT 2**e for integer e — jnp.exp2 is exp(x*ln2) on some backends
    and is off by an ulp, which breaks bit-exactness vs Algorithm 1.
    Builds the f32 exponent field directly; e clamped to normal range
    (out-of-range only happens for all-zero blocks, where mantissas are 0)."""
    e = jnp.clip(e.astype(jnp.int32), -126, 127)
    bits = ((e + 127) << 23).astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _blockify(x: jax.Array, block_size: int, axis: int) -> Tuple[jax.Array, tuple]:
    """Reshape so blocks are contiguous on a new trailing axis."""
    axis = axis % x.ndim
    x = jnp.moveaxis(x, axis, -1)
    orig = x.shape
    n = orig[-1]
    pad = (-n) % block_size
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    x = x.reshape(*x.shape[:-1], (n + pad) // block_size, block_size)
    return x, orig


def _unblockify(x: jax.Array, orig: tuple, axis: int, ndim: int) -> jax.Array:
    x = x.reshape(*x.shape[:-2], -1)[..., : orig[-1]]
    return jnp.moveaxis(x, -1, axis % ndim)


@partial(jax.jit, static_argnames=("block_size", "mantissa_bits", "axis", "rounding"))
def quantize(
    x: jax.Array,
    *,
    block_size: int = DEFAULT_BLOCK,
    mantissa_bits: int = DEFAULT_MANTISSA,
    axis: int = -1,
    rounding: str = "trunc",
) -> BFPTensor:
    """Algorithm 1 — BFP normalization.

    For block X = (m_1 2^{e_1}, ..., m_N 2^{e_N}):
        xi = max_i e_i;  d_i = xi - e_i;  m_bi = m_i >> d_i
    ``rounding='trunc'`` matches the hardware right-shift; ``'nearest'``
    adds half-ulp before shifting (the software toolchain option).
    """
    if rounding not in ("trunc", "nearest"):
        raise ValueError(rounding)
    xb, orig = _blockify(x.astype(jnp.float32), block_size, axis)
    m, e = jnp.frexp(xb)                      # x = m * 2**e, |m| in [0.5, 1)
    e = jnp.where(xb == 0, -(2**30), e)       # zeros never win the max
    xi = jnp.max(e, axis=-1, keepdims=True)   # block max exponent
    xi = jnp.maximum(xi, -(2**29))            # all-zero block -> harmless exp
    d = xi - e                                # shift distances >= 0
    # integer mantissa with `mantissa_bits` fractional bits of |m| < 1:
    mi = m * (1 << mantissa_bits)
    mi = jnp.trunc(mi).astype(jnp.int32)      # frexp mantissa is exact in f32
    d = jnp.minimum(d, 31)
    if rounding == "nearest":
        # add +/- half of the soon-to-be-dropped ulp before shifting
        half = jnp.where(d > 0, (1 << jnp.maximum(d - 1, 0)), 0)
        mi = mi + jnp.sign(mi).astype(jnp.int32) * half
    mb = mi >> d                              # arithmetic shift == truncation
    mb = _unblockify(mb, orig, axis, x.ndim)
    exponent = jnp.squeeze(xi, -1).astype(jnp.int32)
    # store the axis in NEGATIVE form: BFPTensor leaves get sliced along
    # leading (layer-stack) dims by lax.scan, and a last-relative axis
    # stays valid under that slicing
    axis_store = axis if axis < 0 else axis - x.ndim
    return BFPTensor(mb, exponent, mantissa_bits, block_size, axis_store)


@jax.jit
def dequantize(t: BFPTensor) -> jax.Array:
    mb, orig = _blockify(t.mantissa.astype(jnp.float32), t.block_size, t.axis)
    scale = exp2i(t.exponent - t.mantissa_bits)
    out = mb * scale[..., None]
    return _unblockify(out, orig, t.axis, t.mantissa.ndim)


def roundtrip(
    x: jax.Array,
    *,
    block_size: int = DEFAULT_BLOCK,
    mantissa_bits: int = DEFAULT_MANTISSA,
    axis: int = -1,
    rounding: str = "trunc",
) -> jax.Array:
    """Quantize-dequantize: the numerical effect of running through BFP."""
    return dequantize(
        quantize(
            x,
            block_size=block_size,
            mantissa_bits=mantissa_bits,
            axis=axis,
            rounding=rounding,
        )
    ).astype(x.dtype)


def quantization_error(x: jax.Array, **kw) -> jax.Array:
    """Mean relative error introduced by BFP — used by precision benches."""
    y = roundtrip(x, **kw)
    denom = jnp.maximum(jnp.abs(x), 1e-12)
    return jnp.mean(jnp.abs(x - y) / denom)


# ---------------------------------------------------------------------------
# BFP matmul semantics (the oracle mirrored by kernels/bfp_matmul).
# ---------------------------------------------------------------------------

def bfp_matmul_reference(
    a: jax.Array,
    b: jax.Array,
    *,
    block_size: int = DEFAULT_BLOCK,
    mantissa_bits: int = DEFAULT_MANTISSA,
    rounding: str = "trunc",
    wide_accum: bool = True,
) -> jax.Array:
    """C = A @ B with both operands BFP-quantized along the contraction dim.

    A: (M, K) blocked along K; B: (K, N) blocked along K.  Within a block
    the mantissa dot is exact integer arithmetic (the paper's fixed-point
    MAC); across blocks partial sums accumulate in f32 — the widened
    accumulator of §IV.C.  ``wide_accum=False`` truncates every partial sum
    back to `mantissa_bits` (the failure mode the paper's Fig. 7 fixes),
    used by the Table VI precision benchmark.
    """
    qa = quantize(a, block_size=block_size, mantissa_bits=mantissa_bits,
                  axis=-1, rounding=rounding)
    qb = quantize(b, block_size=block_size, mantissa_bits=mantissa_bits,
                  axis=0, rounding=rounding)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    nb = -(-K // block_size)
    pad = nb * block_size - K
    ma = jnp.pad(qa.mantissa, ((0, 0), (0, pad))).reshape(M, nb, block_size)
    mb = jnp.pad(qb.mantissa, ((0, pad), (0, 0))).reshape(nb, block_size, N)
    # exponent layout: quantization axis moved last then blocked, so
    # qa.exponent is (M, nb) and qb.exponent (axis=0) is (N, nb).
    ea = qa.exponent                                   # (M, nb)
    eb = qb.exponent.T                                 # (nb, N)
    # exact int32 dot per block (mantissas fit in mantissa_bits each, block
    # sums fit easily in f32's 24-bit exact-integer range for mb<=11, and in
    # int32 generally; use f32 einsum over ints for MXU-shaped math):
    partial = jnp.einsum(
        "mkb,kbn->kmn",
        ma.astype(jnp.float32),
        mb.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )                                                   # (nb, M, N)
    scale = exp2i(
        ea.T[:, :, None] + eb[:, None, :] - 2 * mantissa_bits
    )                                                   # (nb, M, N)
    contrib = partial * scale
    if wide_accum:
        return jnp.sum(contrib, axis=0)
    # narrow accumulator: truncate each running partial sum to mantissa_bits
    def body(carry, c):
        s = carry + c
        s = roundtrip(s, block_size=s.shape[-1], mantissa_bits=mantissa_bits,
                      axis=-1, rounding="trunc")
        return s, None
    out, _ = jax.lax.scan(body, jnp.zeros((M, N), jnp.float32), contrib)
    return out
