"""Microcode interpreter — the paper's FCN module + microcode interpreter
(Fig. 5), as a trace-time executor emitting one XLA program.

The hardware parses one microcode per layer and drives fixed datapath
units (conv / pool / upsample / post-process) against a DDR4 data pool.
Here the data pool is a trace-time *arena* keyed by the microcode address
fields; the datapath units are jnp/Pallas implementations chosen by
``mode``:

    mode="reference"  pure lax/jnp ops (the oracle)
    mode="optimized"  Winograd F(4x4,3x3) for stride-1 3x3 convs, fused
                      phase-decomposed upsample, Pallas kernels where
                      available

BFP numerics (paper §III.E): when a :class:`BFPConfig` is given, conv
inputs and weights are run through Algorithm 1 quantization before the MAC
and the accumulator stays wide (f32 >= the paper's 15-bit mantissa) — the
§IV.C accuracy-maintenance discipline.  Storage between layers is FP16
(``storage_dtype``), exactly the paper's data-pool format.

The same interpreter executes LM architectures: :func:`build_stream_fn`
turns a microcode segment into a layer function by dispatching extended
opcodes against a module registry (the "datapath modules" for
transformers), with ``res_op`` cache/add providing residual connections —
the transformer residual is *literally* the paper's Fig. 3 mechanism.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import bfp as bfp_lib
from . import fuse, winograd
from .assembler import Program, STORAGE_BYTES
from .microcode import ExtOp, LayerType, Microcode, ResOp


@dataclasses.dataclass(frozen=True)
class BFPConfig:
    block_size: int = bfp_lib.DEFAULT_BLOCK
    mantissa_bits: int = bfp_lib.DEFAULT_MANTISSA
    rounding: str = "trunc"
    wide_accum: bool = True      # False reproduces the pre-Fig.7 failure


def _he_init(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * np.sqrt(2.0 / fan_in)


class FCNEngine:
    """Executes an assembled FCN :class:`Program` (paper Figs. 2 & 5)."""

    def __init__(
        self,
        program: Program,
        mode: str = "reference",
        bfp: Optional[BFPConfig] = None,
        storage_dtype=jnp.float32,
        use_pallas: bool = False,
        memplan=None,
    ):
        if mode not in ("reference", "optimized"):
            raise ValueError(mode)
        self.program = program
        self.mode = mode
        self.bfp = bfp
        self.storage_dtype = storage_dtype
        self.use_pallas = use_pallas
        # memplan: None/False -> legacy keep-everything loop; True ->
        # compute the static plan here (once per engine, pure function of
        # the program); a MemPlan instance is used as-is.  The plan
        # supplies fusion facts, dead-word/dead-store elimination, and
        # per-word free-after sets so the trace drops a buffer reference
        # at its last use instead of pinning every intermediate.
        if memplan is True:
            from . import memplan as memplan_lib

            memplan = memplan_lib.plan_program(
                program, dtype_bytes=jnp.dtype(storage_dtype).itemsize
            )
        self.memplan = memplan or None

    # -- parameters ----------------------------------------------------------
    def init_params(self, key: jax.Array) -> Dict[str, Dict[str, jax.Array]]:
        params: Dict[str, Dict[str, jax.Array]] = {}
        for idx, name in self.program.weight_bindings.items():
            mc = self.program.words[idx]
            spec = self.program.layer_specs[idx]
            key, k1 = jax.random.split(key)
            if spec.op == "conv":
                k = mc.kernel_size
                cin, cout = mc.in_ch, mc.out_ch
                if spec.table and spec.table.get("depthwise"):
                    p = {"w": _he_init(k1, (k, k, 1, cout), k * k)}
                else:
                    p = {"w": _he_init(k1, (k, k, cin, cout), k * k * cin)}
                if spec.bias:
                    p["b"] = jnp.zeros((cout,), jnp.float32)
                if spec.bn:
                    p.update(
                        gamma=jnp.ones((cout,), jnp.float32),
                        beta=jnp.zeros((cout,), jnp.float32),
                        mean=jnp.zeros((cout,), jnp.float32),
                        var=jnp.ones((cout,), jnp.float32),
                    )
                params[name] = p
            elif spec.op == "upsample" and spec.upsample_mode == "fused":
                cin = mc.in_ch
                cout = mc.out_ch or cin
                params[name] = {"w": _he_init(k1, (3, 3, cin, cout), 9 * cin)}
        return params

    def normalize_weights(self, params):
        """Paper Fig. 4 right branch: fold BN, then BFP-normalize weights."""
        out = {}
        for idx, name in self.program.weight_bindings.items():
            spec = self.program.layer_specs[idx]
            p = dict(params[name])
            if spec.op == "conv" and spec.bn:
                w, b = fuse.fold_batchnorm(
                    p["w"], p.get("b"), p["gamma"], p["beta"], p["mean"],
                    p["var"],
                )
                p = {"w": w, "b": b}
            if self.bfp is not None and "w" in p:
                p["w"] = bfp_lib.roundtrip(
                    p["w"],
                    block_size=self.bfp.block_size,
                    mantissa_bits=self.bfp.mantissa_bits,
                    axis=-2,                       # block along Cin (K dim)
                    rounding=self.bfp.rounding,
                )
            out[name] = p
        return out

    # -- datapath units -------------------------------------------------------
    def _conv(self, x, p, mc: Microcode, spec, *, transposed: bool = False,
              relu: bool = False):
        """One conv microcode word.  ``transposed`` rides in as an
        explicit argument — never instance state, so concurrent traces
        of one cached engine (transposed vs not, PR 4's async dispatch)
        each bake their own kernel orientation.  ``relu=True`` fuses the
        word's activation into this launch (fuse.can_fuse_conv_epilogue
        decides eligibility at the call site)."""
        w = p["w"]
        b = p.get("b")
        if transposed:
            # transposed-image mode: transpose the weight kernels (paper:
            # "transposing the corresponding weight kernels and modifying
            # the convolution mode")
            w = jnp.swapaxes(w, 0, 1)
        depthwise = bool(spec.table and spec.table.get("depthwise"))
        if (
            self.bfp is not None
            and self.use_pallas
            and self.mode == "optimized"
            and not depthwise
            and mc.kernel_size == 1
            and mc.stride_n == 1
        ):
            # a 1x1 conv IS a matmul: run the BFP Pallas kernel, which
            # quantizes both operands along the contraction dim itself
            # (activations axis=-1, weights axis=Cin — the same blocking
            # as the roundtrip below, so numerics match)
            from repro.kernels.bfp_matmul import ops as bops

            n, hh, ww, cin = x.shape
            y = bops.bfp_matmul(
                x.astype(jnp.float32).reshape(-1, cin),
                w.astype(jnp.float32).reshape(cin, -1),
                block_size=self.bfp.block_size,
                mantissa_bits=self.bfp.mantissa_bits,
                rounding=self.bfp.rounding,
            ).reshape(n, hh, ww, -1)
            return fuse.conv_epilogue(y, b, relu)
        if self.bfp is not None:
            x = bfp_lib.roundtrip(
                x.astype(jnp.float32),
                block_size=self.bfp.block_size,
                mantissa_bits=self.bfp.mantissa_bits,
                axis=-1,
                rounding=self.bfp.rounding,
            )
            # weights quantize in-call too (paper Fig. 4's normalization
            # branch must hold whether or not the caller ran
            # normalize_weights() offline — trunc rounding is idempotent,
            # so pre-normalized weights pass through unchanged)
            w = bfp_lib.roundtrip(
                w.astype(jnp.float32),
                block_size=self.bfp.block_size,
                mantissa_bits=self.bfp.mantissa_bits,
                axis=-2,                       # block along Cin (K dim)
                rounding=self.bfp.rounding,
            )
        x = x.astype(jnp.float32)
        w = w.astype(jnp.float32)
        if depthwise:
            y = lax.conv_general_dilated(
                x, w, (mc.stride_n, mc.stride_n), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=mc.in_ch,
                preferred_element_type=jnp.float32,
            )
            return fuse.conv_epilogue(y, b, relu)
        if (
            self.mode == "optimized"
            and mc.kernel_size == 3
            and mc.stride_n == 1
        ):
            if self.use_pallas:
                from repro.kernels.winograd_conv import ops as wops

                # bias + ReLU fused into the kernel's output-transform
                # flush: one launch for the whole microcode sequence
                return wops.winograd_conv2d(x, w, b, relu=relu)
            y = winograd.winograd_conv2d(x, w, padding="SAME")
        else:
            y = lax.conv_general_dilated(
                x, w, (mc.stride_n, mc.stride_n), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                preferred_element_type=jnp.float32,
            )
        return fuse.conv_epilogue(y, b, relu)

    @staticmethod
    def _pool(x, mc: Microcode, spec):
        k = 2 if mc.kernel == 0 else 3
        s = mc.stride_n
        if spec.pool_kind == "max":
            init, op = -jnp.inf, lax.max
        else:
            init, op = 0.0, lax.add
        y = lax.reduce_window(
            x, init, op, (1, k, k, 1), (1, s, s, 1), "SAME"
        )
        if spec.pool_kind == "avg":
            y = y / (k * k)
        return y

    def _upsample(self, x, p, mc, spec, *, decomposed: Optional[bool] = None):
        # ``decomposed`` is the plan fact "this upsample carries a 3x3
        # conv eligible for phase decomposition"; None derives it from
        # the spec (legacy no-plan path).
        if decomposed is None:
            decomposed = spec.upsample_mode != "nearest"
        if not decomposed:
            return fuse.upsample_nearest_2x(x)
        w = p["w"].astype(jnp.float32)
        if self.mode == "optimized":
            return fuse.upsample2x_conv3x3_fused(x.astype(jnp.float32), w)
        return fuse.upsample2x_conv3x3_naive(x.astype(jnp.float32), w)

    # -- row-banded spatial execution (paper §IV.B across devices) ------------
    @staticmethod
    def _spatial_banded(band_ctx, x, k, s, op, out_scale: int = 1):
        """Run one spatial layer on a row-band shard: exchange enough
        neighbor rows (``band_ctx.exchange`` — see
        runtime.collectives.halo_exchange), apply the op with its normal
        SAME padding on the extended band, slice this band's own output
        rows back out.  The halo is rounded up to a multiple of 4 so
        stride phase is always preserved and the Winograd F(4x4) tile
        grid stays aligned with the full plane wherever the band offset
        is itself a tile multiple."""
        if band_ctx is None or k <= s:
            # k <= s: windows never cross a band boundary (rows % s == 0)
            return op(x)
        halo = s * (-(-(k - 1) // s))            # context + stride phase
        halo = -(-halo // 4) * 4                 # winograd tile alignment
        bh = x.shape[1]
        y = op(band_ctx.exchange(x, halo))
        j0 = halo * out_scale // s
        return lax.slice_in_dim(y, j0, j0 + bh * out_scale // s, axis=1)

    # -- the interpreter loop ---------------------------------------------------
    def __call__(
        self, params, x: jax.Array, *, transposed: bool = False,
        band_ctx=None,
    ) -> Dict[str, jax.Array]:
        """x: (N, H, W, C) matching the program's input plane.

        ``band_ctx`` enables row-banded execution (paper §IV.B spread
        over a device mesh): ``x`` is one horizontal band of a larger
        plane and every spatial layer halo-exchanges its boundary rows
        through ``band_ctx.exchange(x, halo)`` so each band computes the
        full plane's rows (the multi-device generalization of
        core.rowband.conv2d_banded — see runtime/executor.py; exact up
        to Winograd tile-regrouping float noise in "optimized" mode).

        ``transposed=True`` is the paper's §IV.B over-wide-image mode: the
        SAME microcode program runs on the transposed plane with
        transposed kernels (square kernels, symmetric strides — so the
        datapath is reused unchanged); outputs come back transposed and
        the caller inverse-transposes.  Region extents are invariant
        (H*W*C bytes), so the address plan still holds.
        """
        prog = self.program
        c0, h0, w0 = prog.input_shape_chw
        if transposed:
            if x.shape[1:] != (w0, h0, c0):
                raise ValueError(
                    f"transposed input {x.shape} != plane {(w0, h0, c0)}"
                )
        elif x.shape[1:] != (h0, w0, c0):
            raise ValueError(
                f"input {x.shape} != program plane {(h0, w0, c0)}"
            )
        arena: Dict[int, jax.Array] = {prog.input_addr: x}
        extents: Dict[int, int] = {
            prog.input_addr: h0 * w0 * c0 * STORAGE_BYTES
        }
        cache: Optional[jax.Array] = None

        def read(addr: int, want_ch: int) -> jax.Array:
            if addr in arena and arena[addr].shape[-1] == want_ch:
                return arena[addr]
            # concat read: collect memory-contiguous buffers from addr
            parts, cur, got = [], addr, 0
            while got < want_ch:
                if cur not in arena:
                    raise KeyError(
                        f"read at {cur:#x}: no buffer (concat walk from "
                        f"{addr:#x}, have {got}/{want_ch} channels)"
                    )
                buf = arena[cur]
                parts.append(buf)
                got += buf.shape[-1]
                cur += extents[cur]
            if got != want_ch:
                raise ValueError(f"concat channel mismatch {got}!={want_ch}")
            return jnp.concatenate(parts, axis=-1)

        plan = self.memplan
        indices = plan.schedule if plan is not None else range(len(prog.words))
        for idx in indices:
            mc = prog.words[idx]
            wp = plan.word(idx) if plan is not None else None
            spec = prog.layer_specs[idx]
            xin = read(mc.in_addr, mc.in_ch)
            name = prog.weight_bindings.get(idx)
            p = params.get(name, {}) if name else {}
            lt = LayerType(mc.layer_type)
            fused_relu = False
            if lt == LayerType.CONV:
                # conv+bias+ReLU fuse into one launch (optimized mode;
                # eligibility is a plan fact when a memplan is bound, the
                # per-call fuse.py check otherwise — the residual register
                # reads the pre-activation value, so res words keep a
                # separate ReLU either way)
                eligible = (wp.fuse_relu if wp is not None
                            else fuse.can_fuse_conv_epilogue(mc))
                fused_relu = self.mode == "optimized" and eligible
                y = self._spatial_banded(
                    band_ctx, xin, mc.kernel_size, mc.stride_n,
                    lambda xb: self._conv(xb, p, mc, spec,
                                          transposed=transposed,
                                          relu=fused_relu),
                )
            elif lt == LayerType.POOL:
                y = self._spatial_banded(
                    band_ctx, xin, 2 if mc.kernel == 0 else 3, mc.stride_n,
                    lambda xb: self._pool(xb, mc, spec),
                )
            elif lt == LayerType.UPSAMPLE:
                up_conv = (wp.fuse_upsample if wp is not None
                           else spec.upsample_mode != "nearest")
                y = self._spatial_banded(
                    band_ctx, xin,
                    3 if up_conv else 1, 1,
                    lambda xb: self._upsample(xb, p, mc, spec,
                                              decomposed=up_conv),
                    out_scale=2,
                )
            else:
                op = ExtOp(mc.ext_opcode)
                if op == ExtOp.SIGMOID:
                    y = jax.nn.sigmoid(xin)
                elif op == ExtOp.ADD:
                    y = xin + read(mc.ext_addr2, mc.in_ch)
                elif op == ExtOp.IDENTITY:
                    y = xin
                else:
                    raise NotImplementedError(
                        f"FCN engine does not implement {op!r}; LM opcodes "
                        f"run through build_stream_fn"
                    )
            if mc.res_op == ResOp.CACHE:
                cache = y
            elif mc.res_op == ResOp.ADD:
                assert cache is not None, "res add with empty cache register"
                y = y + cache
            if mc.relu and not fused_relu:
                y = jax.nn.relu(y)
            # write back to the data pool in storage precision (FP16 in the
            # paper; f32 for the reference numerics)
            y = y.astype(self.storage_dtype)
            if wp is None or wp.store:
                arena[mc.out_addr] = y
                h, w, c = prog.addr_shapes[mc.out_addr]
                extents[mc.out_addr] = h * w * c * STORAGE_BYTES
            if wp is not None:
                # drop buffers at their last use so the trace holds no
                # reference past the plan's liveness range
                for a in wp.free_after:
                    arena.pop(a, None)
                    extents.pop(a, None)
                if wp.drop_cache:
                    cache = None

        return {k: arena[a] for k, a in prog.outputs.items()}


# ---------------------------------------------------------------------------
# LM stream execution — same ISA, transformer datapath modules.
# ---------------------------------------------------------------------------

# module signature: fn(params, x, *, mc, table, ctx) -> y
ModuleFn = Callable[..., jax.Array]


def build_stream_fn(
    words: Sequence[Microcode],
    tables: Sequence[Dict[str, Any]],
    registry: Dict[ExtOp, ModuleFn],
    weight_bindings: Dict[int, str],
):
    """Compile a microcode segment into ``fn(params, x, ctx) -> (y, ctx)``.

    ``params`` is a dict keyed by binding name.  The residual cache/add
    register is interpreted exactly as in :class:`FCNEngine`; transformer
    pre-norm residuals are expressed as IDENTITY(cache) ... ATTN(add).
    The returned function is pure and scan-friendly: a transformer stack
    scans it over stacked per-layer params (see models/lm/transformer.py).
    """

    words = list(words)

    def _deq(p, ctx):
        """BFP-stored weights (serving mode): int8 mantissas stream from
        HBM; the widening to compute dtype is the VMEM dequant unit."""
        is_bfp = lambda x: isinstance(x, bfp_lib.BFPTensor)
        if not any(is_bfp(l) for l in
                   jax.tree_util.tree_leaves(p, is_leaf=is_bfp)):
            return p
        dt = ctx.get("compute_dtype", jnp.bfloat16)
        return jax.tree_util.tree_map(
            lambda x: bfp_lib.dequantize(x).astype(dt) if is_bfp(x) else x,
            p, is_leaf=is_bfp,
        )

    def fn(params, x, ctx=None):
        ctx = {} if ctx is None else ctx
        cache = None
        cur = x
        for idx, mc in enumerate(words):
            op = ExtOp(mc.ext_opcode)
            name = weight_bindings.get(idx)
            p = params.get(name) if name else None
            if p is not None:
                p = _deq(p, ctx)
            table = tables[mc.ext_table_idx - 1] if mc.ext_table_idx else {}
            if op == ExtOp.IDENTITY:
                y = cur
            elif op == ExtOp.ADD:
                y = cur + (cache if cache is not None else 0)
            elif op in registry:
                y = registry[op](p, cur, mc=mc, table=table, ctx=ctx)
            else:
                raise NotImplementedError(f"no module registered for {op!r}")
            if mc.res_op == ResOp.CACHE:
                cache = y
            elif mc.res_op == ResOp.ADD and op != ExtOp.ADD:
                assert cache is not None
                y = y + cache
            if mc.relu:
                y = jax.nn.relu(y)
            cur = y
        return cur, ctx

    return fn
