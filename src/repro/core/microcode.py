"""256-bit microcode ISA — bit-exact implementation of paper Table II.

The paper configures a fixed FCN datapath with one 256-bit microcode word
per layer (width aligned to the AXI bus).  Field layout (LSB first), from
Table II:

    =============  =====  =========================================
    field          bits   meaning
    =============  =====  =========================================
    layer_type     2      0=conv 1=pool 2=upsample 3=null/extended
    transpose_relu 2      bit0 = relu enable, bit1 = transpose mode
    in_ch          16     input channels
    out_ch         16     output channels
    height         20     feature-map height (rows)
    width          15     feature-map width (<= 4096 in the paper)
    kernel         2      0 -> 1x1, 1 -> 3x3, 2 -> 7x7
    stride         1      0 -> 1,   1 -> 2
    res_op         2      0=none 1=cache result 2=add cached result
    in_addr        34     input buffer address (external memory)
    out_addr       34     output buffer address
    reserved       112    (extension page, below)
    =============  =====  =========================================

Layer interconnection is carried entirely by the address fields: each
layer writes its output at ``out_addr`` and the next layer reads from its
``in_addr``; *concatenation* is expressed by allocating two producers at
adjacent addresses and letting the consumer read the combined extent
(paper SSIII-B).  Residual blocks use ``res_op`` (1 = cache, 2 = add the
cached tensor; Fig. 3).

Extension page
--------------
The paper reserves 112 bits.  We use them — exactly as reserved fields
are meant to be used — to extend the same ISA to transformer / SSM
"datapath modules" so that *every* architecture in this framework is
driven by the one interpreter (the paper's versatility axis):

    =============  =====  =========================================
    ext field      bits   meaning (within the 112 reserved bits)
    =============  =====  =========================================
    ext_opcode     8      ExtOp below; 0 keeps plain Table II meaning
    ext_table_idx  16     index into the program's parameter side-table
                          (for hyperparameters too wide for the fields,
                          e.g. vocab 163840 > 2**16; the paper likewise
                          keeps weights out-of-band in DDR4)
    ext_addr2      34     second input address (binary ops: add/concat/
                          cross-attention memory)
    ext_flags      16     op-specific flags
    (unused)       38     still reserved
    =============  =====  =========================================
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, List, Sequence, Tuple

import numpy as np

MICROCODE_BITS = 256
MICROCODE_BYTES = MICROCODE_BITS // 8

# (name, bitwidth) in LSB-first order — Table II verbatim, reserved split
# into the extension page.
_FIELDS: Tuple[Tuple[str, int], ...] = (
    ("layer_type", 2),
    ("transpose_relu", 2),
    ("in_ch", 16),
    ("out_ch", 16),
    ("height", 20),
    ("width", 15),
    ("kernel", 2),
    ("stride", 1),
    ("res_op", 2),
    ("in_addr", 34),
    ("out_addr", 34),
    # --- 112 reserved bits ---
    ("ext_opcode", 8),
    ("ext_table_idx", 16),
    ("ext_addr2", 34),
    ("ext_flags", 16),
    ("reserved", 38),
)

assert sum(w for _, w in _FIELDS) == MICROCODE_BITS


class LayerType(enum.IntEnum):
    CONV = 0
    POOL = 1
    UPSAMPLE = 2
    EXT = 3          # the paper's "null" type doubles as our escape


class Kernel(enum.IntEnum):
    K1 = 0           # 1x1
    K3 = 1           # 3x3
    K7 = 2           # 7x7


KERNEL_SIZES = {Kernel.K1: 1, Kernel.K3: 3, Kernel.K7: 7}
KERNEL_CODES = {1: Kernel.K1, 3: Kernel.K3, 7: Kernel.K7}


class ResOp(enum.IntEnum):
    NONE = 0
    CACHE = 1        # cache layer result (residual branch entry)
    ADD = 2          # add cached result (residual branch exit)


class ExtOp(enum.IntEnum):
    """Extended datapath modules (reserved-page opcodes)."""

    NONE = 0
    # --- FCN fusion-module extras (paper: sigmoid replaces maxpool) ---
    SIGMOID = 1
    ADD = 2          # explicit elementwise add of in_addr + ext_addr2
    CONCAT = 3       # explicit concat marker (normally implied by addrs)
    IDENTITY = 4
    # --- transformer / LM datapath modules ---
    EMBED = 16       # token embedding lookup
    RMSNORM = 17
    LAYERNORM = 18
    ATTN = 19        # GQA attention with RoPE (self)
    CROSS_ATTN = 20  # cross attention (enc-dec); memory at ext_addr2
    GLU_MLP = 21     # gate/up/down SwiGLU MLP
    MLP = 22         # plain 2-matmul MLP (gelu)
    MOE = 23         # top-k routed mixture of experts
    SSD = 24         # Mamba2 state-space dual block
    CONV1D = 25      # short causal conv (mamba/whisper frontends)
    LM_HEAD = 26     # final projection to vocab
    SOFTMAX = 27
    GELU = 28
    SCALE = 29


@dataclasses.dataclass(frozen=True)
class Microcode:
    """One decoded 256-bit word.  Fields mirror Table II."""

    layer_type: int = int(LayerType.EXT)
    transpose_relu: int = 0
    in_ch: int = 0
    out_ch: int = 0
    height: int = 0
    width: int = 0
    kernel: int = int(Kernel.K1)
    stride: int = 0
    res_op: int = int(ResOp.NONE)
    in_addr: int = 0
    out_addr: int = 0
    ext_opcode: int = int(ExtOp.NONE)
    ext_table_idx: int = 0
    ext_addr2: int = 0
    ext_flags: int = 0
    reserved: int = 0

    # ---- convenience views -------------------------------------------------
    @property
    def relu(self) -> bool:
        return bool(self.transpose_relu & 0b01)

    @property
    def transpose(self) -> bool:
        return bool(self.transpose_relu & 0b10)

    @property
    def kernel_size(self) -> int:
        return KERNEL_SIZES[Kernel(self.kernel)]

    @property
    def stride_n(self) -> int:
        return 2 if self.stride else 1

    def validate(self) -> "Microcode":
        for name, bits in _FIELDS:
            v = getattr(self, name)
            if not (0 <= v < (1 << bits)):
                raise ValueError(
                    f"microcode field {name}={v} does not fit in {bits} bits"
                )
        return self


def pack(mc: Microcode) -> np.ndarray:
    """Pack to 32 little-endian bytes (one AXI-width word)."""
    mc.validate()
    word = 0
    shift = 0
    for name, bits in _FIELDS:
        word |= (getattr(mc, name) & ((1 << bits) - 1)) << shift
        shift += bits
    return np.frombuffer(
        word.to_bytes(MICROCODE_BYTES, "little"), dtype=np.uint8
    ).copy()


def unpack(raw: np.ndarray | bytes) -> Microcode:
    data = bytes(bytearray(raw))
    if len(data) != MICROCODE_BYTES:
        raise ValueError(f"expected {MICROCODE_BYTES} bytes, got {len(data)}")
    word = int.from_bytes(data, "little")
    kwargs = {}
    shift = 0
    for name, bits in _FIELDS:
        kwargs[name] = (word >> shift) & ((1 << bits) - 1)
        shift += bits
    return Microcode(**kwargs)


def pack_program(words: Sequence[Microcode]) -> np.ndarray:
    """Pack a whole program into the shape the config RAM would hold."""
    if not words:
        return np.zeros((0, MICROCODE_BYTES), dtype=np.uint8)
    return np.stack([pack(w) for w in words])


def unpack_program(raw: np.ndarray) -> List[Microcode]:
    return [unpack(row) for row in np.asarray(raw, dtype=np.uint8)]


def disassemble(words: Iterable[Microcode]) -> str:
    """Human-readable listing (debug aid; mirrors Fig. 3's table style)."""
    rows = []
    for i, w in enumerate(words):
        if w.layer_type == LayerType.EXT and w.ext_opcode != ExtOp.NONE:
            op = f"ext.{ExtOp(w.ext_opcode).name.lower()}"
        else:
            op = LayerType(w.layer_type).name.lower()
        rows.append(
            f"{i:4d}  {op:<14s} k{w.kernel_size} s{w.stride_n} "
            f"c{w.in_ch}->{w.out_ch} hw={w.height}x{w.width} "
            f"res={ResOp(w.res_op).name.lower():<5s} "
            f"{'relu ' if w.relu else ''}{'T ' if w.transpose else ''}"
            f"@{w.in_addr:#x}"
            + (f"+{w.ext_addr2:#x}" if w.ext_addr2 else "")
            + f" -> {w.out_addr:#x}"
            + (f" tbl[{w.ext_table_idx}]" if w.ext_table_idx else "")
        )
    return "\n".join(rows)
