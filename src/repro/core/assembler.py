"""Auto-configuration flow, left branch (paper Fig. 4): model description
-> general IR -> microcode program.

The paper's Python parser resolves a model description file layer by layer
into microcode; weights are normalized separately (right branch — see
``core.bfp`` and ``FCNEngine.normalize_weights``).  Here the "model
description" is a list of :class:`LayerSpec` (what the paper calls the
*general model description*), produced by the backbone/fusion builders in
``models/fcn`` and the LM block builders in ``models/lm``.

Address allocation (paper §III.B):
  * every layer output is a region in external memory, assigned by a bump
    allocator (the DDR4 data pool);
  * concatenation is expressed by allocating the producers *adjacent* so
    the consumer reads one combined extent — no copy, no concat op;
  * residual connections use the ``res_op`` cache/add register (Fig. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .microcode import (
    ExtOp,
    Kernel,
    KERNEL_CODES,
    LayerType,
    Microcode,
    ResOp,
)

# storage dtype in the data pool is FP16 (paper §III.E)
STORAGE_BYTES = 2
ADDR_ALIGN = 64          # AXI burst alignment


@dataclasses.dataclass
class LayerSpec:
    """One node of the general model description."""

    name: str
    op: str                              # conv|pool|upsample|sigmoid|add|
                                         # identity|input|ext:<opname>
    inputs: Sequence[str] = ()
    out_ch: int = 0
    kernel: int = 1
    stride: int = 1
    relu: bool = False
    bn: bool = False                     # batch-norm (folded at normalize)
    bias: bool = True
    res: str = "none"                    # none|cache|add
    pool_kind: str = "max"               # max|avg (pool layers)
    upsample_mode: str = "fused"         # fused|nearest (upsample layers)
    table: Optional[Dict[str, Any]] = None   # ext-op hyperparameters
    ext_op: Optional[ExtOp] = None


@dataclasses.dataclass
class Program:
    """Assembled program: microcode words + side tables + bindings."""

    words: List[Microcode]
    tables: List[Dict[str, Any]]
    weight_bindings: Dict[int, str]      # word index -> parameter name
    layer_specs: Dict[int, LayerSpec]    # word index -> originating spec
    input_addr: int
    input_shape_chw: Tuple[int, int, int]    # (C, H, W) of the input plane
    outputs: Dict[str, int]              # output name -> address
    addr_shapes: Dict[int, Tuple[int, int, int]]   # addr -> (H, W, C)
    arena_bytes: int

    def disassemble(self) -> str:
        from .microcode import disassemble

        return disassemble(self.words)


def _align(addr: int) -> int:
    return (addr + ADDR_ALIGN - 1) // ADDR_ALIGN * ADDR_ALIGN


def _region_bytes(h: int, w: int, c: int) -> int:
    return _align(h * w * c * STORAGE_BYTES)


class Assembler:
    """Resolves a LayerSpec graph into a :class:`Program`.

    Shapes are propagated from the input plane so every microcode word
    carries the height/width/channel hyperparameters of Table II.
    """

    def __init__(self, input_shape_hwc: Tuple[int, int, int]):
        self.input_shape = input_shape_hwc

    # -- shape rules ---------------------------------------------------------
    @staticmethod
    def _out_shape(spec: LayerSpec, h: int, w: int, c: int) -> Tuple[int, int, int]:
        if spec.op == "conv":
            s = spec.stride
            return (-(-h // s), -(-w // s), spec.out_ch)
        if spec.op == "pool":
            s = spec.stride
            return (-(-h // s), -(-w // s), c)
        if spec.op == "upsample":
            return (2 * h, 2 * w, spec.out_ch or c)
        if spec.op in ("sigmoid", "identity", "add"):
            return (h, w, spec.out_ch or c)
        raise ValueError(f"unknown FCN op {spec.op!r}")

    def assemble(
        self, specs: Sequence[LayerSpec], outputs: Sequence[str]
    ) -> Program:
        by_name = {s.name: s for s in specs}
        order = list(specs)

        # ---- pass 1: concat groups --------------------------------------
        # a layer consuming >1 input reads them as one extent; producers in
        # the group must be allocated adjacently, in input order.
        group_of: Dict[str, Tuple[str, int]] = {}
        for s in order:
            if s.op == "add":
                continue                       # binary op, not a concat
            if len(s.inputs) > 1:
                for slot, p in enumerate(s.inputs):
                    if p in group_of and group_of[p][0] != s.name:
                        raise ValueError(
                            f"{p} feeds two concat groups; insert an "
                            f"identity copy layer"
                        )
                    group_of[p] = (s.name, slot)

        # ---- pass 2: allocation + emission -------------------------------
        h0, w0, c0 = self.input_shape
        cursor = 0
        input_addr = cursor
        cursor += _region_bytes(h0, w0, c0)
        addr_of: Dict[str, int] = {"input": input_addr}
        shape_of: Dict[str, Tuple[int, int, int]] = {"input": (h0, w0, c0)}
        addr_shapes: Dict[int, Tuple[int, int, int]] = {
            input_addr: (h0, w0, c0)
        }
        # concat groups get a contiguous region allocated when their first
        # producer is emitted:
        group_base: Dict[str, int] = {}

        words: List[Microcode] = []
        tables: List[Dict[str, Any]] = []
        bindings: Dict[int, str] = {}
        spec_of: Dict[int, LayerSpec] = {}

        def alloc_out(spec: LayerSpec, shp) -> int:
            nonlocal cursor
            h, w, c = shp
            if spec.name in group_of:
                gname, slot = group_of[spec.name]
                consumer = by_name[gname]
                if gname not in group_base:
                    # allocate the whole concat extent now, packed tight
                    # (concat is along channels; members share H, W)
                    total = 0
                    for p in consumer.inputs:
                        ph, pw, pc = self._infer_shape(p, by_name, shape_of)
                        total += ph * pw * pc * STORAGE_BYTES
                    base = _align(cursor)
                    group_base[gname] = base
                    cursor = base + _align(total)
                # member offset = sum of earlier members' *unaligned* bytes
                off = 0
                for p in consumer.inputs[:slot]:
                    ph, pw, pc = self._infer_shape(p, by_name, shape_of)
                    off += ph * pw * pc * STORAGE_BYTES
                return group_base[gname] + off
            base = _align(cursor)
            cursor = base + _region_bytes(h, w, c)
            return base

        for spec in order:
            ins = list(spec.inputs) or ["input"]
            ih, iw, ic = shape_of[ins[0]]
            if len(ins) > 1:
                for p in ins[1:]:
                    ph, pw, pc = shape_of[p]
                    if (ph, pw) != (ih, iw):
                        raise ValueError(
                            f"{'add' if spec.op == 'add' else 'concat'} "
                            f"into {spec.name}: H/W mismatch "
                            f"{(ph, pw)} vs {(ih, iw)}"
                        )
                    if spec.op == "add":
                        # binary add reads TWO same-shape operands (the
                        # second via ext_addr2), never a combined extent
                        # — channels must match, not sum
                        if pc != ic:
                            raise ValueError(
                                f"add into {spec.name}: channel mismatch "
                                f"{pc} vs {ic}"
                            )
                    else:          # concat read: channels sum, H/W match
                        ic += pc
            in_addr = addr_of[ins[0]]

            if spec.op.startswith("ext:") or spec.ext_op is not None:
                ext = spec.ext_op or ExtOp[spec.op.split(":", 1)[1].upper()]
                oshape = (ih, iw, spec.out_ch or ic)
            else:
                ext = ExtOp.NONE
                oshape = self._out_shape(spec, ih, iw, ic)
            out_addr = alloc_out(spec, oshape)

            layer_type = {
                "conv": LayerType.CONV,
                "pool": LayerType.POOL,
                "upsample": LayerType.UPSAMPLE,
            }.get(spec.op, LayerType.EXT)
            if layer_type == LayerType.EXT and ext == ExtOp.NONE:
                ext = {
                    "sigmoid": ExtOp.SIGMOID,
                    "add": ExtOp.ADD,
                    "identity": ExtOp.IDENTITY,
                }[spec.op]

            tbl_idx = 0
            if spec.table:
                tables.append(dict(spec.table))
                tbl_idx = len(tables)        # 1-based; 0 = no table

            # pool convention: code 0 -> 2x2, code 1 -> 3x3 (Table II's
            # kernel field only encodes {1,3,7}; the pool unit treats
            # code 0 as its native 2x2 window).  Anything else must
            # fail HERE: an unencodable kernel that silently snapped to
            # a nearby code would assemble fine and compute the wrong
            # thing.
            if spec.op == "pool":
                if spec.kernel not in (2, 3):
                    raise ValueError(
                        f"{spec.name}: pool kernel {spec.kernel} not "
                        f"encodable (the pool unit supports 2x2 and 3x3)"
                    )
                kernel_code = 0 if spec.kernel == 2 else 1
            elif spec.op == "conv" and spec.kernel not in KERNEL_CODES:
                raise ValueError(
                    f"{spec.name}: conv kernel {spec.kernel} not "
                    f"encodable (Table II encodes "
                    f"{sorted(KERNEL_CODES)})"
                )
            else:
                kernel_code = int(KERNEL_CODES.get(spec.kernel, Kernel.K1))

            mc = Microcode(
                layer_type=int(layer_type),
                transpose_relu=(0b01 if spec.relu else 0),
                in_ch=min(ic, (1 << 16) - 1),
                out_ch=min(oshape[2], (1 << 16) - 1),
                height=min(ih, (1 << 20) - 1),
                width=min(iw, (1 << 15) - 1),
                kernel=kernel_code,
                stride=1 if spec.stride == 2 else 0,
                res_op=int(ResOp[spec.res.upper()]),
                in_addr=in_addr,
                out_addr=out_addr,
                ext_opcode=int(ext),
                ext_table_idx=tbl_idx,
                ext_addr2=addr_of[ins[1]] if (spec.op == "add" and len(ins) > 1) else 0,
            ).validate()

            idx = len(words)
            words.append(mc)
            spec_of[idx] = spec
            if (
                spec.op == "conv"
                or (spec.op == "upsample" and spec.upsample_mode == "fused")
                or ext in (ExtOp.EMBED, ExtOp.ATTN, ExtOp.CROSS_ATTN,
                           ExtOp.GLU_MLP, ExtOp.MLP, ExtOp.MOE, ExtOp.SSD,
                           ExtOp.CONV1D, ExtOp.LM_HEAD, ExtOp.RMSNORM,
                           ExtOp.LAYERNORM)
            ):
                bindings[idx] = spec.name

            addr_of[spec.name] = out_addr
            shape_of[spec.name] = oshape
            addr_shapes[out_addr] = oshape

        return Program(
            words=words,
            tables=tables,
            weight_bindings=bindings,
            layer_specs=spec_of,
            input_addr=input_addr,
            input_shape_chw=(c0, h0, w0),
            outputs={o: addr_of[o] for o in outputs},
            addr_shapes=addr_shapes,
            arena_bytes=cursor,
        )

    def _infer_shape(self, name, by_name, shape_of):
        if name in shape_of:
            return shape_of[name]
        # forward-shape inference for not-yet-emitted concat members:
        spec = by_name[name]
        ins = list(spec.inputs) or ["input"]
        h, w, c = self._infer_shape(ins[0], by_name, shape_of)
        if len(ins) > 1 and spec.op != "add":      # add: channels match
            for p in ins[1:]:
                c += self._infer_shape(p, by_name, shape_of)[2]
        return self._out_shape(spec, h, w, c) if not (
            spec.op.startswith("ext:") or spec.ext_op
        ) else (h, w, spec.out_ch or c)
