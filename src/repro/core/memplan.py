"""Static microcode optimizer + data-pool memory planner (paper §III.B/§IV).

The paper's auto-configuration flow plans every layer's ``in_addr`` /
``out_addr`` ahead of time so the DDR4 data pool reuses a region the
moment its last consumer has run.  This module is that pass for our
assembled :class:`~repro.core.assembler.Program`:

* **liveness** — per-address last-use from the same concat-walk read
  discipline the interpreter uses (``in_addr`` extent walks,
  ``ext_addr2`` second operands, and the ``res_op`` cache/add register);
* **elimination** — words whose output is never observable (not read,
  not a program output, not a residual-cache source) are unreachable and
  dropped; residual-cache sources whose *arena* region is never read keep
  executing but skip the store (a *dead store*);
* **fusion facts** — conv+bias+ReLU epilogue fusion and the
  upsample2x+conv3x3 phase decomposition are decided here, once, instead
  of per-call inside the trace loop;
* **arena plan** — an address→slot assignment (best-fit reuse of freed
  slots), the peak live bytes under drop-at-last-use, and per-word
  free-after sets the interpreter uses to release buffers.

Everything is a pure function of the Program — no tracing, no params —
so a plan can be computed once per (bucket, model) and consulted by the
batcher, the engine LRU, and the planner's cost model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .assembler import Program, STORAGE_BYTES
from .microcode import ExtOp, LayerType, Microcode, ResOp
from . import fuse

#: end-of-program sentinel for lifetimes (outputs live past the last word)
_END = 1 << 30


@dataclasses.dataclass(frozen=True)
class WordPlan:
    """Per-word plan facts consumed by the interpreter loop."""

    index: int                      # position in the original word list
    store: bool                     # write out_addr into the arena?
    fuse_relu: bool                 # conv epilogue ReLU folds into launch
    fuse_upsample: bool             # upsample word carries a 3x3 conv
                                    # eligible for phase decomposition
    free_after: Tuple[int, ...]     # arena addrs dead once this word ran
    drop_cache: bool                # res register value dead after word


@dataclasses.dataclass(frozen=True)
class MemPlan:
    """The memory plan for one assembled program."""

    n_words: int                    # original word count
    schedule: Tuple[int, ...]       # live word indices, program order
    dead_words: Tuple[int, ...]     # unreachable words (skipped entirely)
    dead_stores: Tuple[int, ...]    # live words that skip the arena write
    dtype_bytes: int                # activation element size used for sizes
    peak_bytes: int                 # max live activation bytes (1 image)
    naive_bytes: int                # input + every word output kept live
    pool_bytes: int                 # sum of arena slot sizes
    slot_of: Dict[int, int]         # stored addr -> slot id
    slot_bytes: Tuple[int, ...]     # slot id -> size in bytes
    words: Dict[int, WordPlan]      # word index -> plan facts

    def word(self, idx: int) -> WordPlan:
        return self.words[idx]

    @property
    def reduction(self) -> float:
        """Fraction of the naive footprint the plan eliminates."""
        if self.naive_bytes <= 0:
            return 0.0
        return 1.0 - self.peak_bytes / self.naive_bytes


def _walk(program: Program, addr: int, want_ch: int) -> List[int]:
    """Static mirror of the interpreter's concat read walk: the list of
    region base addresses one read at ``addr`` for ``want_ch`` channels
    touches.  Extent arithmetic is the assembler's (STORAGE_BYTES), which
    is what the address fields were allocated with."""
    shapes = program.addr_shapes
    if addr in shapes and shapes[addr][2] == want_ch:
        return [addr]
    out, cur, got = [], addr, 0
    while got < want_ch:
        if cur not in shapes:
            raise KeyError(
                f"memplan walk at {cur:#x}: no region (from {addr:#x}, "
                f"have {got}/{want_ch} channels)"
            )
        h, w, c = shapes[cur]
        out.append(cur)
        got += c
        cur += h * w * c * STORAGE_BYTES
    if got != want_ch:
        raise ValueError(
            f"memplan walk from {addr:#x}: channels {got} != {want_ch}"
        )
    return out


def _reads_of(program: Program, idx: int, mc: Microcode) -> List[int]:
    """All arena addresses word ``idx`` reads (in_addr walk + ext_addr2)."""
    addrs = _walk(program, mc.in_addr, mc.in_ch)
    if (
        LayerType(mc.layer_type) == LayerType.EXT
        and ExtOp(mc.ext_opcode) == ExtOp.ADD
    ):
        addrs += _walk(program, mc.ext_addr2, mc.in_ch)
    return addrs


def _region_raw_bytes(program: Program, addr: int, dtype_bytes: int) -> int:
    h, w, c = program.addr_shapes[addr]
    return h * w * c * dtype_bytes


def plan_program(program: Program, *, dtype_bytes: int = 4) -> MemPlan:
    """Compute the :class:`MemPlan` for ``program``.

    ``dtype_bytes`` sizes activations for the byte accounting (4 for f32
    compute, 2 when the engine stores fp16 between layers); addresses and
    extents always use the assembler's STORAGE_BYTES arithmetic.
    """
    words = program.words
    n = len(words)

    # The pass assumes single assignment: every word writes a distinct
    # address (the bump allocator guarantees it).  A program violating
    # that gets a conservative identity plan — everything live, nothing
    # freed — rather than a wrong one.
    out_addrs = [mc.out_addr for mc in words]
    if len(set(out_addrs)) != n:
        return _identity_plan(program, dtype_bytes)

    def_word: Dict[int, int] = {program.input_addr: -1}
    for i, mc in enumerate(words):
        def_word[mc.out_addr] = i

    # nearest preceding res-CACHE word for every res-ADD word
    cache_src: Dict[int, int] = {}
    last_cache = -1
    for i, mc in enumerate(words):
        if mc.res_op == ResOp.CACHE:
            last_cache = i
        elif mc.res_op == ResOp.ADD:
            if last_cache < 0:
                raise ValueError(f"word {i}: res add with empty cache register")
            cache_src[i] = last_cache

    # ---- backward reachability from the program outputs -----------------
    needed: Set[int] = set(program.outputs.values())
    reg_demand: Set[int] = set()        # CACHE word indices a live ADD needs
    live = [False] * n
    for i in range(n - 1, -1, -1):
        mc = words[i]
        if mc.out_addr in needed or i in reg_demand:
            live[i] = True
            needed.update(_reads_of(program, i, mc))
            if mc.res_op == ResOp.ADD:
                reg_demand.add(cache_src[i])

    schedule = tuple(i for i in range(n) if live[i])
    dead_words = tuple(i for i in range(n) if not live[i])

    # ---- forward liveness over the live schedule ------------------------
    arena_use: Dict[int, int] = {}      # addr -> last word index reading it
    reg_use: Dict[int, int] = {}        # CACHE out_addr -> last register use
    for i in schedule:
        mc = words[i]
        for a in _reads_of(program, i, mc):
            arena_use[a] = i
        if mc.res_op == ResOp.ADD:
            src = cache_src[i]
            reg_use[words[src].out_addr] = i

    output_addrs = set(program.outputs.values())
    stored: Set[int] = {program.input_addr}
    dead_stores: List[int] = []
    for i in schedule:
        a = words[i].out_addr
        if a in arena_use or a in output_addrs:
            stored.add(a)
        else:
            # live only through the res register: execute, skip the store
            dead_stores.append(i)

    def lifetime_end(addr: int) -> int:
        if addr in output_addrs:
            return _END
        return max(arena_use.get(addr, def_word[addr]),
                   reg_use.get(addr, -1))

    # per-word free-after sets: stored regions whose last *arena* read is
    # this word (the register may keep the value alive past the drop —
    # it aliases the same array, so dropping the dict entry costs nothing)
    free_after: Dict[int, List[int]] = {i: [] for i in schedule}
    for a in stored:
        if a in output_addrs:
            continue
        last = arena_use.get(a)
        if last is not None:
            free_after[last].append(a)

    # drop_cache: last res-ADD consuming each register value
    drop_at: Set[int] = set()
    for src in set(cache_src.values()):
        uses = [i for i in schedule if cache_src.get(i) == src]
        if uses:
            drop_at.add(max(uses))

    # ---- peak live bytes under drop-at-last-use -------------------------
    frees_at: Dict[int, List[int]] = {}
    tracked = set(stored) | {words[i].out_addr for i in dead_stores}
    for a in tracked:
        frees_at.setdefault(lifetime_end(a), []).append(a)
    running = _region_raw_bytes(program, program.input_addr, dtype_bytes)
    for a in frees_at.get(-1, ()):      # degenerate: input never read
        running -= _region_raw_bytes(program, a, dtype_bytes)
    peak = running
    for i in schedule:
        running += _region_raw_bytes(program, words[i].out_addr, dtype_bytes)
        peak = max(peak, running)
        for a in frees_at.get(i, ()):
            running -= _region_raw_bytes(program, a, dtype_bytes)
    naive = sum(
        _region_raw_bytes(program, a, dtype_bytes)
        for a in [program.input_addr] + out_addrs
    )

    # ---- address -> arena slot assignment (best-fit reuse) --------------
    slot_bytes: List[int] = []
    free_slots: List[int] = []
    slot_of: Dict[int, int] = {}

    def acquire(need: int) -> int:
        fitting = [s for s in free_slots if slot_bytes[s] >= need]
        if fitting:
            s = min(fitting, key=lambda s: slot_bytes[s])
        elif free_slots:
            s = max(free_slots, key=lambda s: slot_bytes[s])
            slot_bytes[s] = need
        else:
            slot_bytes.append(need)
            return len(slot_bytes) - 1
        free_slots.remove(s)
        return s

    slot_of[program.input_addr] = acquire(
        _region_raw_bytes(program, program.input_addr, dtype_bytes)
    )
    slot_release: Dict[int, List[int]] = {}
    for a in stored:
        end = lifetime_end(a)
        if end < _END:
            slot_release.setdefault(end, []).append(a)
    for a in slot_release.get(-1, ()):
        free_slots.append(slot_of[a])
    for i in schedule:
        a = words[i].out_addr
        if a in stored:
            slot_of[a] = acquire(_region_raw_bytes(program, a, dtype_bytes))
        for r in slot_release.get(i, ()):
            free_slots.append(slot_of[r])

    # ---- per-word plan facts --------------------------------------------
    dead_store_set = set(dead_stores)
    plans: Dict[int, WordPlan] = {}
    for i in schedule:
        mc = words[i]
        spec = program.layer_specs[i]
        lt = LayerType(mc.layer_type)
        plans[i] = WordPlan(
            index=i,
            store=i not in dead_store_set,
            fuse_relu=(lt == LayerType.CONV
                       and fuse.can_fuse_conv_epilogue(mc)),
            fuse_upsample=(lt == LayerType.UPSAMPLE
                           and spec.upsample_mode == "fused"),
            free_after=tuple(sorted(free_after[i])),
            drop_cache=i in drop_at,
        )

    return MemPlan(
        n_words=n,
        schedule=schedule,
        dead_words=dead_words,
        dead_stores=tuple(dead_stores),
        dtype_bytes=dtype_bytes,
        peak_bytes=int(peak),
        naive_bytes=int(naive),
        pool_bytes=int(sum(slot_bytes)),
        slot_of=slot_of,
        slot_bytes=tuple(slot_bytes),
        words=plans,
    )


def _identity_plan(program: Program, dtype_bytes: int) -> MemPlan:
    """Conservative fallback: run every word, free nothing."""
    words = program.words
    n = len(words)
    naive = sum(
        _region_raw_bytes(program, a, dtype_bytes)
        for a in [program.input_addr] + [mc.out_addr for mc in words]
    )
    plans = {}
    for i, mc in enumerate(words):
        spec = program.layer_specs[i]
        lt = LayerType(mc.layer_type)
        plans[i] = WordPlan(
            index=i, store=True,
            fuse_relu=(lt == LayerType.CONV
                       and fuse.can_fuse_conv_epilogue(mc)),
            fuse_upsample=(lt == LayerType.UPSAMPLE
                           and spec.upsample_mode == "fused"),
            free_after=(), drop_cache=False,
        )
    return MemPlan(
        n_words=n, schedule=tuple(range(n)), dead_words=(), dead_stores=(),
        dtype_bytes=dtype_bytes, peak_bytes=int(naive), naive_bytes=int(naive),
        pool_bytes=int(naive), slot_of={}, slot_bytes=(), words=plans,
    )


def optimize_program(program: Program) -> Program:
    """Return ``program`` with unreachable words removed (indices in the
    side tables remapped).  Addresses are untouched — the data-pool
    layout, concat adjacency, and addr_shapes all still hold."""
    plan = plan_program(program)
    if not plan.dead_words:
        return program
    remap = {old: new for new, old in enumerate(plan.schedule)}
    return Program(
        words=[program.words[i] for i in plan.schedule],
        tables=list(program.tables),
        weight_bindings={remap[i]: v
                         for i, v in program.weight_bindings.items()
                         if i in remap},
        layer_specs={remap[i]: v
                     for i, v in program.layer_specs.items()
                     if i in remap},
        input_addr=program.input_addr,
        input_shape_chw=program.input_shape_chw,
        outputs=dict(program.outputs),
        addr_shapes=dict(program.addr_shapes),
        arena_bytes=program.arena_bytes,
    )


def admissible_batch(
    peak_bytes_per_image: int,
    budget_bytes: int,
    *,
    multiple: int = 1,
    floor: int = 1,
) -> int:
    """Largest batch whose planned activation footprint fits the budget,
    rounded down to ``multiple`` (a plan's batch multiple) but never
    below ``max(multiple, floor)`` — a bucket that cannot fit even one
    group still has to serve it."""
    multiple = max(1, int(multiple))
    lo = max(int(floor), multiple)
    if peak_bytes_per_image <= 0 or budget_bytes <= 0:
        return lo
    b = int(budget_bytes) // int(peak_bytes_per_image)
    b = (b // multiple) * multiple
    return max(lo, b)


def plan_disassembly(program: Program, *, dtype_bytes: int = 4) -> str:
    """Disassembly of the memplan-optimized program plus the plan
    summary — the golden-snapshot text for one model."""
    plan = plan_program(program, dtype_bytes=dtype_bytes)
    opt = optimize_program(program)
    lines = [
        f"# memplan: words={plan.n_words} live={len(plan.schedule)} "
        f"dead_words={len(plan.dead_words)} "
        f"dead_stores={len(plan.dead_stores)}",
        f"# bytes: peak={plan.peak_bytes} pool={plan.pool_bytes} "
        f"naive={plan.naive_bytes} reduction={plan.reduction:.3f} "
        f"(dtype_bytes={plan.dtype_bytes})",
        f"# slots: n={len(plan.slot_bytes)} "
        f"sizes=[{','.join(str(s) for s in plan.slot_bytes)}]",
    ]
    for i in plan.schedule:
        wp = plan.words[i]
        mc = program.words[i]
        flags = [
            f for f, on in (
                ("fuse_relu", wp.fuse_relu),
                ("fuse_upsample", wp.fuse_upsample),
                ("dead_store", not wp.store),
                ("drop_cache", wp.drop_cache),
            ) if on
        ]
        frees = ",".join(f"{a:#x}" for a in wp.free_after) or "-"
        slot = plan.slot_of.get(mc.out_addr, -1)
        lines.append(
            f"# w{i:03d} out={mc.out_addr:#08x} slot={slot} "
            f"free=[{frees}] flags=[{','.join(flags) or '-'}]"
        )
    return opt.disassemble() + "\n" + "\n".join(lines) + "\n"
